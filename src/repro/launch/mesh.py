"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run, whose XLA_FLAGS must
be set before the first jax initialisation.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "tp_axis"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    ADSALA_TP overrides the model-axis degree (total chips preserved) —
    the §Perf hillclimb knob for shifting TP<->DP balance.
    """
    import os
    tp = int(os.environ.get("ADSALA_TP", "16"))
    if multi_pod:
        shape = (2, 512 // (2 * tp), tp)
        axes = ("pod", "data", "model")
    else:
        shape = (256 // tp, tp)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def tp_axis(mesh) -> str:
    return "model"
