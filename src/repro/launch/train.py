"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --scale smoke --steps 50 --ckpt-dir /tmp/ckpt

Scales:
  smoke — reduced config, CPU-sized, no mesh (CI / laptop)
  full  — the assigned config on the production mesh (TPU pod)

Wraps the step loop in the fault-tolerant driver (checkpoint/restart,
preemption handling, straggler detection) and the prefetching data
pipeline.  When an ADSALA artifact is supplied the tuner is loaded and
its worker-config choices are logged for the serve path.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_config, get_smoke_config
from repro.data.pipeline import Prefetcher, SyntheticLM, make_global_batch
from repro.ft.driver import DriverConfig, TrainDriver
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ShapeSpec
from repro.train.optim import AdamWConfig
from repro.train.step import build_train_step, init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_IDS)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/adsala_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.scale == "full":
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
        shape = SHAPES["train_4k"]
    else:
        cfg = get_smoke_config(args.arch)
        mesh = None
        shape = ShapeSpec("custom", args.seq, args.batch, "train")

    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps,
                          compress=args.compress_grads)
    step_fn, s_specs, b_specs = build_train_step(
        model, cfg, shape, mesh, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    state = init_train_state(model, cfg, opt_cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] {cfg.name} scale={args.scale} params={n_params:,}")

    data_src = SyntheticLM(
        cfg.vocab, shape.seq_len, shape.global_batch,
        audio_dim=cfg.d_model if cfg.family == "audio" else None,
        audio_len=cfg.encoder_len)
    data = ({k: jnp.asarray(v) for k, v in b.items()}
            for b in Prefetcher(iter(data_src), depth=2))

    driver = TrainDriver(
        DriverConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     max_steps=args.steps),
        jit_step, state, data, mesh=mesh, specs=s_specs)
    if args.resume:
        resumed = driver.maybe_resume()
        print(f"[train] resumed from step {resumed}")

    t0 = time.perf_counter()
    summary = driver.run()
    dt = time.perf_counter() - t0
    print(f"[train] done: step={summary['step']} "
          f"loss={summary['last_metrics'].get('loss', float('nan')):.4f} "
          f"wall={dt:.1f}s stragglers={len(summary['stragglers'])}")


if __name__ == "__main__":
    main()
