import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and record memory/cost/collective analyses.

MUST be executed as its own process (``python -m repro.launch.dryrun``):
the XLA_FLAGS line above creates 512 placeholder host devices and must
run before any other jax import in the process.

Per cell this emits results/dryrun/<arch>_<shape>_<mesh>.json with:
  memory_analysis  — bytes per device (arguments / temp / output / peak)
  cost_analysis    — per-device HLO FLOPs + bytes accessed
  collectives      — per-op-kind byte totals parsed from post-SPMD HLO
  model_flops      — 6·N·D (dense) / 6·N_active·D (MoE) for §Roofline
"""

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_config
from repro.dist.sharding import named_shardings
from repro.kernels.recorder import DispatchRecorder
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ShapeSpec
from repro.serve.step import (
    build_decode,
    build_prefill,
    decode_inputs_sds,
    prefill_batch_sds,
)
from repro.train.optim import AdamWConfig
from repro.train.step import abstract_state, build_train_step, train_batch_sds

_DTYPE = jnp.bfloat16

#: long_500k eligibility (DESIGN.md §Arch-applicability): sub-quadratic
#: state only — recurrent or window-bounded caches.
LONG_OK = {"mixtral-8x22b", "recurrentgemma-2b", "xlstm-125m"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return False, ("full-attention arch: 524288-token dense KV cache "
                       "is quadratic-cost; skipped per DESIGN.md")
    return True, ""


_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
    r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
                "u16": 2, "u8": 1, "pred": 1}
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt.split("e")[0][:4], 2)
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum output-shape bytes of every collective op in post-SPMD HLO.

    Shapes in the partitioned module are PER-DEVICE.  ``-start`` /
    ``-done`` pairs are counted once (on the start op).  Ops are
    bucketed by scope: "entry" (executed once) vs "loop" (inside a
    non-entry computation — scan/while bodies, executed trip-count
    times; the roofline post-processing multiplies by the recorded
    layer-loop trip count, XLA cost analysis counts them once).
    """
    out = {k: {"count": 0, "bytes": 0, "loop_count": 0, "loop_bytes": 0}
           for k in _COLL_KINDS}
    in_entry = False
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
        elif stripped.startswith("}") and not line.startswith(" "):
            in_entry = False
        elif re.match(r"^%?\S+ \(", stripped) and stripped.endswith("{") \
                and not line.startswith(" "):
            in_entry = False
        if "=" not in stripped:
            continue
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+([a-z0-9-]+)", stripped)
        if not m:
            continue
        op = m.group(2)
        base = op.removesuffix("-start")
        if op.endswith("-done"):
            continue
        if base in _COLL_KINDS:
            nbytes = _shape_bytes(m.group(1))
            if in_entry:
                out[base]["count"] += 1
                out[base]["bytes"] += nbytes
            else:
                out[base]["loop_count"] += 1
                out[base]["loop_bytes"] += nbytes
    return out


def build_cell(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]

    if shape.kind == "train":
        fn, s_specs, b_specs = build_train_step(
            model, cfg, shape, mesh, AdamWConfig())
        state_sds = abstract_state(model, cfg, AdamWConfig(), _DTYPE)
        batch_sds = train_batch_sds(cfg, shape, _DTYPE)
        in_shardings = (named_shardings(mesh, s_specs),
                        named_shardings(mesh, b_specs))
        out_shardings = (named_shardings(mesh, s_specs), None)
        args = (state_sds, batch_sds)
    elif shape.kind == "prefill":
        fn, p_specs, b_specs = build_prefill(model, cfg, shape, mesh)
        from repro.models.params import abstract_params
        params_sds = abstract_params(model.defs, _DTYPE)
        batch_sds = prefill_batch_sds(cfg, shape, _DTYPE)
        in_shardings = (named_shardings(mesh, p_specs),
                        named_shardings(mesh, b_specs))
        out_shardings = None
        args = (params_sds, batch_sds)
    else:  # decode
        fn, p_specs, io_specs = build_decode(model, cfg, shape, mesh)
        from repro.models.params import abstract_params
        params_sds = abstract_params(model.defs, _DTYPE)
        token_sds, cache_sds_, pos_sds = decode_inputs_sds(
            model, cfg, shape, _DTYPE)
        t_spec, c_specs, pos_spec = io_specs
        in_shardings = (named_shardings(mesh, p_specs),
                        named_shardings(mesh, t_spec),
                        named_shardings(mesh, c_specs),
                        named_shardings(mesh, pos_spec))
        out_shardings = (None, named_shardings(mesh, c_specs))
        args = (params_sds, token_sds, cache_sds_, pos_sds)
    return cfg, model, fn, args, in_shardings, out_shardings


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "n_devices": 512 if multi_pod else 256}
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, model, fn, args, in_sh, out_sh = build_cell(arch, shape_name,
                                                     mesh)

    t0 = time.time()
    donate = ((0,) if os.environ.get("ADSALA_DONATE") == "1"
              and shape_name.startswith("train") else ())
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    # the routine-aware call sites report their dispatches at trace
    # time, so wrapping .lower() yields the cell's per-call-site
    # routine mix — how much of this arch's dispatch volume is
    # SYRK/TRSM-eligible — with zero extra compile work
    with DispatchRecorder() as rec:
        lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per exec
        cost = cost[0] if cost else {}
    colls = parse_collectives(compiled.as_text())
    shape = SHAPES[shape_name]
    n_tok = (shape.tokens if shape.kind != "decode"
             else shape.global_batch)
    flops_factor = 6 if shape.kind == "train" else 2
    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            # CPU-backed jax builds expose no peak stat; args+temp is
            # the live-set upper bound the roofline needs
            "peak_bytes": getattr(
                mem, "peak_memory_in_bytes",
                mem.argument_size_in_bytes + mem.temp_size_in_bytes),
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes accessed", 0.0),
        },
        "collectives": colls,
        # trace-time dispatch observability (events are per call site
        # per trace: scanned layer stacks count once per unit layer —
        # a routine *mix*, not an absolute count)
        "dispatch": {
            "events": len(rec.events),
            "routine_mix": rec.routine_mix(),
            "routine_mix_events": rec.routine_mix(by="events"),
            "summary": rec.summary(),
            # aggregated (routine, m, k, n) rows: what
            # repro.launch.profile folds into a WorkloadProfile to
            # weight the install grid by this cell's workload
            "shapes": rec.shape_table(),
        },
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens": n_tok,
            # 6ND train / 2ND inference per token
            "model_flops": flops_factor * cfg.active_param_count() * n_tok,
        },
        # trip counts for the xla-counts-loop-bodies-once correction
        "loops": {
            "layer_repeats": getattr(model, "repeats", 0),
            "prefix_layers": len(getattr(model, "prefix", [])),
            "suffix_layers": len(getattr(model, "suffix", [])),
            "unit_len": len(getattr(model, "unit", [])),
            "n_layers": cfg.n_layers,
        },
    })
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell for --mesh")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = [False, True] if args.mesh == "both" \
        else [args.mesh == "multi"]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                path = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_name}.json")
                if os.path.exists(path):
                    print(f"[dryrun] cached {path}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, multi, args.out)
                except Exception as e:  # noqa: BLE001 - report and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    gb = rec["memory"]["argument_bytes"] / 2**30
                    extra = (f" compile={rec['compile_s']}s "
                             f"args={gb:.2f}GiB/dev")
                print(f"[dryrun]   -> {status}{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
