"""Serving launcher: batched prefill + decode loop with the ADSALA tuner.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --scale smoke --requests 4 --gen-tokens 16 \
        --artifact results/adsala_artifact

Demonstrates the runtime workflow of the paper (Fig 3): the tuner is
loaded once at boot, consulted per GEMM *shape* (memoised — repeated
decode steps hit the cache), and its chosen worker configurations are
reported alongside the generation stats.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_config, get_smoke_config
from repro.models.transformer import Ctx
from repro.train.step import make_ctx

#: total-variation distance between the serving routine mix and the
#: installed workload profile above which serve warns (0 = identical)
DRIFT_WARN = 0.25


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_IDS)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--queue", action="store_true",
                    help="trace-driven continuous batching: serve a "
                         "ragged request queue (prompt lengths up to "
                         "--prompt-len, outputs up to --gen-tokens) "
                         "through the paged-KV scheduler instead of "
                         "one fixed batch")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (batch width) in --queue mode")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page size (token slots) in --queue mode")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="total KV pages in the shared pool (default: "
                         "2x worst case for --slots sequences)")
    ap.add_argument("--artifact", default=None,
                    help="ADSALA artifact dir (tuner enabled when set)")
    ap.add_argument("--registry", default=None,
                    help="per-architecture artifact registry root: "
                         "fingerprint this host and serve from its own "
                         "cell, falling back to the nearest populated "
                         "neighbour (mutually exclusive with "
                         "--artifact); with --reinstall the loop "
                         "targets this machine's cell")
    ap.add_argument("--search-width", type=int, default=None,
                    help="beam width for dispatch-time config search "
                         "over the artifact's persisted space (default: "
                         "fixed-candidate argmin, the paper's policy)")
    ap.add_argument("--profile-out", default=None,
                    help="write the recorded dispatch mix as a "
                         "WorkloadProfile JSON (feed it back into the "
                         "installer via repro.launch.profile)")
    ap.add_argument("--profile-by", default="flops",
                    choices=["flops", "events"],
                    help="dispatch-volume weighting of --profile-out; "
                         "keep the default to merge with dry-run "
                         "profiles (repro.launch.profile uses flops "
                         "weighting by default, and mixed weightings "
                         "refuse to merge)")
    ap.add_argument("--reinstall", action="store_true",
                    help="close the serving loop: watch live dispatch "
                         "drift vs the installed workload profile and "
                         "re-install + hot-swap the artifact in the "
                         "background when it crosses the threshold "
                         "(requires --artifact)")
    ap.add_argument("--reinstall-threshold", type=float, default=0.25,
                    help="drift (total variation, 0..1) that triggers "
                         "a background re-install")
    ap.add_argument("--reinstall-budget", type=int, default=2000,
                    help="timing budget (cells) for each background "
                         "re-install; keeps the online install cheap")
    ap.add_argument("--reinstall-cooldown", type=float, default=300.0,
                    help="minimum seconds between re-installs")
    args = ap.parse_args()

    cfg = (get_config if args.scale == "full"
           else get_smoke_config)(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    from repro.kernels.recorder import DispatchRecorder

    # separate recorders per traffic class: prefill and decode have very
    # different shape profiles, and the re-install manager merges them
    # volume-weighted so the install budget follows serving volume
    recs = {"prefill": DispatchRecorder(), "decode": DispatchRecorder()}

    fingerprint = None
    if args.registry:
        if args.artifact:
            raise SystemExit("--registry and --artifact are mutually "
                             "exclusive: the registry resolves the "
                             "artifact by this machine's fingerprint")
        from repro.core.registry import (ArtifactRegistry,
                                         resolve_serving_artifact)
        resolved = resolve_serving_artifact(args.registry)
        fingerprint = resolved.local
        if resolved.path is None:
            raise SystemExit(
                f"registry {args.registry} has no servable artifact in "
                f"any cell — run an install first "
                "(repro.launch.profile --registry ...)")
        if not resolved.exact and args.reinstall:
            # the re-install loop must own a LOCAL cell (never
            # overwrite the neighbour's artifact with this machine's
            # corrected timings): seed ours by adopting the neighbour
            reg = ArtifactRegistry(args.registry)
            args.artifact = reg.adopt(fingerprint, resolved.path)
            print(f"[serve] registry: cold cell {fingerprint.key()} "
                  f"seeded from nearest neighbour "
                  f"{resolved.cell.key()} (adopt; re-installs stay "
                  "local)")
        else:
            args.artifact = resolved.path
            cell = ("own cell" if resolved.exact
                    else f"nearest cell {resolved.cell.key()}")
            print(f"[serve] registry: serving {cell} for "
                  f"{fingerprint.key()}")

    tuner = None
    manager = None
    if args.artifact and os.path.isdir(args.artifact):
        mode = (f"beam search width {args.search_width}"
                if args.search_width else "fixed-candidate argmin")
        if args.reinstall:
            from repro.core.installer import InstallConfig
            from repro.serve import ReinstallConfig, ReinstallManager
            # backend=None on purpose: the manager rebuilds the same
            # kind of backend that installed the artifact (its
            # "backend" provenance block) — a measured artifact
            # re-installs measured, legacy ones fall back to the
            # simulator
            manager = ReinstallManager(
                args.artifact, recs,
                fingerprint=fingerprint,
                cfg=ReinstallConfig(
                    threshold=args.reinstall_threshold,
                    cooldown_s=args.reinstall_cooldown,
                    min_events=8,
                    install=InstallConfig(
                        n_samples=160, repeats=2,
                        models=("lightgbm",),
                        timing_budget=args.reinstall_budget)),
                search_width=args.search_width)
            tuner = manager
            print(f"[serve] ADSALA tuner loaded from {args.artifact} "
                  f"({mode}); online re-install armed at drift > "
                  f"{args.reinstall_threshold}")
        else:
            from repro.core import AdsalaTuner
            tuner = AdsalaTuner.from_artifact(
                args.artifact, search_width=args.search_width,
                local_fingerprint=fingerprint)
            print(f"[serve] ADSALA tuner loaded from {args.artifact} "
                  f"({mode})")
    elif args.reinstall:
        raise SystemExit("--reinstall requires --artifact (or "
                         "--registry) pointing at an installed ADSALA "
                         "artifact")

    if args.queue:
        _serve_queue(args, cfg, model, params, tuner, manager, recs)
        return

    cache_len = args.prompt_len + args.gen_tokens
    pctx = make_ctx(None, "prefill", cache_len=cache_len, remat=False,
                    tuner=tuner)
    dctx = make_ctx(None, "decode", cache_len=cache_len, tuner=tuner)

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        rng, (args.requests, args.prompt_len), 0, cfg.vocab)
    batch_extra = {}
    if cfg.family == "audio":
        batch_extra["audio_emb"] = jax.random.normal(
            rng, (args.requests, cfg.encoder_len, cfg.d_model))

    prefill = jax.jit(lambda p, t: model.prefill(
        p, ({"tokens": t, **batch_extra} if cfg.family == "audio" else t),
        pctx))
    decode = jax.jit(lambda p, tok, c, pos: model.decode_step(
        p, tok, c, pos, dctx))

    t0 = time.perf_counter()
    # the recorders observe the trace-time dispatches of both steps:
    # which routine every contraction was tagged as, per call site
    with recs["prefill"]:
        logits, cache = prefill(params, prompts)
        logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    if tuner is not None:
        # the serving GEMM shapes the tuner is consulted for
        d = cfg.d_model
        shapes = [(args.requests * args.prompt_len, d, d),  # qkv/o proj
                  (args.requests, d, cfg.vocab)]            # decode logits
        for (m, k, n) in shapes:
            c = tuner.select(m, k, n)
            print(f"[serve] tuner GEMM {m}x{k}x{n} -> chips={c.n_chips} "
                  f"partition={c.partition} tile={c.tile}")

    toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [toks]
    t0 = time.perf_counter()
    for i in range(args.gen_tokens - 1):
        with recs["decode"]:        # decode dispatches trace on step 0
            logits, cache = decode(params, toks,
                                   cache, jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(toks)
        if manager is not None and manager.check():
            print(f"[serve] drift {manager.last_drift:.3f} crossed "
                  f"{args.reinstall_threshold} at decode step {i}: "
                  "background re-install launched (serving continues)")
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    tps = args.requests * (args.gen_tokens - 1) / max(t_decode, 1e-9)
    print(f"[serve] {cfg.name}: {args.requests} requests, "
          f"prefill {args.prompt_len} toks in {t_prefill*1e3:.1f}ms, "
          f"decoded {args.gen_tokens} toks at {tps:.1f} tok/s")
    print(f"[serve] sample continuation ids: {out[0, :8].tolist()}")
    _report_tail(args, cfg, recs, tuner, manager)


def _report_tail(args, cfg, recs, tuner, manager) -> None:
    """Shared post-run reporting: routine mix, tuner/re-install stats,
    optional --profile-out — identical for fixed-batch and --queue."""
    from repro.kernels.recorder import DispatchRecorder

    # combined view across traffic classes for reporting / --profile-out
    rec = DispatchRecorder()
    for r in recs.values():
        rec.events.extend(r.events)
    mix = rec.routine_mix(by="events")
    if mix:
        pretty = " ".join(f"{r}={f:.2f}" for r, f in mix.items())
        print(f"[serve] dispatch routine mix (by events): {pretty} "
              f"over {len(rec.events)} traced events")
    if manager is not None:
        if manager.installing:
            print("[serve] waiting for the background re-install...")
        manager.wait()
        if manager.last_error is not None:
            print(f"[serve] re-install failed (old artifact still "
                  f"serving): {manager.last_error!r}")
        drift = manager.drift()
        print(f"[serve] tuner stats: {tuner.stats}")
        print(f"[serve] re-install: fires={manager.fires} "
              f"swaps={manager.swaps} post-swap drift="
              f"{'n/a' if drift is None else format(drift, '.3f')}")
    elif tuner is not None:
        print(f"[serve] tuner stats: {tuner.stats}")
        # compare the live mix against the profile the install grid was
        # weighted by (same weighting the profile was built with)
        if tuner.workload is not None and rec.events:
            drift = tuner.workload_drift(
                rec.routine_mix(by=tuner.workload.by))
            print(f"[serve] workload drift vs installed profile: "
                  f"{drift:.3f} (total variation)")
            if drift > DRIFT_WARN:
                print(f"[serve] WARNING: serving mix drifted "
                      f"{drift:.2f} > {DRIFT_WARN} from the installed "
                      "workload profile — the install budget was spent "
                      "on a different routine mix; re-profile and "
                      "re-install (repro.launch.profile)")
    if args.profile_out:
        from repro.core.workload import WorkloadProfile
        prof = WorkloadProfile.from_recorder(
            rec, by=args.profile_by,
            source={"kind": "serve", "arch": cfg.name,
                    "queue": bool(args.queue),
                    "requests": args.requests,
                    "prompt_len": args.prompt_len,
                    "gen_tokens": args.gen_tokens})
        prof.save(args.profile_out)
        print(f"[serve] workload profile written to {args.profile_out}")


def _serve_queue(args, cfg, model, params, tuner, manager, recs) -> None:
    """Trace-driven continuous batching: ragged requests through the
    paged-KV scheduler, re-install drift checks riding the step hook."""
    import numpy as np

    from repro.serve.kv_cache import pages_for
    from repro.serve.scheduler import ContinuousBatchingScheduler

    max_seq = args.prompt_len + args.gen_tokens
    worst = pages_for(max_seq, args.page_size)
    n_pages = (args.kv_pages if args.kv_pages is not None
               else 2 * args.slots * worst)
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=args.slots, n_pages=n_pages,
        page_size=args.page_size, max_seq_len=max_seq, tuner=tuner,
        recorders=recs)

    rng = np.random.default_rng(1)
    for _ in range(args.requests):
        length = int(rng.integers(max(2, args.prompt_len // 4),
                                  args.prompt_len + 1))
        new = int(rng.integers(max(1, args.gen_tokens // 4),
                               args.gen_tokens + 1))
        sched.submit(rng.integers(0, cfg.vocab, length).tolist(), new)

    def on_step(s):
        if manager is not None and manager.check():
            print(f"[serve] drift {manager.last_drift:.3f} crossed the "
                  f"threshold at decode step {s.steps}: background "
                  "re-install launched (serving continues)")

    t0 = time.perf_counter()
    finished = sched.run_until_drained(on_step=on_step)
    wall = time.perf_counter() - t0

    toks = sum(len(f.tokens) for f in finished.values())
    tps = toks / max(wall, 1e-9)
    print(f"[serve] {cfg.name}: {len(finished)} requests via "
          f"continuous batching ({args.slots} slots, {n_pages} pages x "
          f"{args.page_size} tokens), {toks} tokens in {wall*1e3:.1f}ms "
          f"({tps:.1f} tok/s), goodput {sched.goodput():.3f} "
          f"tok/slot-step over {sched.steps} steps")
    sample = min(finished)
    print(f"[serve] sample continuation ids: "
          f"{list(finished[sample].tokens)[:8]}")
    sched.alloc.check()
    _report_tail(args, cfg, recs, tuner, manager)


if __name__ == "__main__":
    main()
