"""Workload profiling entry point: dryrun → profile → install.

Folds the per-cell ``dispatch`` blocks that ``repro.launch.dryrun``
persists (and/or serve profiles written by ``repro.launch.serve
--profile-out``) into one merged :class:`~repro.core.workload.
WorkloadProfile`, writes it out, and optionally runs a mix-weighted
ADSALA install driven by it:

    # 1. dry-run some cells (separate process; see dryrun docstring)
    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b
    # 2. fold the recorded mixes into a profile and install against it
    PYTHONPATH=src python -m repro.launch.profile \
        --dryrun-dir results/dryrun --out results/workload_profile.json \
        --install --artifact results/adsala_artifact_workload

Cells are merged proportionally to their recorded flop volume — an arch
that dispatches 10x the contraction flops pulls the install budget 10x
harder toward its shapes.  This module never imports jax: it reads the
persisted JSON blocks, so profiling + installing runs anywhere.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.core import InstallConfig, SimulatedBackend, install
from repro.core.costmodel import ROUTINES
from repro.core.workload import WorkloadProfile


def profiles_from_dryrun(dryrun_dir: str, *, arch: str | None = None,
                         shape: str | None = None,
                         mesh: str | None = None, by: str = "flops"
                         ) -> list[WorkloadProfile]:
    """One profile per ok dry-run cell JSON (optionally filtered)."""
    out: list[WorkloadProfile] = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") != "ok" or "dispatch" not in cell:
            continue
        if arch is not None and cell.get("arch") != arch:
            continue
        if shape is not None and cell.get("shape") != shape:
            continue
        if mesh is not None and cell.get("mesh") != mesh:
            continue
        out.append(WorkloadProfile.from_dispatch_block(
            cell["dispatch"], by=by,
            source={"kind": "dryrun", "arch": cell.get("arch"),
                    "shape": cell.get("shape"),
                    "mesh": cell.get("mesh"), "path": path}))
    return out


def build_profile(args: argparse.Namespace) -> WorkloadProfile:
    profiles: list[WorkloadProfile] = []
    if args.dryrun_dir:
        profiles += profiles_from_dryrun(
            args.dryrun_dir, arch=args.arch, shape=args.shape,
            mesh=args.mesh, by=args.by)
    for path in args.profile or []:
        profiles.append(WorkloadProfile.load(path))
    if not profiles:
        sys.exit(f"[profile] no dispatch blocks under "
                 f"{args.dryrun_dir!r} and no --profile files; run "
                 "repro.launch.dryrun (or serve --profile-out) first")
    if len(profiles) == 1:
        return profiles[0]
    return WorkloadProfile.merge(profiles)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fold recorded dispatch mixes into a WorkloadProfile "
                    "and (optionally) run a mix-weighted install")
    ap.add_argument("--dryrun-dir", default="results/dryrun",
                    help="directory of repro.launch.dryrun cell JSONs")
    ap.add_argument("--profile", action="append", default=None,
                    help="extra WorkloadProfile JSON(s) to merge in "
                         "(e.g. from serve --profile-out); repeatable")
    ap.add_argument("--arch", default=None,
                    help="only fold cells of this arch")
    ap.add_argument("--shape", default=None,
                    help="only fold cells of this shape (e.g. "
                         "decode_32k for a decode-serving profile)")
    ap.add_argument("--mesh", default=None, choices=["single", "multi"],
                    help="only fold cells on this mesh")
    ap.add_argument("--by", default="flops",
                    choices=["flops", "events"],
                    help="dispatch-volume weighting of the profile")
    ap.add_argument("--out", default="results/workload_profile.json")
    ap.add_argument("--install", action="store_true",
                    help="run a mix-weighted install driven by the "
                         "profile (simulated v5e backend)")
    ap.add_argument("--artifact", default="results/adsala_artifact_workload")
    ap.add_argument("--registry", default=None,
                    help="install into this per-arch registry root "
                         "instead of --artifact: the cell is this "
                         "machine's hardware fingerprint and the "
                         "commit is atomic (tmp/COMMIT/.prev)")
    ap.add_argument("--backend", default="simulated",
                    choices=["simulated", "measured"],
                    help="timing backend: 'measured' times real "
                         "blocked BLAS-3 on this host "
                         "(MeasuredCPUBackend) instead of the v5e "
                         "analytic model")
    ap.add_argument("--transfer", default="none",
                    help="'none' (full local gather), 'nearest' (pick "
                         "the closest populated registry cell as "
                         "donor; needs --registry), or a donor "
                         "artifact path: warm-start from the donor's "
                         "gathered rows and only time "
                         "--calibration-dims locally")
    ap.add_argument("--calibration-dims", type=int, default=32,
                    help="donor dims re-timed locally by a transfer "
                         "install")
    ap.add_argument("--samples", type=int, default=400,
                    help="install budget (paper scale: 1763)")
    ap.add_argument("--bias", type=float, default=0.75,
                    help="fraction of the budget biased toward the "
                         "profile's shape regions / routine mix")
    ap.add_argument("--space", default="default",
                    choices=["default", "enlarged"],
                    help="candidate ConfigSpace the install searches: "
                         "'enlarged' is ~11x the paper grid (3*2^k chip "
                         "counts, extra tiles, TRSM pipeline depth) and "
                         "pairs with --timing-budget")
    ap.add_argument("--timing-budget", type=int, default=None,
                    help="total timed (dim x config) cells; when set, a "
                         "cost-model beam search picks which cells to "
                         "time instead of the dense grid")
    ap.add_argument("--beam-width", type=int, default=8,
                    help="beam width of the budgeted install's search")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    profile = build_profile(args)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    profile.save(args.out)
    print(f"[profile] merged profile -> {args.out}")
    print(profile.table())

    if not args.install:
        return
    # install over every known routine: observed ones get the lion's
    # share via the profile quotas, unobserved ones keep floor coverage
    space = None
    if args.space == "enlarged":
        from repro.core import ConfigSpace
        space = ConfigSpace.enlarged()
    cfg = InstallConfig(
        n_samples=args.samples, routines=tuple(ROUTINES),
        workload=profile, workload_bias=args.bias, seed=args.seed,
        space=space, timing_budget=args.timing_budget,
        beam_width=args.beam_width,
        calibration_dims=args.calibration_dims)
    if args.backend == "measured":
        from repro.core.timing import MeasuredCPUBackend
        backend = MeasuredCPUBackend(seed=args.seed, repeats=3)
    else:
        backend = SimulatedBackend(seed=args.seed)
    grid = (f"{args.space} space, "
            + (f"budget {args.timing_budget} cells, beam "
               f"{args.beam_width}" if args.timing_budget
               else "dense grid"))
    transfer = None if args.transfer == "none" else args.transfer
    if args.registry:
        from repro.core.registry import (ArtifactRegistry,
                                         HardwareFingerprint)
        reg = ArtifactRegistry(args.registry)
        fp = HardwareFingerprint.collect()
        print(f"[profile] mix-weighted install: {args.samples} samples, "
              f"bias {args.bias}, {grid}, {args.backend} backend -> "
              f"registry cell {fp.key()}")
        report = reg.install(fp, backend, cfg, transfer_from=transfer,
                             verbose=True)
    else:
        if transfer == "nearest":
            sys.exit("[profile] --transfer nearest needs --registry "
                     "(there is no registry to pick a neighbour from)")
        print(f"[profile] mix-weighted install: {args.samples} samples, "
              f"bias {args.bias}, {grid}, {args.backend} backend -> "
              f"{args.artifact}")
        report = install(backend, cfg, artifact_dir=args.artifact,
                         transfer_from=transfer, verbose=True)
    print(report.table())


if __name__ == "__main__":
    main()
