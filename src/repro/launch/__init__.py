"""Launchers: mesh, dryrun, train, serve, profile (dryrun -> workload
profile -> mix-weighted install)."""
