"""Checkpointing: atomic sharded save/restore, elastic reshard."""
