"""Sharded, atomic checkpointing with elastic restore.

Layout per step:
    <dir>/step_<n>.tmp/          (written)
    <dir>/step_<n>/              (atomic rename on completion)
        meta.json                (tree structure, shapes, dtypes, step)
        arrays.npz               (flattened path -> host array)
        COMMIT                   (sentinel written last)

Restore targets ANY mesh: arrays are saved unsharded (per-host shard
concatenation in multi-host deployments; this container is single-host)
and re-placed with the target sharding at load, which is what makes
scale-up/scale-down (elastic) restarts work.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.dist.sharding import is_partition_spec

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_SEP = "//"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(state)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(arrays),
                   "treedef": str(treedef)}, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       mesh=None, specs: Any = None) -> Any:
    """Restore into the structure of ``like`` (a state pytree or
    ShapeDtypeStruct tree), re-sharding onto ``mesh``/``specs`` if given —
    the elastic path: the saved arrays are mesh-agnostic."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    z = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    spec_leaves = (jax.tree_util.tree_leaves(specs,
                                             is_leaf=is_partition_spec)
                   if specs is not None else [None] * len(flat))
    for (path_k, leaf), spec in zip(flat, spec_leaves):
        key = _SEP.join(str(p) for p in path_k)
        arr = z[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if mesh is not None and spec is not None:
            leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread, so the
    step loop never blocks on disk (one in flight at a time)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot now

        def work():
            save_checkpoint(self.directory, step, host_state)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
