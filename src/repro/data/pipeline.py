"""Deterministic synthetic token pipeline with background prefetch.

Production shape: per-host generation of the host's shard of the global
batch, assembled into a global jax.Array via the mesh sharding.  The
synthetic stream is a stateless function of (seed, step) so restarts
resume mid-epoch exactly (the checkpoint stores only the step counter).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding

__all__ = ["SyntheticLM", "Prefetcher", "make_global_batch"]


class SyntheticLM:
    """Markov-ish synthetic LM tokens: learnable but non-trivial."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, audio_dim: int | None = None,
                 audio_len: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.audio_dim = audio_dim
        self.audio_len = audio_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # token t+1 = (a * t + drift) % V on half the stream, noise rest
        base = rng.integers(0, self.vocab, (b, 1))
        mult = rng.integers(1, 8, (b, 1))
        idx = np.arange(s)[None, :]
        structured = (base + mult * idx) % self.vocab
        noise = rng.integers(0, self.vocab, (b, s))
        mask = rng.random((b, 1)) < 0.5
        tokens = np.where(mask, structured, noise).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.audio_dim:
            out["audio_emb"] = rng.standard_normal(
                (b, self.audio_len, self.audio_dim)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_global_batch(batch: dict[str, np.ndarray], mesh, specs: dict
                      ) -> dict[str, jax.Array]:
    """Host numpy -> globally sharded jax.Arrays per the spec tree."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        out[k] = jax.device_put(v, NamedSharding(mesh, specs[k]))
    return out


class Prefetcher:
    """Background-thread prefetch of the next N batches (overlap of host
    data generation with device compute)."""

    def __init__(self, source: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._src = source
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        try:
            for item in self._src:
                if self._done:
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self) -> None:
        self._done = True
