"""Data pipeline: deterministic synthetic streams + prefetch."""
