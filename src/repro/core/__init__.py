"""ADSALA core: the paper's contribution as a composable library.

Pipeline:  halton -> timing backend -> features/preprocessing -> ml zoo
           -> installer (Fig 2) -> artifact -> AdsalaTuner (Fig 3)
           -> tuned GEMM dispatch (repro.kernels.ops.tuned_matmul).
"""

from repro.core.costmodel import (
    DEFAULT_ROUTINE,
    DEFAULT_TILES,
    ROUTINES,
    TRSM_SEQ_CHIPS,
    BatchBreakdown,
    GemmConfig,
    TimeBreakdown,
    TPUSpec,
    candidate_configs,
    estimate_batch,
    estimate_batch_terms,
    estimate_gemm_time,
    estimate_routine_time,
    routine_ids,
)
from repro.core.halton import (
    gemm_bytes,
    sample_gemm_dims,
    sample_gemm_dims_mixture,
    scrambled_halton,
)
from repro.core.installer import (
    DEFAULT_WORKER_CONFIG,
    GatheredData,
    InstallConfig,
    InstallReport,
    gather_data,
    install,
    load_artifact,
)
from repro.core.timing import (
    MeasuredCPUBackend,
    SimulatedBackend,
    time_gemm_grid,
    time_routine_grid,
)
from repro.core.tuner import AdsalaTuner
from repro.core.workload import WorkloadProfile

__all__ = [
    "TPUSpec", "GemmConfig", "TimeBreakdown", "BatchBreakdown",
    "DEFAULT_TILES", "ROUTINES", "DEFAULT_ROUTINE", "TRSM_SEQ_CHIPS",
    "candidate_configs",
    "estimate_gemm_time", "estimate_routine_time", "routine_ids",
    "estimate_batch", "estimate_batch_terms", "time_gemm_grid",
    "time_routine_grid",
    "scrambled_halton", "sample_gemm_dims", "sample_gemm_dims_mixture",
    "gemm_bytes", "WorkloadProfile",
    "InstallConfig", "GatheredData", "InstallReport", "gather_data",
    "install", "load_artifact", "DEFAULT_WORKER_CONFIG",
    "SimulatedBackend", "MeasuredCPUBackend",
    "AdsalaTuner",
]
