"""ADSALA core: the paper's contribution as a composable library.

Pipeline:  halton -> timing backend -> features/preprocessing -> ml zoo
           -> installer (Fig 2) -> artifact -> AdsalaTuner (Fig 3)
           -> tuned GEMM dispatch (repro.kernels.ops.tuned_matmul).

One search harness sits under all of it: a declarative
:class:`~repro.core.search.ConfigSpace` (axes + admissibility gates)
turned into a :class:`~repro.core.search.SearchGraph` and explored by
:func:`~repro.core.search.beam_search` — the installer times its
survivors under a budget, the tuner beam-searches at dispatch on cache
miss, and ``candidate_configs`` is its exhaustive enumeration.
"""

from repro.core.costmodel import (
    DEFAULT_ROUTINE,
    DEFAULT_TILES,
    ROUTINES,
    TRSM_SEQ_CHIPS,
    BatchBreakdown,
    GemmConfig,
    TimeBreakdown,
    TPUSpec,
    candidate_configs,
    estimate_batch,
    estimate_batch_terms,
    estimate_gemm_time,
    estimate_routine_time,
    routine_ids,
)
from repro.core.halton import (
    gemm_bytes,
    sample_gemm_dims,
    sample_gemm_dims_mixture,
    scrambled_halton,
)
from repro.core.installer import (
    DEFAULT_WORKER_CONFIG,
    GatheredData,
    InstallConfig,
    InstallReport,
    gather_data,
    install,
    load_artifact,
    transfer_gather,
)
from repro.core.registry import (
    ArtifactRegistry,
    HardwareFingerprint,
    ResolvedArtifact,
    resolve_serving_artifact,
)
from repro.core.search import (
    Axis,
    BeamResult,
    ConfigSpace,
    Gate,
    SearchGraph,
    beam_search,
    exhaustive_best,
)
from repro.core.timing import (
    MeasuredCPUBackend,
    SimulatedBackend,
    backend_from_dict,
    describe_backend,
    time_gemm_grid,
    time_routine_cells,
    time_routine_grid,
)
from repro.core.tuner import AdsalaTuner
from repro.core.workload import WorkloadProfile

__all__ = [
    "TPUSpec", "GemmConfig", "TimeBreakdown", "BatchBreakdown",
    "DEFAULT_TILES", "ROUTINES", "DEFAULT_ROUTINE", "TRSM_SEQ_CHIPS",
    "candidate_configs",
    "estimate_gemm_time", "estimate_routine_time", "routine_ids",
    "estimate_batch", "estimate_batch_terms", "time_gemm_grid",
    "time_routine_grid", "time_routine_cells",
    "Axis", "Gate", "ConfigSpace", "SearchGraph", "BeamResult",
    "beam_search", "exhaustive_best",
    "scrambled_halton", "sample_gemm_dims", "sample_gemm_dims_mixture",
    "gemm_bytes", "WorkloadProfile",
    "InstallConfig", "GatheredData", "InstallReport", "gather_data",
    "install", "load_artifact", "transfer_gather",
    "DEFAULT_WORKER_CONFIG",
    "SimulatedBackend", "MeasuredCPUBackend",
    "describe_backend", "backend_from_dict",
    "HardwareFingerprint", "ArtifactRegistry", "ResolvedArtifact",
    "resolve_serving_artifact",
    "AdsalaTuner",
]
