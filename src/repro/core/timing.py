"""Timing backends for install-time data gathering (paper Fig 2, left box).

Two backends:

* ``SimulatedBackend`` — the TPU v5e analytic model (costmodel.py).  The
  default on this CPU-only container; see DESIGN.md §Hardware adaptation.
* ``MeasuredCPUBackend`` — real wall-clock timing of a K-blocked numpy
  GEMM on the host.  The tunable knob with measurable effect on a single
  CPU core is the K-panel chunk (cache blocking); it demonstrates the
  full ADSALA pipeline against genuine measurements, reproducing the
  paper's install procedure 1:1 (repeat loop, median, separate
  configurations per run).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Protocol

import numpy as np

from repro.core.costmodel import (
    DEFAULT_TILES,
    GemmConfig,
    TPUSpec,
    estimate_batch_terms,
    estimate_gemm_time,
)

__all__ = ["TimingBackend", "SimulatedBackend", "MeasuredCPUBackend",
           "time_gemm_grid"]


class TimingBackend(Protocol):
    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        """One timed execution (seconds)."""
        ...


def time_gemm_grid(backend: "TimingBackend", dims: np.ndarray,
                   cfgs: list[GemmConfig], repeats: int) -> np.ndarray:
    """Median-of-``repeats`` timing matrix, shape (D, C), for any backend.

    Uses the backend's whole-grid batched path when it has one (the
    simulated backend times every (dim x config) cell per call); falls
    back to the scalar ``time_gemm`` loop for measured backends, where
    each execution is genuinely sequential wall-clock.
    """
    batch = getattr(backend, "time_gemm_batch", None)
    if batch is not None:
        reps = np.stack([batch(dims, cfgs) for _ in range(repeats)])
        return np.median(reps, axis=0)
    dims = np.asarray(dims, dtype=np.int64)
    times = np.empty((len(dims), len(cfgs)))
    for i, (m, k, n) in enumerate(dims):
        for j, c in enumerate(cfgs):
            reps = [backend.time_gemm(int(m), int(k), int(n), c)
                    for _ in range(repeats)]
            times[i, j] = float(np.median(reps))
    return times


@dataclasses.dataclass
class SimulatedBackend:
    """Analytic TPU model with measurement noise."""

    spec: TPUSpec = dataclasses.field(default_factory=TPUSpec)
    dtype_bytes: int = 2
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        return estimate_gemm_time(m, k, n, cfg, self.spec,
                                  dtype_bytes=self.dtype_bytes,
                                  rng=self._rng).total_s

    def time_gemm_batch(self, dims: np.ndarray,
                        cfgs: list[GemmConfig]) -> np.ndarray:
        """One noisy timing of every (dim x config) cell, shape (D, C).

        A single vectorised pass over the grid — the batched analogue of
        calling :meth:`time_gemm` D*C times, drawing noise from the same
        backend stream.
        """
        return estimate_batch_terms(dims, cfgs, self.spec,
                                    dtype_bytes=self.dtype_bytes,
                                    rng=self._rng).total_s

    def time_gemm_clean(self, m: int, k: int, n: int,
                        cfg: GemmConfig) -> float:
        """Noise-free ground truth (used by benchmarks for ideal speedup)."""
        return estimate_gemm_time(m, k, n, cfg, self.spec,
                                  dtype_bytes=self.dtype_bytes).total_s

    def time_gemm_clean_batch(self, dims: np.ndarray,
                              cfgs: list[GemmConfig]) -> np.ndarray:
        """Noise-free (D, C) ground-truth grid."""
        return estimate_batch_terms(dims, cfgs, self.spec,
                                    dtype_bytes=self.dtype_bytes).total_s


@dataclasses.dataclass
class MeasuredCPUBackend:
    """Wall-clock timing of a blocked numpy SGEMM on the host CPU.

    cfg.tile[1] (bk) selects the K-panel size of an explicitly blocked
    matmul — the single-core analogue of a cache-blocking parameter.
    cfg.n_chips is ignored (one physical core in the container); the
    candidate set used with this backend holds n_chips=1.
    """

    max_dim: int = 2048
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._buffers: dict[tuple[int, int], np.ndarray] = {}

    def _operand(self, r: int, c: int) -> np.ndarray:
        key = (r, c)
        if key not in self._buffers:
            self._buffers[key] = self._rng.standard_normal(
                (r, c)).astype(np.float32)
        return self._buffers[key]

    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        m, k, n = (min(d, self.max_dim) for d in (m, k, n))
        a = self._operand(m, k)
        b = self._operand(k, n)
        bk = max(8, min(cfg.tile[1], k))
        t0 = time.perf_counter()
        c = np.zeros((m, n), dtype=np.float32)
        for k0 in range(0, k, bk):
            c += a[:, k0:k0 + bk] @ b[k0:k0 + bk, :]
        dt = time.perf_counter() - t0
        del c
        return dt
