"""Timing backends for install-time data gathering (paper Fig 2, left box).

Two backends:

* ``SimulatedBackend`` — the TPU v5e analytic model (costmodel.py).  The
  default on this CPU-only container; see DESIGN.md §Hardware adaptation.
  Covers every ROUTINES entry (gemm / syrk / trsm / attn).
* ``MeasuredCPUBackend`` — real wall-clock timing of K-blocked numpy
  BLAS-3 routines (plus a KV-chunked causal attention) on the host.  The tunable knob with measurable effect
  on a single CPU core is the K-panel chunk (cache blocking); it
  demonstrates the full ADSALA pipeline against genuine measurements,
  reproducing the paper's install procedure 1:1 (repeat loop, median,
  separate configurations per run).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Protocol

import numpy as np

from repro.core.costmodel import (
    DEFAULT_TILES,
    GemmConfig,
    TPUSpec,
    estimate_batch_terms,
    estimate_routine_time,
    routine_ids,
    ROUTINES,
)

__all__ = ["TimingBackend", "SimulatedBackend", "MeasuredCPUBackend",
           "time_gemm_grid", "time_routine_grid", "time_routine_cells",
           "describe_backend", "backend_from_dict"]


class TimingBackend(Protocol):
    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        """One timed GEMM execution (seconds)."""
        ...


def time_routine_grid(backend: "TimingBackend", dims: np.ndarray,
                      cfgs: list[GemmConfig], repeats: int, *,
                      routines=None) -> np.ndarray:
    """Median-of-``repeats`` timing matrix, shape (D, C), for any backend.

    ``routines`` is ``None`` (all gemm), one routine name, or one
    name/id per dim.  Uses the backend's whole-grid batched path when it
    has one (the simulated backend times every (dim x config) cell per
    call); falls back to a scalar per-cell loop for measured backends,
    where each execution is genuinely sequential wall-clock.
    """
    dims = np.asarray(dims, dtype=np.int64)
    rids = routine_ids(routines, len(dims))
    batch = getattr(backend, "time_routine_batch", None)
    if batch is not None:
        reps = np.stack([batch(dims, cfgs, routines=rids)
                         for _ in range(repeats)])
        return np.median(reps, axis=0)
    legacy_batch = getattr(backend, "time_gemm_batch", None)
    if legacy_batch is not None and not rids.any():
        reps = np.stack([legacy_batch(dims, cfgs) for _ in range(repeats)])
        return np.median(reps, axis=0)
    scalar = getattr(backend, "time_routine", None)
    times = np.empty((len(dims), len(cfgs)))
    for i, (m, k, n) in enumerate(dims):
        routine = ROUTINES[int(rids[i])]
        for j, c in enumerate(cfgs):
            if scalar is not None:
                reps = [scalar(int(m), int(k), int(n), c, routine=routine)
                        for _ in range(repeats)]
            elif routine == "gemm":
                reps = [backend.time_gemm(int(m), int(k), int(n), c)
                        for _ in range(repeats)]
            else:
                raise TypeError(
                    f"backend {type(backend).__name__} cannot time "
                    f"routine {routine!r}: it has neither "
                    "time_routine(_batch) nor a gemm-only grid")
            times[i, j] = float(np.median(reps))
    return times


def time_routine_cells(backend: "TimingBackend", dims: np.ndarray,
                       cfgs: list[GemmConfig], mask: np.ndarray,
                       repeats: int, *, routines=None) -> np.ndarray:
    """Median-of-``repeats`` timing of only the ``mask``-selected
    (dim, config) cells; the rest of the (D, C) matrix is +inf.

    The sparse counterpart of :func:`time_routine_grid` for budgeted
    installs: a beam search has already decided which cells are worth
    measuring, so a backend with a batched path gets one per-dim batch
    over that dim's selected columns per repeat, and scalar backends
    loop only the selected cells — timing cost scales with
    ``mask.sum()``, not ``D * C``.
    """
    dims = np.asarray(dims, dtype=np.int64)
    rids = routine_ids(routines, len(dims))
    mask = np.asarray(mask, dtype=bool)
    if mask.shape != (len(dims), len(cfgs)):
        raise ValueError(f"mask shape {mask.shape} != "
                         f"({len(dims)}, {len(cfgs)})")
    times = np.full((len(dims), len(cfgs)), np.inf)
    batch = getattr(backend, "time_routine_batch", None)
    scalar = getattr(backend, "time_routine", None)
    for i, (m, k, n) in enumerate(dims):
        js = np.flatnonzero(mask[i])
        if not len(js):
            continue
        if batch is not None:
            sub = [cfgs[j] for j in js]
            reps = np.stack([batch(dims[i:i + 1], sub,
                                   routines=rids[i:i + 1])[0]
                             for _ in range(repeats)])
            times[i, js] = np.median(reps, axis=0)
            continue
        routine = ROUTINES[int(rids[i])]
        for j in js:
            if scalar is not None:
                reps = [scalar(int(m), int(k), int(n), cfgs[j],
                               routine=routine) for _ in range(repeats)]
            elif routine == "gemm":
                reps = [backend.time_gemm(int(m), int(k), int(n), cfgs[j])
                        for _ in range(repeats)]
            else:
                raise TypeError(
                    f"backend {type(backend).__name__} cannot time "
                    f"routine {routine!r}: it has neither "
                    "time_routine(_batch) nor a gemm-only grid")
            times[i, j] = float(np.median(reps))
    return times


def time_gemm_grid(backend: "TimingBackend", dims: np.ndarray,
                   cfgs: list[GemmConfig], repeats: int) -> np.ndarray:
    """GEMM-only grid timing (the pre-routine API, kept for callers that
    never mix routines)."""
    return time_routine_grid(backend, dims, cfgs, repeats, routines=None)


@dataclasses.dataclass
class SimulatedBackend:
    """Analytic TPU model with measurement noise."""

    spec: TPUSpec = dataclasses.field(default_factory=TPUSpec)
    dtype_bytes: int = 2
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    # -- routine-aware API -------------------------------------------------
    def time_routine(self, m: int, k: int, n: int, cfg: GemmConfig, *,
                     routine: str = "gemm") -> float:
        return estimate_routine_time(m, k, n, cfg, self.spec,
                                     routine=routine,
                                     dtype_bytes=self.dtype_bytes,
                                     rng=self._rng).total_s

    def time_routine_batch(self, dims: np.ndarray,
                           cfgs: list[GemmConfig], *,
                           routines=None) -> np.ndarray:
        """One noisy timing of every (dim x config) cell, shape (D, C).

        A single vectorised pass over the grid — the batched analogue of
        calling :meth:`time_routine` D*C times, drawing noise from the
        same backend stream.  Rows may mix routines.
        """
        return estimate_batch_terms(dims, cfgs, self.spec,
                                    dtype_bytes=self.dtype_bytes,
                                    rng=self._rng,
                                    routines=routines).total_s

    def time_routine_clean(self, m: int, k: int, n: int, cfg: GemmConfig,
                           *, routine: str = "gemm") -> float:
        """Noise-free ground truth (used by benchmarks for ideal speedup)."""
        return estimate_routine_time(m, k, n, cfg, self.spec,
                                     routine=routine,
                                     dtype_bytes=self.dtype_bytes).total_s

    def time_routine_clean_batch(self, dims: np.ndarray,
                                 cfgs: list[GemmConfig], *,
                                 routines=None) -> np.ndarray:
        """Noise-free (D, C) ground-truth grid."""
        return estimate_batch_terms(dims, cfgs, self.spec,
                                    dtype_bytes=self.dtype_bytes,
                                    routines=routines).total_s

    # -- GEMM-only wrappers (pre-routine API) ------------------------------
    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        return self.time_routine(m, k, n, cfg, routine="gemm")

    def time_gemm_batch(self, dims: np.ndarray,
                        cfgs: list[GemmConfig]) -> np.ndarray:
        return self.time_routine_batch(dims, cfgs, routines=None)

    def time_gemm_clean(self, m: int, k: int, n: int,
                        cfg: GemmConfig) -> float:
        return self.time_routine_clean(m, k, n, cfg, routine="gemm")

    def time_gemm_clean_batch(self, dims: np.ndarray,
                              cfgs: list[GemmConfig]) -> np.ndarray:
        return self.time_routine_clean_batch(dims, cfgs, routines=None)


@dataclasses.dataclass
class MeasuredCPUBackend:
    """Wall-clock timing of blocked numpy BLAS-3 routines on the host CPU.

    cfg.tile (bm, bk) selects the M/K panel sizes of an explicitly
    blocked routine — the single-core analogue of cache-blocking
    parameters.  cfg.n_chips is ignored (one physical core in the
    container); the candidate set used with this backend holds
    n_chips=1.

    ``repeats``/``warmup`` harden every sample against timing noise on
    shared boxes: each :meth:`time_routine` call runs ``warmup``
    untimed executions (operand/page cache warm, BLAS thread spin-up)
    and returns the **median** of ``repeats`` timed ones.  The
    defaults keep the historical single-execution behaviour; measured
    installs and transfer-calibration samples should raise ``repeats``
    (the grid-level repeat loop in :func:`time_routine_grid` then
    medians *those* medians).
    """

    max_dim: int = 2048
    seed: int = 0
    #: timed executions per sample (median taken); 1 = one raw timing
    repeats: int = 1
    #: untimed executions before the timed ones
    warmup: int = 1

    def __post_init__(self) -> None:
        if self.repeats < 1:
            raise ValueError(f"repeats={self.repeats} < 1")
        if self.warmup < 0:
            raise ValueError(f"warmup={self.warmup} < 0")
        self._rng = np.random.default_rng(self.seed)
        self._buffers: dict[tuple[int, int], np.ndarray] = {}

    def _operand(self, r: int, c: int) -> np.ndarray:
        key = (r, c)
        if key not in self._buffers:
            self._buffers[key] = self._rng.standard_normal(
                (r, c)).astype(np.float32)
        return self._buffers[key]

    def _triangular(self, d: int) -> np.ndarray:
        """Well-conditioned lower-triangular operand for TRSM."""
        key = (-d, d)
        if key not in self._buffers:
            a = np.tril(self._rng.standard_normal((d, d))).astype(
                np.float32)
            np.fill_diagonal(a, np.abs(np.diag(a)) + float(d))
            self._buffers[key] = a
        return self._buffers[key]

    def time_routine(self, m: int, k: int, n: int, cfg: GemmConfig, *,
                     routine: str = "gemm") -> float:
        """Median of ``repeats`` timed executions after ``warmup``
        untimed ones (noise hardening for shared CI boxes)."""
        for _ in range(self.warmup):
            self._run_once(m, k, n, cfg, routine)
        if self.repeats == 1:
            return self._run_once(m, k, n, cfg, routine)
        return float(np.median([self._run_once(m, k, n, cfg, routine)
                                for _ in range(self.repeats)]))

    def _run_once(self, m: int, k: int, n: int, cfg: GemmConfig,
                  routine: str) -> float:
        m, k, n = (min(d, self.max_dim) for d in (m, k, n))
        bk = max(8, min(cfg.tile[1], k))
        if routine == "gemm":
            bm = max(8, min(cfg.tile[0], m))
            a, b = self._operand(m, k), self._operand(k, n)
            t0 = time.perf_counter()
            c = np.zeros((m, n), dtype=np.float32)
            for m0 in range(0, m, bm):
                am = a[m0:m0 + bm]
                for k0 in range(0, k, bk):
                    c[m0:m0 + bm] += am[:, k0:k0 + bk] @ b[k0:k0 + bk, :]
            dt = time.perf_counter() - t0
        elif routine == "syrk":
            a = self._operand(m, k)
            t0 = time.perf_counter()
            c = np.zeros((m, m), dtype=np.float32)
            for k0 in range(0, k, bk):
                panel = a[:, k0:k0 + bk]
                c += panel @ panel.T
            c = np.tril(c)
            dt = time.perf_counter() - t0
        elif routine == "trsm":
            # blocked forward substitution L X = B, panel size bk along M
            bm = max(8, min(cfg.tile[1], m))
            ell = self._triangular(m)
            b = self._operand(m, n)
            t0 = time.perf_counter()
            x = b.copy()
            for i0 in range(0, m, bm):
                i1 = min(i0 + bm, m)
                if i0:
                    x[i0:i1] -= ell[i0:i1, :i0] @ x[:i0]
                x[i0:i1] = np.linalg.solve(ell[i0:i1, i0:i1], x[i0:i1])
            dt = time.perf_counter() - t0
            c = x
        elif routine == "attn":
            # causal single-head attention on (Sq=m, Dh=k, Skv=n): the
            # config's flash_bkv chunks the KV axis (cache blocking);
            # its tri grid stops each row's chunk loop at the diagonal
            bkv = max(8, min(cfg.flash_block[1], n))
            q = self._operand(m, k)
            kv = self._operand(n, k)
            v = self._operand(n, k + 1)[:, :k]
            tri = cfg.flash_grid != "dense"
            t0 = time.perf_counter()
            c = np.zeros((m, k), dtype=np.float32)
            qi = np.arange(m, dtype=np.int64)[:, None]
            num = np.zeros((m, k), dtype=np.float32)
            den = np.zeros((m, 1), dtype=np.float32)
            for n0 in range(0, n, bkv):
                n1 = min(n0 + bkv, n)
                rows = slice(0, m)
                if tri and n0 > 0:
                    first = int(np.searchsorted(qi[:, 0], n0))
                    if first >= m:
                        break
                    rows = slice(first, m)
                s = q[rows] @ kv[n0:n1].T
                # finite mask value: a fully-masked row (dense grid,
                # chunk past the diagonal) stays NaN-free garbage that
                # costs the same FLOPs instead of warning on inf - inf
                s = np.where(qi[rows] >= np.arange(n0, n1)[None, :],
                             s, np.float32(-1e30))
                p = np.exp(s - s.max(axis=1, keepdims=True))
                num[rows] += p @ v[n0:n1]
                den[rows] += p.sum(axis=1, keepdims=True)
            c = num / np.maximum(den, 1e-30)
            dt = time.perf_counter() - t0
        else:
            raise ValueError(f"unknown routine {routine!r}")
        del c
        return dt

    def time_gemm(self, m: int, k: int, n: int, cfg: GemmConfig) -> float:
        return self.time_routine(m, k, n, cfg, routine="gemm")


# ---------------------------------------------------------------------------
# backend provenance (per-arch artifact registry)
# ---------------------------------------------------------------------------
#
# Artifacts record WHICH backend timed their grid ("backend" block in
# config.json, written by installer.install) so the serving re-install
# loop can rebuild the same kind of backend — a measured install must
# re-install measured, not silently fall back to the simulator.

def describe_backend(backend: Any) -> dict:
    """JSON-able description of a timing backend (round-trips through
    :func:`backend_from_dict` for the built-in kinds).  Backends outside
    this module can implement ``describe() -> dict``; anything else
    degrades to a kind-only record that cannot be reconstructed."""
    if isinstance(backend, SimulatedBackend):
        return {"kind": "simulated", "seed": backend.seed,
                "dtype_bytes": backend.dtype_bytes,
                "spec": dataclasses.asdict(backend.spec)}
    if isinstance(backend, MeasuredCPUBackend):
        return {"kind": "measured-cpu", "max_dim": backend.max_dim,
                "seed": backend.seed, "repeats": backend.repeats,
                "warmup": backend.warmup}
    describe = getattr(backend, "describe", None)
    if callable(describe):
        return dict(describe())
    return {"kind": type(backend).__name__}


def backend_from_dict(d: dict) -> "TimingBackend":
    """Reconstruct a timing backend from its persisted description.
    Raises ``ValueError`` for kinds this process cannot rebuild (the
    caller decides whether to fall back or refuse)."""
    kind = d.get("kind")
    if kind == "simulated":
        spec = TPUSpec(**d["spec"]) if d.get("spec") else TPUSpec()
        return SimulatedBackend(spec=spec,
                                dtype_bytes=int(d.get("dtype_bytes", 2)),
                                seed=int(d.get("seed", 0)))
    if kind == "measured-cpu":
        return MeasuredCPUBackend(max_dim=int(d.get("max_dim", 2048)),
                                  seed=int(d.get("seed", 0)),
                                  repeats=int(d.get("repeats", 1)),
                                  warmup=int(d.get("warmup", 1)))
    raise ValueError(
        f"cannot reconstruct a timing backend of kind {kind!r} — "
        "pass one explicitly (backend=...)")
