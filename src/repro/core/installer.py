"""ADSALA installation workflow (paper §III-B, Fig 2).

    sample GEMM domain (scrambled Halton)
      -> time every candidate worker config (separate "executions")
      -> preprocess (YJ + standardise + LOF + correlation pruning)
      -> CV hyper-tune every candidate model
      -> measure per-model evaluation latency t_eval on this host
      -> select by estimated speedup  s = t_orig / (t_ADSALA + t_eval)
      -> persist two files: config.json + model.json (paper Fig 2)

The installer returns an ``InstallReport`` whose rows are exactly the
columns of the paper's Tables III/IV.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import ROUTINES, GemmConfig, routine_ids
from repro.core.features import FEATURE_NAMES, build_features
from repro.core.halton import sample_gemm_dims
from repro.core.ml import grid_search, make_model, rmse
from repro.core.ml.base import normalised_rmse, stratified_train_test_split
from repro.core.ml.registry import default_param_grids, model_from_dict
from repro.core.preprocessing import PreprocessPipeline
from repro.core.timing import (
    SimulatedBackend,
    TimingBackend,
    describe_backend,
    time_routine_cells,
    time_routine_grid,
)

__all__ = [
    "GatheredData", "InstallConfig", "ModelReport", "InstallReport",
    "gather_data", "transfer_gather", "install", "load_artifact",
    "default_config", "DEFAULT_WORKER_CONFIG",
    "artifact_tmp_dir", "artifact_prev_dir", "is_artifact",
    "commit_artifact", "rollback_artifact", "resolve_artifact",
]

_PARTITIONS = ("M", "N", "K", "2D")

#: The "use every core" default the paper benchmarks against: all chips,
#: 2D sharding, mid-size tile.
DEFAULT_WORKER_CONFIG = GemmConfig(n_chips=512, partition="2D", tile_id=3)


@dataclasses.dataclass
class InstallConfig:
    n_samples: int = 400
    mem_limit_mb: int = 500
    dtype_bytes: int = 2
    repeats: int = 3                      # paper: 10 iterations per input
    max_chips: int = 512
    #: BLAS-3 routines the install grid covers (arXiv 2406.19621:
    #: routine-aware install).  Without a workload profile the budget is
    #: split ~evenly per routine; with one, proportionally to the
    #: profile's routine weights (with an even floor).
    routines: tuple[str, ...] = ("gemm",)
    #: Recorded :class:`~repro.core.workload.WorkloadProfile` (or None):
    #: when set, routine quotas follow the profile's routine weights and
    #: ``workload_bias`` of the Halton budget is drawn from the
    #: profile's observed shape regions instead of the uniform box.
    workload: Any | None = None
    #: fraction of samples biased toward the profile's shape regions
    #: (and of the routine budget allocated proportionally); the
    #: remaining ``1 - workload_bias`` is the uniform coverage floor.
    workload_bias: float = 0.75
    tile_ids: tuple[int, ...] = (0, 1, 3, 5)
    train_cfgs_per_dim: int = 12          # row subsample for training
    models: tuple[str, ...] = (
        "linear_regression", "elasticnet", "bayesian_regression",
        "decision_tree", "random_forest", "adaboost", "xgboost",
        "lightgbm")
    grid_budget: str = "small"
    cv_splits: int = 3
    test_fraction: float = 0.3
    seed: int = 0
    #: uniform sampling of the (m,k,n) domain, as in the paper (Fig 9's
    #: contour-bounded domain); log-space is an opt-in alternative that
    #: emphasises small GEMMs.
    log_space: bool = False
    dim_min: int = 8
    dim_max: int = 65536
    #: steady-state fraction of GEMM calls whose dims hit the tuner's
    #: memo cache (paper §III-C: "GEMM usage is within a loop with the
    #: same GEMM input size").  Selection uses the warm estimate; the
    #: cold (hit rate 0) estimate is reported alongside.
    cache_hit_rate: float = 0.9
    default_config: GemmConfig = DEFAULT_WORKER_CONFIG
    #: Declarative candidate space (a repro.core.search.ConfigSpace).
    #: None means the default space implied by (max_chips, tile_ids) —
    #: whose enumeration is bit-for-bit the historical candidate list.
    space: Any | None = None
    #: Total (dim, config) cells the install may *time*.  None keeps the
    #: dense grid (every dim x every config).  A budget switches
    #: gather_data to beam-survivor timing: per dim, the analytic cost
    #: model beam-searches the space and only the leaders (plus a
    #: low-discrepancy exploration slice and the default config) are
    #: actually measured — the effective candidate space can grow 10x
    #: without 10x timing cost.
    timing_budget: int | None = None
    #: beam width for budgeted installs (and the README comparison)
    beam_width: int = 8
    #: fraction of each dim's timing quota spent on Halton-sampled
    #: exploration configs instead of beam survivors (guards the model
    #: against the prior's blind spots)
    explore_fraction: float = 0.25
    #: :class:`repro.core.registry.HardwareFingerprint` (or its dict
    #: form) of the machine this install targets; persisted under
    #: ``"fingerprint"`` in config.json so ``from_artifact`` can warn
    #: when an artifact is served on different hardware.  None keeps
    #: the legacy anonymous-artifact layout.
    fingerprint: Any | None = None
    #: transfer installs (``install(..., transfer_from=...)``): how
    #: many donor dims get re-timed on the local backend to fit the
    #: cross-arch correction.
    calibration_dims: int = 32
    #: per calibration dim, how many of the donor's fastest timed
    #: columns to re-time locally (the donor's beam survivors); the
    #: donor's default-config column is always added on top.
    calibration_top_k: int = 4

    @property
    def mem_limit_bytes(self) -> int:
        return self.mem_limit_mb * 2**20

    def resolved_space(self):
        """The ConfigSpace this install searches/enumerates.  Installs
        covering ``attn`` get the flash axes appended (idempotent) —
        the flash knobs only exist to be timed for attention rows."""
        from repro.core.search.space import ConfigSpace  # local: no cycle
        space = self.space if self.space is not None \
            else ConfigSpace.default(self.max_chips, tiles=self.tile_ids)
        if "attn" in self.routines:
            space = space.with_flash()
        return space


def default_config(**overrides: Any) -> InstallConfig:
    return dataclasses.replace(InstallConfig(), **overrides)


def _config_dict(c: GemmConfig) -> dict:
    """JSON form of a config; the TRSM and flash knobs only appear when
    they left their historical defaults, so pre-search (and pre-flash)
    readers keep parsing."""
    d = {"n_chips": c.n_chips, "partition": c.partition,
         "tile_id": c.tile_id}
    if c.trsm_seq_chips != costmodel.TRSM_SEQ_CHIPS:
        d["trsm_seq_chips"] = c.trsm_seq_chips
    if c.flash_block_id != 0:
        d["flash_block_id"] = c.flash_block_id
    if c.flash_grid != "dense":
        d["flash_grid"] = c.flash_grid
    return d


def _config_from_dict(d: dict) -> GemmConfig:
    return GemmConfig(d["n_chips"], d["partition"], d["tile_id"],
                      d.get("trsm_seq_chips", costmodel.TRSM_SEQ_CHIPS),
                      d.get("flash_block_id", 0),
                      d.get("flash_grid", "dense"))


@dataclasses.dataclass
class GatheredData:
    """Long-format timing table + the full (dim x cfg) matrix."""

    dims: np.ndarray                       # (D, 3) int64
    cfgs: list[GemmConfig]                 # C candidates
    times: np.ndarray                      # (D, C) median seconds
    #: per-dim ROUTINES id; None means an all-gemm (pre-routine) grid
    routines: np.ndarray | None = None     # (D,) int64
    #: WorkloadProfile.to_dict() provenance when the grid was
    #: mix-weighted; None for uniform installs
    workload: dict | None = None
    #: (D, C) bool — which cells were actually timed.  None means a
    #: dense grid (every cell).  Budgeted installs only time beam
    #: survivors + exploration configs; un-timed cells hold +inf.
    mask: np.ndarray | None = None
    #: ConfigSpace.to_dict() provenance of the space the candidate
    #: columns came from; None for pre-search grids.
    space: dict | None = None

    def routine_ids(self) -> np.ndarray:
        """(D,) ROUTINES ids, zeros for pre-routine grids."""
        if self.routines is None:
            return np.zeros(len(self.dims), dtype=np.int64)
        return np.asarray(self.routines, dtype=np.int64)

    def routine_names(self) -> list[str]:
        return [ROUTINES[int(r)] for r in self.routine_ids()]

    def timed_mask(self) -> np.ndarray:
        """(D, C) bool of measured cells (all True for dense grids)."""
        if self.mask is None:
            return np.ones(self.times.shape, dtype=bool)
        return np.asarray(self.mask, dtype=bool)

    def optimal_worker_index(self) -> np.ndarray:
        if self.mask is None:
            return np.argmin(self.times, axis=1)
        return np.argmin(np.where(self.timed_mask(), self.times, np.inf),
                         axis=1)

    def to_rows(self, *, per_dim: int | None = None, seed: int = 0
                ) -> tuple[np.ndarray, np.ndarray]:
        """(X_features, y_log_time) long format, optionally subsampling
        configs per dim (the paper separates runs per thread count).
        Only timed cells become rows (budgeted grids are sparse).

        Flash knobs are inert off attn rows, so on a flash-extended grid
        a gemm/syrk/trsm dim sees up to ``len(FLASH_BLOCKS) * 2``
        feature-identical columns per effective config; sampling those
        duplicates would eat the per-dim quota without adding training
        diversity.  Non-attn rows therefore subsample from one
        representative column per (n_chips, partition, tile_id,
        trsm_seq_chips) — the flash-default one when the grid carries it.
        """
        rng = np.random.default_rng(seed)
        D, C = self.times.shape
        rids = self.routine_ids()
        attn_id = ROUTINES.index("attn")
        timed = self.timed_mask()
        # one representative column per non-flash config, defaults first
        rep = np.zeros(C, dtype=bool)
        seen_base: set[tuple] = set()
        for j in sorted(range(C),
                        key=lambda j: (self.cfgs[j].flash_block_id != 0
                                       or self.cfgs[j].flash_grid
                                       != "dense")):
            c = self.cfgs[j]
            base = (c.n_chips, c.partition, c.tile_id, c.trsm_seq_chips)
            if base not in seen_base:
                seen_base.add(base)
                rep[j] = True
        rows_X, rows_y = [], []
        for i in range(D):
            pool = np.flatnonzero(timed[i])
            if rids[i] != attn_id:
                dedup = pool[rep[pool]]
                if len(dedup):
                    pool = dedup
            js = (pool if per_dim is None or per_dim >= len(pool)
                  else rng.choice(pool, size=per_dim, replace=False))
            m, k, n = self.dims[i]
            for j in js:
                cfg = self.cfgs[j]
                rows_X.append((m, k, n, cfg.n_chips, cfg.tile_id,
                               _PARTITIONS.index(cfg.partition), rids[i],
                               cfg.flash_block[0], cfg.flash_block[1],
                               float(cfg.flash_grid != "dense")))
                rows_y.append(self.times[i, j])
        raw = np.asarray(rows_X, dtype=np.float64)
        X = build_features(raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3],
                           raw[:, 4], raw[:, 5],
                           raw[:, 6].astype(np.int64),
                           flash=(raw[:, 7], raw[:, 8], raw[:, 9]))
        y = np.log(np.maximum(np.asarray(rows_y), 1e-12))
        return X, y

    def save(self, path: str) -> None:
        extra = {}
        if self.workload is not None:
            extra["workload_json"] = np.asarray(json.dumps(self.workload))
        if self.mask is not None:
            extra["mask"] = self.timed_mask().astype(np.uint8)
        if self.space is not None:
            extra["space_json"] = np.asarray(json.dumps(self.space))
        np.savez_compressed(
            path, dims=self.dims, times=self.times,
            routines=self.routine_ids(),
            cfg_chips=np.asarray([c.n_chips for c in self.cfgs]),
            cfg_tile=np.asarray([c.tile_id for c in self.cfgs]),
            cfg_part=np.asarray(
                [_PARTITIONS.index(c.partition) for c in self.cfgs]),
            cfg_seq=np.asarray(
                [c.trsm_seq_chips for c in self.cfgs]),
            cfg_fblock=np.asarray(
                [c.flash_block_id for c in self.cfgs]),
            cfg_ftri=np.asarray(
                [int(c.flash_grid != "dense") for c in self.cfgs]),
            **extra)

    @classmethod
    def load(cls, path: str, config: dict | str | None = None
             ) -> "GatheredData":
        """Load a persisted grid.

        ``config`` is the install's sidecar ``config.json`` (a parsed
        dict or a path to it); when given — or when a ``config.json``
        sits next to the ``.npz`` — a grid whose npz predates the
        ``routines`` array is cross-checked against it: if the sidecar
        says the install was mixed-routine, the timing rows CANNOT all
        be gemm, and silently labelling them so would poison any model
        retrained from the file — raise instead.
        """
        z = np.load(path)
        n_cfg = len(z["cfg_chips"])
        seqs = (z["cfg_seq"] if "cfg_seq" in z.files
                else np.full(n_cfg, costmodel.TRSM_SEQ_CHIPS))
        fblocks = (z["cfg_fblock"] if "cfg_fblock" in z.files
                   else np.zeros(n_cfg, dtype=np.int64))
        ftris = (z["cfg_ftri"] if "cfg_ftri" in z.files
                 else np.zeros(n_cfg, dtype=np.int64))
        cfgs = [GemmConfig(int(c), _PARTITIONS[int(p)], int(t), int(s),
                           int(fb), "tri" if ft else "dense")
                for c, t, p, s, fb, ft in zip(
                    z["cfg_chips"], z["cfg_tile"], z["cfg_part"], seqs,
                    fblocks, ftris)]
        routines = (z["routines"].astype(np.int64)
                    if "routines" in z.files else None)
        if isinstance(config, str):
            with open(config) as f:
                config = json.load(f)
        elif config is None:
            sidecar = os.path.join(os.path.dirname(os.path.abspath(path)),
                                   "config.json")
            if os.path.exists(sidecar):
                with open(sidecar) as f:
                    config = json.load(f)
        if routines is None and config is not None:
            installed = config.get("install", {}).get("routines")
            if installed is not None and set(installed) != {"gemm"}:
                raise ValueError(
                    f"{path} has no 'routines' array but its install "
                    f"config says the grid mixed routines {installed}; "
                    "refusing to mislabel every timing row as gemm — "
                    "re-gather the grid or load with the matching "
                    "config.json")
        workload = (json.loads(str(z["workload_json"]))
                    if "workload_json" in z.files else None)
        mask = (z["mask"].astype(bool) if "mask" in z.files else None)
        space = (json.loads(str(z["space_json"]))
                 if "space_json" in z.files else None)
        return cls(dims=z["dims"], cfgs=cfgs, times=z["times"],
                   routines=routines, workload=workload, mask=mask,
                   space=space)


def _assign_routines(cfg: InstallConfig, n: int) -> np.ndarray:
    """Per-dim ROUTINES ids for an ``n``-sample grid.

    Budget split: even across ``cfg.routines`` without a workload
    profile, quota-weighted with one.  Assignment order is a seeded
    permutation, NOT ``i % len(routines)`` cycling: the Halton sequence
    is deterministic and low-discrepancy, so a fixed index stride is
    itself low-discrepancy *within each residue class* — routine id
    becomes perfectly correlated with sample index and every routine
    trains on a systematically different stratum of the shape box (the
    base-3 column's leading digit cycles with period 3, exactly the
    stride a 3-routine install used).  The permutation decouples them
    while staying reproducible via ``cfg.seed``.
    """
    if cfg.workload is not None:
        quotas = cfg.workload.routine_quotas(
            cfg.routines, n, floor=1.0 - cfg.workload_bias)
        counts = [quotas[r] for r in cfg.routines]
    else:
        counts = [len(range(i, n, len(cfg.routines)))
                  for i in range(len(cfg.routines))]
    names = np.repeat(np.asarray(cfg.routines, dtype=object), counts)
    perm = np.random.default_rng(cfg.seed).permutation(n)
    return routine_ids(list(names[perm]), n)


def gather_data(backend: TimingBackend, cfg: InstallConfig) -> GatheredData:
    """Paper Fig 2 'data gathering': Halton-sample the domain, run each
    (input x worker-config) ``repeats`` times, keep the median.

    A mixed-routine install spreads the budget over ``cfg.routines``
    (see :func:`_assign_routines`); the whole grid is still timed in
    batched passes (one per repeat).  With ``cfg.workload`` set, the
    sampled dims are drawn from the profile's observed shape regions
    (``cfg.workload_bias`` fraction, uniform floor for the rest) and
    the routine budget follows the profile's routine weights — install
    effort goes where serving volume actually is.

    Candidate generation routes through ``cfg.resolved_space()``.
    Without a ``timing_budget`` the space is enumerated and every
    (dim x config) cell is timed — for the default space that is
    bit-for-bit the historical grid.  With a budget, an analytic-model
    beam search (:func:`repro.core.search.beam.beam_search`) picks each
    dim's most promising configs and only those — plus a Halton
    exploration slice shared across dims and the always-timed default
    config — are measured; the rest of the grid stays +inf behind
    ``GatheredData.mask``.
    """
    if cfg.workload is not None:
        dims = cfg.workload.sample_dims(
            cfg.n_samples, bias=cfg.workload_bias,
            mem_limit_bytes=cfg.mem_limit_bytes,
            dtype_bytes=cfg.dtype_bytes, seed=cfg.seed,
            dim_min=cfg.dim_min, dim_max=cfg.dim_max,
            log_space=cfg.log_space)
    else:
        dims = sample_gemm_dims(
            cfg.n_samples, mem_limit_bytes=cfg.mem_limit_bytes,
            dtype_bytes=cfg.dtype_bytes, seed=cfg.seed,
            dim_min=cfg.dim_min, dim_max=cfg.dim_max,
            log_space=cfg.log_space)
    space = cfg.resolved_space()
    rids = _assign_routines(cfg, len(dims))
    workload = None if cfg.workload is None else cfg.workload.to_dict()

    if cfg.timing_budget is None:
        cfgs = space.enumerate()
        times = time_routine_grid(backend, dims, cfgs, cfg.repeats,
                                  routines=rids)
        return GatheredData(dims=dims, cfgs=cfgs, times=times,
                            routines=rids, workload=workload,
                            space=space.to_dict())

    # --- budgeted install: time beam survivors, not the grid --------------
    from repro.core.search.beam import beam_search  # local: no cycle

    D = len(dims)
    quota = max(2, cfg.timing_budget // D)     # cells per dim, >= 2
    n_explore = int(round(cfg.explore_fraction * (quota - 1)))
    n_beam = max(1, quota - 1 - n_explore)
    beam = beam_search(dims, space, width=max(cfg.beam_width, n_beam),
                       top_k=n_beam, routines=rids,
                       spec=getattr(backend, "spec", None),
                       dtype_bytes=cfg.dtype_bytes)
    explore = space.sample(n_explore, seed=cfg.seed) if n_explore else []

    col: dict[GemmConfig, int] = {}
    rows_js: list[list[int]] = []
    for d in range(D):
        js = []
        for c in [cfg.default_config] + beam.configs[d] + explore:
            if c not in col:
                col[c] = len(col)
            if col[c] not in js:
                js.append(col[c])
        rows_js.append(js)
    cfgs = list(col)
    mask = np.zeros((D, len(cfgs)), dtype=bool)
    for d, js in enumerate(rows_js):
        mask[d, js] = True
    times = time_routine_cells(backend, dims, cfgs, mask, cfg.repeats,
                               routines=rids)
    return GatheredData(dims=dims, cfgs=cfgs, times=times, routines=rids,
                        workload=workload, mask=mask,
                        space=space.to_dict())


def transfer_gather(backend: TimingBackend, cfg: InstallConfig,
                    donor_dir: str
                    ) -> tuple[GatheredData, dict]:
    """Warm-start a local grid from a donor artifact's gathered rows.

    The cross-arch transfer of the model-driven adaptive-libraries line
    (arXiv 1806.07060): instead of re-timing the donor's full
    (dim x config) grid on this machine, re-time only
    ``cfg.calibration_dims`` donor dims — per dim, the donor's
    ``calibration_top_k`` fastest timed columns (its beam survivors)
    plus the default config — via :func:`time_routine_cells`, fit a
    multiplicative correction in log space
    (``median(log t_local - log t_donor)``) per routine, refined per
    (routine, config) column where calibration measured that column,
    and apply it to every donor cell.  Locally measured cells keep
    their measured value; the
    rest carry the corrected donor estimate.  The returned grid feeds
    the standard :func:`install` machinery, so a new machine cold-starts
    at a few-dozen-sample fraction of the donor's timing budget.

    Returns ``(corrected_grid, transfer_info)`` where ``transfer_info``
    is the JSON-able provenance block persisted under ``"transfer"``.
    """
    grid_path = os.path.join(donor_dir, "grid.npz")
    if not os.path.isfile(grid_path):
        raise FileNotFoundError(
            f"donor artifact {donor_dir} has no grid.npz — it predates "
            "transfer-capable installs; re-install the donor or run a "
            "from-scratch install here")
    donor = GatheredData.load(grid_path)
    donor_config = None
    cfg_path = os.path.join(donor_dir, "config.json")
    if os.path.isfile(cfg_path):
        with open(cfg_path) as f:
            donor_config = json.load(f)

    D, C = donor.times.shape
    rids = donor.routine_ids()
    timed = donor.timed_mask() & np.isfinite(donor.times)
    rng = np.random.default_rng(cfg.seed)
    n_cal = max(1, min(cfg.calibration_dims, D))

    # calibration dims: stratified across the donor's routines so every
    # routine's correction is fit from its own measurements
    unique_rids = sorted(set(int(r) for r in rids))
    quota = {r: n_cal // len(unique_rids) for r in unique_rids}
    for i, r in enumerate(unique_rids):
        if i < n_cal % len(unique_rids):
            quota[r] += 1
    chosen: list[int] = []
    for r in unique_rids:
        pool = np.flatnonzero(rids == r)
        take = min(quota[r], len(pool))
        if take:
            chosen.extend(rng.choice(pool, size=take,
                                     replace=False).tolist())
    if len(chosen) < n_cal:
        rest = np.setdiff1d(np.arange(D), np.asarray(chosen, dtype=int))
        extra = min(n_cal - len(chosen), len(rest))
        if extra:
            chosen.extend(rng.choice(rest, size=extra,
                                     replace=False).tolist())
    cal_idx = np.asarray(sorted(chosen), dtype=int)

    try:
        j_default = donor.cfgs.index(cfg.default_config)
    except ValueError:
        j_default = None
    cal_mask = np.zeros((D, C), dtype=bool)
    for i in cal_idx:
        js = np.flatnonzero(timed[i])
        if not len(js):
            continue
        order = js[np.argsort(donor.times[i, js])]
        take = list(order[:max(1, cfg.calibration_top_k)])
        if (j_default is not None and timed[i, j_default]
                and j_default not in take):
            take.append(j_default)
        cal_mask[i, take] = True

    local = time_routine_cells(backend, donor.dims, donor.cfgs, cal_mask,
                               cfg.repeats, routines=rids)
    meas = cal_mask & np.isfinite(local)
    log_delta = np.zeros_like(local)      # only meas entries are read
    log_delta[meas] = (np.log(np.maximum(local[meas], 1e-12))
                       - np.log(np.maximum(donor.times[meas], 1e-12)))
    all_deltas = log_delta[meas]
    global_delta = float(np.median(all_deltas)) if len(all_deltas) else 0.0
    corrected = donor.times.copy()
    per_routine_delta: dict[str, float] = {}
    for r in unique_rids:
        sel = meas & (rids == r)[:, None]
        d_r = float(np.median(log_delta[sel])) if sel.any() \
            else global_delta
        per_routine_delta[ROUTINES[r]] = d_r
        rowsel = rids == r
        corrected[rowsel] = donor.times[rowsel] * np.exp(d_r)
        # column refinement: calibration times the donor's fastest
        # columns on *every* calibration dim, so most (routine, config)
        # pairs carry their own local measurements — a per-column
        # median captures config-level differences (a cache hierarchy
        # reordering the blocking knob) that a routine-wide scalar
        # cannot
        for j in range(C):
            cj = sel[:, j]
            n_rj = int(cj.sum())
            if n_rj:
                # shrink toward the routine median: a column delta fit
                # from one or two noisy samples should not scale the
                # whole column on its own
                w = n_rj / (n_rj + 1.0)
                d_rj = (w * float(np.median(log_delta[cj, j]))
                        + (1.0 - w) * d_r)
                corrected[rowsel, j] = donor.times[rowsel, j] \
                    * np.exp(d_rj)
    corrected[meas] = local[meas]       # measured truth beats estimates

    data = GatheredData(dims=donor.dims, cfgs=donor.cfgs,
                        times=corrected, routines=donor.routines,
                        workload=donor.workload, mask=donor.mask,
                        space=donor.space)
    info = {
        "donor": os.path.abspath(donor_dir),
        "donor_fingerprint": (donor_config or {}).get("fingerprint"),
        "donor_backend": (donor_config or {}).get("backend"),
        "calibration_dims": int(len(cal_idx)),
        "calibration_cells": int(meas.sum()),
        "donor_cells": int(timed.sum()),
        "log_delta_per_routine": per_routine_delta,
        "global_log_delta": global_delta,
    }
    return data, info


@dataclasses.dataclass
class ModelReport:
    """One row of the paper's Tables III/IV."""

    name: str
    params: dict[str, Any]
    test_rmse: float
    normalised_rmse: float
    eval_time_us: float
    ideal_mean_speedup: float
    ideal_aggregate_speedup: float
    est_mean_speedup: float          # cold: every call pays t_eval
    est_aggregate_speedup: float
    warm_est_mean_speedup: float     # steady state with memo cache
    warm_est_aggregate_speedup: float
    #: routine name -> held-out speedup stats for that routine's dims
    #: (the per-routine Tables III/IV analogue of arXiv 2406.19621)
    per_routine: dict[str, dict[str, float]] = \
        dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class InstallReport:
    selected: str
    reports: list[ModelReport]
    artifact_dir: str | None

    def table(self) -> str:
        hdr = (f"{'model':20s} {'nrmse':>7s} {'ideal_mean':>10s} "
               f"{'ideal_agg':>9s} {'t_eval_us':>9s} {'est_mean':>8s} "
               f"{'est_agg':>8s} {'warm_mean':>9s} {'warm_agg':>8s}")
        lines = [hdr]
        for r in self.reports:
            lines.append(
                f"{r.name:20s} {r.normalised_rmse:7.3f} "
                f"{r.ideal_mean_speedup:10.3f} "
                f"{r.ideal_aggregate_speedup:9.3f} {r.eval_time_us:9.1f} "
                f"{r.est_mean_speedup:8.3f} {r.est_aggregate_speedup:8.3f} "
                f"{r.warm_est_mean_speedup:9.3f} "
                f"{r.warm_est_aggregate_speedup:8.3f}")
        lines.append(f"selected: {self.selected}")
        rt = self.routine_table()
        if rt:
            lines.append(rt)
        return "\n".join(lines)

    def routine_table(self) -> str:
        """Per-routine speedup rows for the selected model (empty string
        for single-routine installs)."""
        sel = next((r for r in self.reports if r.name == self.selected),
                   None)
        if sel is None or len(sel.per_routine) <= 1:
            return ""
        lines = [f"{'routine':8s} {'n_test':>6s} {'ideal_mean':>10s} "
                 f"{'ideal_agg':>9s} {'warm_mean':>9s} {'warm_agg':>8s}"]
        for routine, s in sel.per_routine.items():
            lines.append(
                f"{routine:8s} {int(s['n_test']):6d} "
                f"{s['ideal_mean_speedup']:10.3f} "
                f"{s['ideal_aggregate_speedup']:9.3f} "
                f"{s['warm_est_mean_speedup']:9.3f} "
                f"{s['warm_est_aggregate_speedup']:8.3f}")
        return "\n".join(lines)


def _measure_eval_time(model: Any, pipe: PreprocessPipeline,
                       n_candidates: int, *, iters: int = 30) -> float:
    """Latency of one runtime tuner evaluation (features -> argmin), in µs.

    This is the paper's t_eval: it charges the *whole* per-call path —
    feature build, preprocessing transform and batched model prediction
    over the candidate set.
    """
    Xq = build_features(
        np.full(n_candidates, 512.0), np.full(n_candidates, 512.0),
        np.full(n_candidates, 512.0),
        np.maximum(1, np.arange(n_candidates) % 9),
        np.arange(n_candidates) % 8, np.arange(n_candidates) % 4,
        np.arange(n_candidates) % len(ROUTINES),
        flash=(np.full(n_candidates, 512.0),
               np.full(n_candidates, 512.0),
               np.arange(n_candidates) % 2))
    # warmup
    model.predict(pipe.transform(Xq))
    t0 = time.perf_counter()
    for _ in range(iters):
        model.predict(pipe.transform(Xq))
    return (time.perf_counter() - t0) / iters * 1e6


def _predict_best_configs(model: Any, pipe: PreprocessPipeline,
                          dims: np.ndarray, cfgs: list[GemmConfig],
                          routines: np.ndarray | None = None,
                          mask: np.ndarray | None = None) -> np.ndarray:
    """Predicted-argmin candidate index for every dim, shape (D,).

    Delegates to the runtime tuner's own batched prediction so the
    persisted warm-start choices are, by construction, exactly what the
    tuner would compute for the same artifact.  With ``mask`` (budgeted
    installs) the argmin is restricted to each dim's timed columns —
    the model may only pick configs whose ground truth exists.
    """
    from repro.core.tuner import AdsalaTuner  # local: breaks import cycle

    tuner = AdsalaTuner(model, pipe, cfgs)
    times = tuner.predicted_times_many(
        [(int(m), int(k), int(n)) for m, k, n in np.asarray(dims)],
        routines=None if routines is None else list(routines))
    if mask is not None:
        times = np.where(np.asarray(mask, dtype=bool), times, np.inf)
    return np.argmin(times, axis=1)


def _speedups(model: Any, pipe: PreprocessPipeline, data: GatheredData,
              test_dims_idx: np.ndarray, cfg: InstallConfig,
              eval_time_s: float
              ) -> tuple[tuple[float, float, float, float, float, float],
                         dict[str, dict[str, float]]]:
    """Ideal / cold-estimated / warm-estimated mean + aggregate speedups
    over held-out dims (paper §IV-D), plus the same stats split per
    routine (the arXiv 2406.19621 per-routine tables)."""
    cfgs = data.cfgs
    chips = np.asarray([c.n_chips for c in cfgs], dtype=np.float64)
    try:
        j_default = cfgs.index(cfg.default_config)
    except ValueError:
        j_default = int(np.argmax(chips))
    rids = data.routine_ids()[test_dims_idx]
    t_orig = data.times[test_dims_idx, j_default]
    best_j = _predict_best_configs(
        model, pipe, data.dims[test_dims_idx], cfgs, routines=rids,
        mask=None if data.mask is None else data.mask[test_dims_idx])
    t_chosen = data.times[np.asarray(test_dims_idx), best_j]
    warm_eval = (1.0 - cfg.cache_hit_rate) * eval_time_s

    def _stats(orig: np.ndarray, chosen: np.ndarray
               ) -> tuple[float, float, float, float, float, float]:
        ideal = orig / np.maximum(chosen, 1e-12)
        est = orig / np.maximum(chosen + eval_time_s, 1e-12)
        warm = orig / np.maximum(chosen + warm_eval, 1e-12)
        return (float(ideal.mean()),
                float(orig.sum() / max(chosen.sum(), 1e-12)),
                float(est.mean()),
                float(orig.sum() / max((chosen + eval_time_s).sum(),
                                       1e-12)),
                float(warm.mean()),
                float(orig.sum() / max((chosen + warm_eval).sum(),
                                       1e-12)))

    per_routine: dict[str, dict[str, float]] = {}
    for rid in sorted(set(int(r) for r in rids)):
        sel = rids == rid
        (i_mean, i_agg, _, _, w_mean, w_agg) = _stats(t_orig[sel],
                                                      t_chosen[sel])
        per_routine[ROUTINES[rid]] = {
            "n_test": float(sel.sum()),
            "ideal_mean_speedup": i_mean,
            "ideal_aggregate_speedup": i_agg,
            "warm_est_mean_speedup": w_mean,
            "warm_est_aggregate_speedup": w_agg,
        }
    return _stats(t_orig, t_chosen), per_routine


def install(backend: TimingBackend | None = None,
            cfg: InstallConfig | None = None, *,
            artifact_dir: str | None = None,
            data: GatheredData | None = None,
            transfer_from: str | None = None,
            verbose: bool = False) -> InstallReport:
    """Run the full installation workflow; optionally persist the artifact.

    ``transfer_from`` names a donor artifact directory: the grid is
    warm-started from the donor's persisted rows via
    :func:`transfer_gather` (a few dozen locally-timed calibration
    cells instead of a full gather), and the correction provenance is
    persisted under ``"transfer"`` in config.json.
    """
    cfg = cfg or InstallConfig()
    backend = backend or SimulatedBackend(seed=cfg.seed)
    transfer_info = None
    if data is None:
        if transfer_from is not None:
            data, transfer_info = transfer_gather(backend, cfg,
                                                  transfer_from)
        else:
            data = gather_data(backend, cfg)
    elif transfer_from is not None:
        raise ValueError("pass either data= or transfer_from=, not both")

    # --- split on GEMM *inputs* (not rows) so test dims are unseen --------
    D = len(data.dims)
    dim_idx = np.arange(D)
    log_best = np.log(np.maximum(data.times.min(axis=1), 1e-12))
    _, test_dim_idx, _, _ = stratified_train_test_split(
        dim_idx[:, None], log_best, test_fraction=cfg.test_fraction,
        seed=cfg.seed)
    test_dims = set(test_dim_idx[:, 0].astype(int).tolist())
    if not test_dims:
        # tiny installs (a handful of calibration-scale dims) can leave
        # the stratified split's test side empty; hold out the slowest
        # dim so the report always has a held-out row
        test_dims = {int(np.argmax(log_best))}
    elif len(test_dims) >= D:
        # ... and per-routine strata of one dim each can put *every*
        # dim on the test side; keep at least one dim for training
        test_dims.discard(int(np.argmin(log_best)))
    train_mask = np.asarray([i not in test_dims for i in range(D)])

    rids = data.routine_ids()
    train_data = GatheredData(dims=data.dims[train_mask], cfgs=data.cfgs,
                              times=data.times[train_mask],
                              routines=rids[train_mask],
                              mask=None if data.mask is None
                              else data.mask[train_mask])
    test_idx = np.asarray(sorted(test_dims), dtype=int)

    X_train, y_train = train_data.to_rows(per_dim=cfg.train_cfgs_per_dim,
                                          seed=cfg.seed)
    test_rows = GatheredData(dims=data.dims[test_idx], cfgs=data.cfgs,
                             times=data.times[test_idx],
                             routines=rids[test_idx],
                             mask=None if data.mask is None
                             else data.mask[test_idx])
    X_test, y_test = test_rows.to_rows(per_dim=cfg.train_cfgs_per_dim,
                                       seed=cfg.seed + 1)

    pipe = PreprocessPipeline()
    Xt_train, yt_train = pipe.fit_transform(X_train, y_train)
    Xt_test = pipe.transform(X_test)

    grids = default_param_grids(cfg.grid_budget)
    reports: list[ModelReport] = []
    fitted: dict[str, Any] = {}
    for name in cfg.models:
        grid = grids.get(name, {})
        if grid:
            best_params, _ = grid_search(
                lambda **p: make_model(name, **p), grid, Xt_train, yt_train,
                n_splits=cfg.cv_splits, seed=cfg.seed)
        else:
            best_params = {}
        model = make_model(name, **best_params)
        model.fit(Xt_train, yt_train)
        fitted[name] = model
        test_pred = model.predict(Xt_test)
        t_eval_us = _measure_eval_time(model, pipe, len(data.cfgs))
        ((ideal_mean, ideal_agg, est_mean, est_agg,
          warm_mean, warm_agg), per_routine) = _speedups(
            model, pipe, data, test_idx, cfg, t_eval_us * 1e-6)
        reports.append(ModelReport(
            name=name, params=best_params,
            test_rmse=rmse(y_test, test_pred),
            normalised_rmse=normalised_rmse(y_test, test_pred),
            eval_time_us=t_eval_us,
            ideal_mean_speedup=ideal_mean,
            ideal_aggregate_speedup=ideal_agg,
            est_mean_speedup=est_mean,
            est_aggregate_speedup=est_agg,
            warm_est_mean_speedup=warm_mean,
            warm_est_aggregate_speedup=warm_agg,
            per_routine=per_routine))
        if verbose:
            print(f"[install] {name}: nrmse={reports[-1].normalised_rmse:.3f}"
                  f" est_mean={est_mean:.3f} warm={warm_mean:.3f}"
                  f" t_eval={t_eval_us:.0f}us")

    selected = max(reports, key=lambda r: r.warm_est_mean_speedup).name
    report = InstallReport(selected=selected, reports=reports,
                           artifact_dir=artifact_dir)

    if artifact_dir is not None:
        os.makedirs(artifact_dir, exist_ok=True)
        # Warm-start cache: the selected model's argmin choice for every
        # sampled GEMM dim, computed in one batched predict at install
        # time so the runtime tuner starts with a hot memo cache instead
        # of paying t_eval on first sight of the trained-on shapes.
        warm_best = _predict_best_configs(fitted[selected], pipe,
                                          data.dims, data.cfgs,
                                          routines=data.routine_ids(),
                                          mask=data.mask)
        # paper Fig 2: "two files ... the configurations together with the
        # production-ready ML model"
        with open(os.path.join(artifact_dir, "config.json"), "w") as f:
            json.dump({
                "feature_names": FEATURE_NAMES,
                "preprocess": pipe.to_dict(),
                "candidates": [_config_dict(c) for c in data.cfgs],
                "default_config": _config_dict(cfg.default_config),
                # the declarative space the candidates came from —
                # from_artifact reconstructs it exactly, so dispatch-time
                # search explores the same space the install searched
                "space": (data.space if data.space is not None
                          else cfg.resolved_space().to_dict()),
                "install": {
                    "n_samples": cfg.n_samples,
                    "mem_limit_mb": cfg.mem_limit_mb,
                    "dtype_bytes": cfg.dtype_bytes,
                    "repeats": cfg.repeats, "seed": cfg.seed,
                    "routines": list(cfg.routines),
                    "workload_bias": cfg.workload_bias,
                    "max_chips": cfg.max_chips,
                    "tile_ids": list(cfg.tile_ids),
                    "timing_budget": cfg.timing_budget,
                    "beam_width": cfg.beam_width},
                # WorkloadProfile provenance: the recorded mix this grid
                # was weighted by (None = uniform install).  Surfaced by
                # tuner.from_artifact so serve can warn when the live
                # mix drifts from what was installed.
                "workload": data.workload if data.workload is not None
                else (cfg.workload.to_dict()
                      if cfg.workload is not None else None),
                # provenance: which hardware this install targeted and
                # which backend timed the grid.  Absent/None on legacy
                # artifacts — from_artifact treats that as "unknown"
                # and skips the mismatch check.
                "fingerprint": (
                    cfg.fingerprint.to_dict()
                    if hasattr(cfg.fingerprint, "to_dict")
                    else cfg.fingerprint),
                "backend": describe_backend(backend),
                # non-None iff this was a transfer install: donor path,
                # fitted per-routine log-space correction, calibration
                # budget actually spent
                "transfer": transfer_info,
                "selection": [r.to_dict() for r in reports],
                "selected": selected,
                # v3: explicit config dicts, validated against the
                # persisted space on load (beam-found configs need not
                # sit in any fixed candidate list).  v2 stored argmin
                # *indices* with (routine, m, k, n) keys; v1 blocks (no
                # "version"/"routines") are all-gemm.  from_artifact
                # reads all three.
                "warm_start": {
                    "version": 3,
                    "dims": np.asarray(data.dims,
                                       dtype=np.int64).tolist(),
                    "routines": data.routine_names(),
                    "configs": [_config_dict(data.cfgs[int(j)])
                                for j in warm_best]},
            }, f, indent=1)
        with open(os.path.join(artifact_dir, "model.json"), "w") as f:
            json.dump(fitted[selected].to_dict(), f)
        # the gathered grid itself: transfer installs on other machines
        # warm-start from these rows (transfer_gather reads grid.npz).
        # is_artifact() deliberately ignores it — legacy artifacts stay
        # loadable, they just can't act as transfer donors.
        data.save(os.path.join(artifact_dir, "grid.npz"))
    return report


def load_artifact(artifact_dir: str) -> tuple[Any, PreprocessPipeline,
                                              list[GemmConfig], dict]:
    """Load the two installation files back (paper Fig 3, left box)."""
    with open(os.path.join(artifact_dir, "config.json")) as f:
        config = json.load(f)
    with open(os.path.join(artifact_dir, "model.json")) as f:
        model = model_from_dict(json.load(f))
    pipe = PreprocessPipeline.from_dict(config["preprocess"])
    cands = [_config_from_dict(d) for d in config["candidates"]]
    return model, pipe, cands, config


# ---------------------------------------------------------------------------
# atomic artifact lifecycle (online re-install hot-swap + crash recovery)
# ---------------------------------------------------------------------------
#
# The serving re-install loop (repro.serve.reinstall) writes a fresh
# artifact under traffic, so the on-disk transition must be atomic in
# the same write-to-tmp + commit-sentinel + rename style the checkpoint
# layer uses (repro.ckpt.checkpoint, repro.ft.driver):
#
#     <dir>.tmp/      install() output, COMMIT sentinel written last
#     <dir>/          live artifact (os.replace renames, never copies)
#     <dir>.prev/     the displaced artifact, kept for one-call rollback
#
# A crash at any point leaves either the old artifact in place (tmp
# dirs without COMMIT are ignored and swept on restart) or a recoverable
# two-rename window that resolve_artifact() repairs.

#: sentinel written into a tmp artifact dir after config.json +
#: model.json are complete; commit_artifact refuses dirs without it
ARTIFACT_COMMIT = "COMMIT"


def artifact_tmp_dir(artifact_dir: str) -> str:
    """Staging dir a re-install writes into before the atomic swap."""
    return artifact_dir.rstrip(os.sep) + ".tmp"


def artifact_prev_dir(artifact_dir: str) -> str:
    """Where the displaced artifact lands on commit (rollback source)."""
    return artifact_dir.rstrip(os.sep) + ".prev"


def is_artifact(path: str) -> bool:
    """True when ``path`` holds a loadable artifact (both paper files)."""
    return (os.path.isfile(os.path.join(path, "config.json"))
            and os.path.isfile(os.path.join(path, "model.json")))


def commit_artifact(tmp_dir: str, artifact_dir: str) -> str | None:
    """Atomically promote a committed tmp install to the live artifact.

    Requires the :data:`ARTIFACT_COMMIT` sentinel (the writer stamps it
    only after both artifact files are complete — a killed install never
    has one, so a crashed tmp can never be promoted).  The displaced
    artifact is retained at :func:`artifact_prev_dir` for rollback; its
    previous occupant is deleted.  Returns the prev path, or None when
    there was no artifact to displace.

    Both transitions are single ``os.replace`` renames.  A hard crash
    between them leaves no live dir but a complete ``.prev`` (and the
    committed tmp) — :func:`resolve_artifact` repairs that window by
    restoring ``.prev``, i.e. recovery always lands on a complete
    artifact and never serves a half-written one.
    """
    if not os.path.isfile(os.path.join(tmp_dir, ARTIFACT_COMMIT)):
        raise ValueError(
            f"{tmp_dir} has no {ARTIFACT_COMMIT} sentinel — refusing to "
            "promote a possibly half-written install")
    if not is_artifact(tmp_dir):
        raise ValueError(f"{tmp_dir} is not a complete artifact")
    prev = artifact_prev_dir(artifact_dir)
    displaced = None
    if os.path.isdir(artifact_dir):
        if os.path.isdir(prev):
            shutil.rmtree(prev)
        os.replace(artifact_dir, prev)
        displaced = prev
    os.replace(tmp_dir, artifact_dir)
    return displaced


def rollback_artifact(artifact_dir: str) -> None:
    """Swap the live artifact with ``.prev`` (one-call rollback).

    Pure renames — the restored artifact is byte-for-byte what commit
    displaced.  The rolled-back artifact becomes the new ``.prev``, so
    a second call rolls forward again.
    """
    prev = artifact_prev_dir(artifact_dir)
    if not is_artifact(prev):
        raise FileNotFoundError(f"no rollback artifact at {prev}")
    hold = artifact_dir.rstrip(os.sep) + ".rollback"
    if os.path.isdir(hold):
        shutil.rmtree(hold)
    had_live = os.path.isdir(artifact_dir)
    if had_live:
        os.replace(artifact_dir, hold)
    os.replace(prev, artifact_dir)
    if had_live:
        os.replace(hold, prev)


def resolve_artifact(artifact_dir: str) -> str | None:
    """Crash recovery at boot: return a servable artifact path or None.

    * A live artifact wins; any leftover ``.tmp`` (an install killed
      mid-write OR one killed after COMMIT but before the swap) is
      ignored and swept — an unpromoted install is an aborted install.
    * No live artifact but a complete ``.prev``: the process died inside
      commit_artifact's two-rename window — restore ``.prev``.
    """
    tmp = artifact_tmp_dir(artifact_dir)
    if not is_artifact(artifact_dir):
        prev = artifact_prev_dir(artifact_dir)
        if is_artifact(prev):
            os.replace(prev, artifact_dir)
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    return artifact_dir if is_artifact(artifact_dir) else None
