"""Data preprocessing: Yeo-Johnson (MLE), standardisation, LOF, correlation pruning.

Implements the paper's §II-C / §IV-C pipeline from scratch (the container
has no sklearn/scipy):

  raw features --Yeo-Johnson(λ per feature, MLE)--> near-Gaussian
              --standardise--> zero-mean/unit-var
              --LOF--> drop local outliers
              --|ρ|>0.8 pruning--> decorrelated feature set

Order follows the paper exactly: LOF *after* standardisation ("LOF is a
density-based method and thus requires a similar scale in all
dimensions"), correlation pruning last.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "yeo_johnson_transform",
    "yeo_johnson_transform_matrix",
    "yeo_johnson_mle_lambda",
    "YeoJohnson",
    "StandardScaler",
    "local_outlier_factor",
    "correlation_prune",
    "PreprocessPipeline",
]


# ---------------------------------------------------------------------------
# Yeo-Johnson power transform
# ---------------------------------------------------------------------------

def yeo_johnson_transform(x: np.ndarray, lam: float) -> np.ndarray:
    """Yeo-Johnson transform of a 1-D array for parameter ``lam``."""
    x = np.asarray(x, dtype=np.float64)
    out = np.empty_like(x)
    pos = x >= 0
    # x >= 0 branch
    if abs(lam) < 1e-10:
        out[pos] = np.log1p(x[pos])
    else:
        out[pos] = (np.power(x[pos] + 1.0, lam) - 1.0) / lam
    # x < 0 branch
    if abs(lam - 2.0) < 1e-10:
        out[~pos] = -np.log1p(-x[~pos])
    else:
        out[~pos] = -(np.power(1.0 - x[~pos], 2.0 - lam) - 1.0) / (2.0 - lam)
    return out


def yeo_johnson_transform_matrix(X: np.ndarray,
                                 lambdas: np.ndarray) -> np.ndarray:
    """Vectorised YJ over all columns at once (runtime tuner hot path).

    Equivalent to column-wise ``yeo_johnson_transform`` but one fused
    numpy pass — the per-call latency here is charged to t_eval by the
    paper's model-selection criterion, so it must stay in the tens of µs.
    """
    X = np.asarray(X, dtype=np.float64)
    lam = np.asarray(lambdas, dtype=np.float64)[None, :]
    pos = X >= 0
    lam_zero = np.abs(lam) < 1e-10
    lam_two = np.abs(lam - 2.0) < 1e-10
    xp = np.where(pos, X, 0.0)
    xn = np.where(pos, 0.0, X)
    lam_safe = np.where(lam_zero, 1.0, lam)
    pos_val = np.where(lam_zero, np.log1p(xp),
                       (np.power(xp + 1.0, lam) - 1.0) / lam_safe)
    two_m = np.where(lam_two, 1.0, 2.0 - lam)
    neg_val = np.where(lam_two, -np.log1p(-xn),
                       -(np.power(1.0 - xn, 2.0 - lam) - 1.0) / two_m)
    return np.where(pos, pos_val, neg_val)


def _yj_log_likelihood(x: np.ndarray, lam: float) -> float:
    """Profile log-likelihood of the YJ-transformed data under a Gaussian."""
    n = x.shape[0]
    y = yeo_johnson_transform(x, lam)
    var = y.var()
    if var <= 0 or not np.isfinite(var):
        return -np.inf
    # Jacobian term: (lam - 1) * sum(sign(x) * log1p(|x|))
    jac = (lam - 1.0) * np.sum(np.sign(x) * np.log1p(np.abs(x)))
    return -0.5 * n * np.log(var) + jac


def yeo_johnson_mle_lambda(x: np.ndarray, *, lo: float = -3.0,
                           hi: float = 3.0, tol: float = 1e-4) -> float:
    """MLE of λ via golden-section search on the profile likelihood.

    The likelihood is unimodal in λ for well-behaved data; golden-section
    on [-3, 3] matches scipy's default bracket and needs no gradients.
    """
    x = np.asarray(x, dtype=np.float64)
    invphi = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - invphi * (b - a)
    d = a + invphi * (b - a)
    fc = _yj_log_likelihood(x, c)
    fd = _yj_log_likelihood(x, d)
    while abs(b - a) > tol:
        if fc > fd:
            b, d, fd = d, c, fc
            c = b - invphi * (b - a)
            fc = _yj_log_likelihood(x, c)
        else:
            a, c, fc = c, d, fd
            d = a + invphi * (b - a)
            fd = _yj_log_likelihood(x, d)
    return 0.5 * (a + b)


@dataclasses.dataclass
class YeoJohnson:
    """Per-column Yeo-Johnson transformer with MLE-estimated λ."""

    lambdas_: np.ndarray | None = None

    def fit(self, X: np.ndarray) -> "YeoJohnson":
        X = np.asarray(X, dtype=np.float64)
        self.lambdas_ = np.array(
            [yeo_johnson_mle_lambda(X[:, j]) for j in range(X.shape[1])])
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        if self.lambdas_ is None:
            raise RuntimeError("YeoJohnson not fitted")
        return yeo_johnson_transform_matrix(X, self.lambdas_)

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)


# ---------------------------------------------------------------------------
# Standardisation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StandardScaler:
    mean_: np.ndarray | None = None
    scale_: np.ndarray | None = None

    def fit(self, X: np.ndarray) -> "StandardScaler":
        X = np.asarray(X, dtype=np.float64)
        self.mean_ = X.mean(axis=0)
        std = X.std(axis=0)
        self.scale_ = np.where(std > 1e-12, std, 1.0)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        if self.mean_ is None:
            raise RuntimeError("StandardScaler not fitted")
        return (np.asarray(X, dtype=np.float64) - self.mean_) / self.scale_

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)


# ---------------------------------------------------------------------------
# Local Outlier Factor (Breunig et al. 2000)
# ---------------------------------------------------------------------------

def local_outlier_factor(X: np.ndarray, *, k: int = 20) -> np.ndarray:
    """LOF score per row (≈1 inlier, ≫1 outlier).  Exact O(n²) kNN.

    n ~ 10³ in the paper's datasets, so the dense distance matrix is
    cheap and avoids a KD-tree implementation.
    """
    X = np.asarray(X, dtype=np.float64)
    n = X.shape[0]
    k = min(k, n - 1)
    if k < 1:
        return np.ones(n)
    # pairwise distances
    sq = np.sum(X * X, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    np.maximum(d2, 0.0, out=d2)
    dist = np.sqrt(d2)
    np.fill_diagonal(dist, np.inf)
    # k nearest neighbours
    nn_idx = np.argpartition(dist, k - 1, axis=1)[:, :k]
    rows = np.arange(n)[:, None]
    nn_dist = dist[rows, nn_idx]
    k_dist = nn_dist.max(axis=1)                      # k-distance(p)
    # reachability distance r(p, o) = max(k_dist(o), d(p, o))
    reach = np.maximum(k_dist[nn_idx], nn_dist)
    lrd = 1.0 / (reach.mean(axis=1) + 1e-12)          # local reachability
    lof = (lrd[nn_idx].mean(axis=1)) / (lrd + 1e-12)
    return lof


# ---------------------------------------------------------------------------
# Correlation pruning
# ---------------------------------------------------------------------------

def correlation_prune(X: np.ndarray, *, threshold: float = 0.8,
                      names: list[str] | None = None
                      ) -> tuple[np.ndarray, list[int]]:
    """Drop one of every feature pair with |ρ| > threshold (paper §IV-C).

    "For each correlated feature pair, we remove the feature with the
    larger total correlation with the other features."

    Returns (kept column indices as list, boolean keep-mask) — callers
    index their arrays with the list.
    """
    X = np.asarray(X, dtype=np.float64)
    f = X.shape[1]
    with np.errstate(invalid="ignore"):
        corr = np.corrcoef(X, rowvar=False)
    corr = np.nan_to_num(corr, nan=0.0)
    np.fill_diagonal(corr, 0.0)
    abs_corr = np.abs(corr)
    alive = np.ones(f, dtype=bool)
    while True:
        masked = abs_corr * np.outer(alive, alive)
        i, j = np.unravel_index(np.argmax(masked), masked.shape)
        if masked[i, j] <= threshold:
            break
        # drop the one with larger total correlation to everything alive
        tot_i = masked[i].sum()
        tot_j = masked[j].sum()
        alive[i if tot_i >= tot_j else j] = False
    kept = [int(i) for i in np.nonzero(alive)[0]]
    return alive, kept


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PreprocessPipeline:
    """YJ -> standardise -> (fit-time LOF row filter) -> correlation prune.

    ``fit`` learns λ, mean/scale and the kept-feature set from training
    data and returns the filtered training matrix; ``transform`` applies
    the learned mapping to new data (no row filtering at inference).
    """

    lof_k: int = 20
    lof_threshold: float = 1.5
    corr_threshold: float = 0.8
    yj: YeoJohnson = dataclasses.field(default_factory=YeoJohnson)
    scaler: StandardScaler = dataclasses.field(default_factory=StandardScaler)
    kept_features_: list[int] | None = None
    inlier_mask_: np.ndarray | None = None

    def fit_transform(self, X: np.ndarray, y: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
        Xt = self.yj.fit_transform(X)
        Xt = self.scaler.fit_transform(Xt)
        lof = local_outlier_factor(Xt, k=self.lof_k)
        self.inlier_mask_ = lof <= self.lof_threshold
        # never drop more than 10% of rows — LOF is a cleaner, not a filter
        if self.inlier_mask_.mean() < 0.9:
            order = np.argsort(lof)
            keep_n = int(np.ceil(0.9 * len(lof)))
            self.inlier_mask_ = np.zeros(len(lof), dtype=bool)
            self.inlier_mask_[order[:keep_n]] = True
        Xt = Xt[self.inlier_mask_]
        y = np.asarray(y)[self.inlier_mask_]
        _, self.kept_features_ = correlation_prune(
            Xt, threshold=self.corr_threshold)
        return Xt[:, self.kept_features_], y

    def transform(self, X: np.ndarray) -> np.ndarray:
        if self.kept_features_ is None:
            raise RuntimeError("pipeline not fitted")
        Xt = self.scaler.transform(self.yj.transform(X))
        return Xt[:, self.kept_features_]

    # -- persistence -------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "lambdas": self.yj.lambdas_.tolist(),
            "mean": self.scaler.mean_.tolist(),
            "scale": self.scaler.scale_.tolist(),
            "kept_features": self.kept_features_,
            "lof_k": self.lof_k,
            "lof_threshold": self.lof_threshold,
            "corr_threshold": self.corr_threshold,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PreprocessPipeline":
        p = cls(lof_k=d["lof_k"], lof_threshold=d["lof_threshold"],
                corr_threshold=d["corr_threshold"])
        p.yj.lambdas_ = np.asarray(d["lambdas"])
        p.scaler.mean_ = np.asarray(d["mean"])
        p.scaler.scale_ = np.asarray(d["scale"])
        p.kept_features_ = list(d["kept_features"])
        return p
