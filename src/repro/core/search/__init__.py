"""One search harness for the whole stack: compositional config spaces
(:class:`ConfigSpace`), partial-config action graphs
(:class:`SearchGraph`), and cost-model-guided :func:`beam_search` with
whole-frontier vectorised pricing.  The installer's budgeted grids, the
tuner's dispatch-time ``search=`` path, and the benchmarks all go
through here instead of bespoke candidate lists.
"""

from repro.core.search.beam import BeamResult, beam_search, exhaustive_best
from repro.core.search.graph import SearchGraph
from repro.core.search.space import Axis, ConfigSpace, Gate

__all__ = [
    "Axis", "BeamResult", "ConfigSpace", "Gate", "SearchGraph",
    "beam_search", "exhaustive_best",
]
