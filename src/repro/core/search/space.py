"""Compositional config space: declarative axes + admissibility gates.

The paper's tuner argmins over a flat enumerated candidate list; this
module recasts that list as the exhaustive enumeration of a declarative
:class:`ConfigSpace` — named axes (chip-count doublings, partition
choices, tile splits, routine-specific knobs like the TRSM pipeline
depth) with :class:`Gate` predicates expressing when a value is
admissible (2D sharding needs a 2D submesh; optionally, sharding must
keep a minimum local extent per chip).  The model-driven adaptive-
libraries line (arXiv 1806.07060) motivates the shape: the space is the
product of independent refinements, so a search policy can explore it
compositionally instead of materialising the whole grid.

Two spaces matter in practice:

* ``ConfigSpace.default(...)`` — exactly the historical
  ``candidate_configs`` grid.  ``enumerate()`` reproduces the old triple
  loop bit for bit (chip doublings outer, partitions with the 2D gate,
  then tiles), which is what keeps every persisted artifact and test pin
  meaningful.
* ``ConfigSpace.enlarged(...)`` — ~11x bigger: 3*2^k chip counts, the
  EXTENDED_TILES presets, and the ``trsm_seq_chips`` pipeline-depth knob
  as a fourth axis.  Too big to time exhaustively at install; meant to
  be beam-searched (see :mod:`repro.core.search.beam`).

Spaces serialise to a versioned dict (the artifact's ``"space"`` block)
and reconstruct exactly via :meth:`ConfigSpace.from_dict`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.costmodel import (
    DEFAULT_TILES,
    EXTENDED_TILES,
    FLASH_BLOCKS,
    FLASH_GRIDS,
    PARTITIONS,
    TRSM_SEQ_CHIPS,
    GemmConfig,
    chip_doublings,
)

__all__ = ["Axis", "ConfigSpace", "Gate"]

#: ConfigSpace axis name -> GemmConfig field, in canonical (enumeration)
#: order.  Axes absent from a space pin their field to the dataclass
#: default (``trsm_seq_chips`` -> TRSM_SEQ_CHIPS, flash knobs -> the
#: historical dense 512x512 kernel).
_FIELDS = ("n_chips", "partition", "tile_id", "trsm_seq_chips",
           "flash_block_id", "flash_grid")
_REQUIRED = ("n_chips", "partition", "tile_id")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _2d_factors(p: int) -> tuple[int, int]:
    """The cost model's 2D submesh factorisation: (pm, pn), pm*pn <= p."""
    pm = 2 ** (int(math.log2(p)) // 2)
    return pm, p // pm


@dataclasses.dataclass(frozen=True)
class Gate:
    """Admissibility predicate guarding one axis value.

    kind:
      ``min_chips`` — the guarded value needs ``n_chips >= param``
                      (e.g. 2D sharding needs a 2D submesh).
      ``min_local`` — dims-aware: the guarded partition must keep at
                      least ``param`` elements per chip along every
                      sharded extent.  A no-op when dims are unknown.
      ``flash_tri_rows`` — dims-aware: the triangular flash grid is only
                      admissible when the Q axis (m = Sq) spans at least
                      ``param`` rows of the config's ``flash_bq`` block —
                      on a single-row grid tri degenerates to dense, so
                      enumerating both would double the space for
                      nothing.  Defers while ``flash_block_id`` is
                      unassigned or dims are unknown.

    Gates referencing a not-yet-assigned axis *defer* (admit) — partial
    states stay expandable in any axis order; the predicate re-fires
    once the referenced axis is assigned and on every completion.
    """
    kind: str
    value: object
    param: int

    def admits(self, partial: dict, dims=None) -> bool:
        if self.kind == "min_chips":
            c = partial.get("n_chips")
            return c is None or c >= self.param
        if self.kind == "flash_tri_rows":
            b = partial.get("flash_block_id")
            if dims is None or b is None:
                return True
            return _ceil_div(int(dims[0]), FLASH_BLOCKS[b][0]) >= self.param
        if self.kind == "min_local":
            c = partial.get("n_chips")
            if dims is None or c is None:
                return True
            m, k, n = (int(x) for x in dims)
            if self.value == "M":
                return _ceil_div(m, c) >= self.param
            if self.value == "N":
                return _ceil_div(n, c) >= self.param
            if self.value == "K":
                return _ceil_div(k, c) >= self.param
            if self.value == "2D":
                pm, pn = _2d_factors(c)
                return (_ceil_div(m, pm) >= self.param
                        and _ceil_div(n, pn) >= self.param)
            return True
        raise ValueError(f"unknown gate kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Axis:
    """One refinement dimension: a name, its values (in enumeration
    order), an optional canonical default (used when completing partial
    states for pricing), and the gates guarding individual values."""
    name: str
    values: tuple
    default: object = None
    gates: tuple[Gate, ...] = ()


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    """A product of gated axes over :class:`GemmConfig` fields."""
    axes: tuple[Axis, ...]

    def __post_init__(self):
        names = [ax.name for ax in self.axes]
        for req in _REQUIRED:
            if req not in names:
                raise ValueError(f"ConfigSpace needs a {req!r} axis")
        for nm in names:
            if nm not in _FIELDS:
                raise ValueError(f"unknown axis {nm!r}; "
                                 f"expected one of {_FIELDS}")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axes in {names}")

    # -- admissibility -----------------------------------------------------

    def check(self, partial: dict, dims=None) -> bool:
        """Do all gates of the values assigned in ``partial`` admit it?"""
        for ax in self.axes:
            v = partial.get(ax.name)
            if v is None:
                continue
            for g in ax.gates:
                if g.value == v and not g.admits(partial, dims):
                    return False
        return True

    def axis(self, name: str) -> Axis:
        for ax in self.axes:
            if ax.name == name:
                return ax
        raise KeyError(name)

    # -- enumeration / completion ------------------------------------------

    def _to_config(self, partial: dict) -> GemmConfig:
        return GemmConfig(partial["n_chips"], partial["partition"],
                          partial["tile_id"],
                          partial.get("trsm_seq_chips", TRSM_SEQ_CHIPS),
                          partial.get("flash_block_id", 0),
                          partial.get("flash_grid", "dense"))

    def enumerate(self, dims=None) -> list[GemmConfig]:
        """Every admissible config, in canonical axis order (the old
        ``candidate_configs`` triple-loop order for the default space)."""
        out: list[GemmConfig] = []

        def rec(i: int, partial: dict) -> None:
            if i == len(self.axes):
                out.append(self._to_config(partial))
                return
            ax = self.axes[i]
            for v in ax.values:
                nxt = dict(partial)
                nxt[ax.name] = v
                if self.check(nxt, dims):
                    rec(i + 1, nxt)

        rec(0, {})
        return out

    def size(self, dims=None) -> int:
        """Number of admissible configs (``len(enumerate(dims))``)."""
        count = 0

        def rec(i: int, partial: dict) -> None:
            nonlocal count
            if i == len(self.axes):
                count += 1
                return
            ax = self.axes[i]
            for v in ax.values:
                nxt = dict(partial)
                nxt[ax.name] = v
                if self.check(nxt, dims):
                    rec(i + 1, nxt)

        rec(0, {})
        return count

    def complete(self, partial: dict, dims=None) -> GemmConfig:
        """Canonical completion of a partial assignment: each unassigned
        axis takes its default when admissible, else its first admissible
        value.  This is how search policies price partial states with the
        (whole-config) cost model."""
        filled = dict(partial)
        for ax in self.axes:
            if ax.name in filled:
                continue
            chosen = None
            if ax.default is not None and ax.default in ax.values:
                trial = dict(filled)
                trial[ax.name] = ax.default
                if self.check(trial, dims):
                    chosen = ax.default
            if chosen is None:
                for v in ax.values:
                    trial = dict(filled)
                    trial[ax.name] = v
                    if self.check(trial, dims):
                        chosen = v
                        break
            if chosen is None:
                raise ValueError(
                    f"no admissible value for axis {ax.name!r} "
                    f"completing {partial!r}")
            filled[ax.name] = chosen
        if not self.check(filled, dims):
            raise ValueError(f"partial {partial!r} admits no completion")
        return self._to_config(filled)

    def contains(self, cfg: GemmConfig, dims=None) -> bool:
        """Is ``cfg`` an admissible member of this space?  Fields without
        an axis must sit at their dataclass default."""
        values = {"n_chips": cfg.n_chips, "partition": cfg.partition,
                  "tile_id": cfg.tile_id,
                  "trsm_seq_chips": cfg.trsm_seq_chips,
                  "flash_block_id": cfg.flash_block_id,
                  "flash_grid": cfg.flash_grid}
        names = {ax.name for ax in self.axes}
        if "trsm_seq_chips" not in names \
                and cfg.trsm_seq_chips != TRSM_SEQ_CHIPS:
            return False
        if "flash_block_id" not in names and cfg.flash_block_id != 0:
            return False
        if "flash_grid" not in names and cfg.flash_grid != "dense":
            return False
        partial = {nm: v for nm, v in values.items() if nm in names}
        for ax in self.axes:
            if partial[ax.name] not in ax.values:
                return False
        return self.check(partial, dims)

    def rank_of(self, cfg: GemmConfig) -> tuple:
        """Per-axis value indices in canonical axis order — the config's
        lexicographic position in ``enumerate()``.  Search policies break
        cost ties on this so a full-width beam reproduces the exhaustive
        argmin's first-occurrence tie-breaking exactly."""
        values = {"n_chips": cfg.n_chips, "partition": cfg.partition,
                  "tile_id": cfg.tile_id,
                  "trsm_seq_chips": cfg.trsm_seq_chips,
                  "flash_block_id": cfg.flash_block_id,
                  "flash_grid": cfg.flash_grid}
        return tuple(ax.values.index(values[ax.name]) for ax in self.axes)

    # -- sampling ----------------------------------------------------------

    def sample(self, n: int, *, seed: int = 0, dims=None
               ) -> list[GemmConfig]:
        """Up to ``n`` distinct admissible configs, low-discrepancy over
        the axis lattice (scrambled Halton, one base per axis), axes
        refined in canonical order with gate filtering.  Deterministic
        given ``seed``; used for the exploration slice of budgeted
        installs."""
        from repro.core.halton import scrambled_halton
        out: list[GemmConfig] = []
        seen: set[GemmConfig] = set()
        start = 1
        while len(out) < n and start < 64 * max(n, 8):
            batch = max(64, 2 * (n - len(out)))
            u = scrambled_halton(batch, len(self.axes), seed=seed,
                                 start=start)
            start += batch
            for row in u:
                partial: dict = {}
                dead = False
                for ax, uu in zip(self.axes, row):
                    vals = []
                    for v in ax.values:
                        trial = dict(partial)
                        trial[ax.name] = v
                        if self.check(trial, dims):
                            vals.append(v)
                    if not vals:
                        dead = True
                        break
                    partial[ax.name] = vals[min(int(uu * len(vals)),
                                                len(vals) - 1)]
                if dead:
                    continue
                cfg = self._to_config(partial)
                if cfg not in seen:
                    seen.add(cfg)
                    out.append(cfg)
                    if len(out) == n:
                        break
        return out

    # -- serialisation -----------------------------------------------------

    def to_dict(self) -> dict:
        """Versioned, JSON-ready description (artifact ``"space"`` block)."""
        return {
            "version": 1,
            "axes": [
                {"name": ax.name, "values": list(ax.values),
                 "default": ax.default,
                 "gates": [{"kind": g.kind, "value": g.value,
                            "param": g.param} for g in ax.gates]}
                for ax in self.axes],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ConfigSpace":
        if d.get("version") != 1:
            raise ValueError(
                f"unsupported ConfigSpace version {d.get('version')!r}")
        axes = tuple(
            Axis(a["name"], tuple(a["values"]), a.get("default"),
                 tuple(Gate(g["kind"], g["value"], g["param"])
                       for g in a.get("gates", ())))
            for a in d["axes"])
        return cls(axes)

    # -- stock spaces ------------------------------------------------------

    @classmethod
    def default(cls, max_chips: int = 512, *,
                tiles: Iterable[int] | None = None,
                partitions: Iterable[str] = PARTITIONS) -> "ConfigSpace":
        """The historical ``candidate_configs`` grid as a space:
        enumeration reproduces the old list bit for bit."""
        chips = tuple(chip_doublings(max_chips))
        parts = tuple(partitions)
        tile_ids = tuple(tiles) if tiles is not None \
            else tuple(range(len(DEFAULT_TILES)))
        gates = (Gate("min_chips", "2D", 4),) if "2D" in parts else ()
        return cls((
            Axis("n_chips", chips, default=chips[-1]),
            Axis("partition", parts,
                 default="2D" if "2D" in parts else parts[0],
                 gates=gates),
            Axis("tile_id", tile_ids,
                 default=3 if 3 in tile_ids else tile_ids[0]),
        ))

    def with_flash(self, *, block_ids: Iterable[int] | None = None
                   ) -> "ConfigSpace":
        """This space extended with the flash-attention axes: the
        ``FLASH_BLOCKS`` (bq, bkv) preset and the dense/tri KV-grid
        knob, tri gated on the Q axis actually spanning >= 2 block rows
        (below that the grids are identical).  Idempotent.  Only the
        ``attn`` routine reads these knobs, so pre-existing axes (and
        gemm/syrk/trsm pricing) are untouched — ties on non-attn rows
        break to the dense 512x512 defaults via ``rank_of``."""
        if any(ax.name in ("flash_block_id", "flash_grid")
               for ax in self.axes):
            return self
        ids = tuple(block_ids) if block_ids is not None \
            else tuple(range(len(FLASH_BLOCKS)))
        return ConfigSpace(self.axes + (
            Axis("flash_block_id", ids, default=0),
            Axis("flash_grid", FLASH_GRIDS, default="tri",
                 gates=(Gate("flash_tri_rows", "tri", 2),)),
        ))

    @classmethod
    def enlarged(cls, max_chips: int = 512, *,
                 min_local: int = 8) -> "ConfigSpace":
        """~11x the default grid: 3*2^k chip counts interleaved with the
        doublings, the EXTENDED_TILES presets, and the TRSM pipeline
        depth as a searchable fourth axis.  ``min_local`` gates (dims-
        aware) drop partitions that would shard an extent below one
        sublane row per chip — inadmissible rather than merely slow."""
        base = chip_doublings(max_chips)
        chips = tuple(sorted(set(base)
                             | {3 * c for c in base if 3 * c <= max_chips}))
        gates = tuple([Gate("min_chips", "2D", 4)]
                      + [Gate("min_local", p, min_local)
                         for p in PARTITIONS])
        return cls((
            Axis("n_chips", chips, default=chips[-1]),
            Axis("partition", PARTITIONS, default="2D", gates=gates),
            Axis("tile_id", tuple(range(len(EXTENDED_TILES))), default=3),
            Axis("trsm_seq_chips", (1, 2, 4, 8),
                 default=TRSM_SEQ_CHIPS),
        ))
