"""Cost-model-guided beam search over a ConfigSpace, vectorised per level.

The policy the whole stack shares (installer budget mode, tuner
dispatch-time search, benchmarks): per dim, keep the ``width`` cheapest
partial states, refine one axis per level, and price **every frontier of
every dim in one batched cost call per level** — the union of unseen
canonical completions goes through ``cost_fn(dims, configs, routines)``
(default: noise-free :func:`~repro.core.costmodel.estimate_batch_terms`)
as a single (D, U) grid, exactly the vectorised pass PR 1 built.
Priced configs are cached across levels, so ``n_priced`` — the honest
"how much model work did this cost" count — only grows by genuinely new
(dim, config) cells.

Exactness: ties break on the config's lexicographic position in the
space's canonical enumeration, so at full width and depth the beam
returns bit-for-bit the exhaustive argmin (first occurrence), for every
routine.  :func:`exhaustive_best` is that baseline, shaped like a
:class:`BeamResult` for side-by-side accounting.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import (
    GemmConfig,
    TPUSpec,
    estimate_batch_terms,
)
from repro.core.search.graph import SearchGraph
from repro.core.search.space import ConfigSpace

__all__ = ["BeamResult", "beam_search", "exhaustive_best"]

#: Default axis expansion order: partition first (four informative
#: branches priced at the canonical chip default) before the wide chip
#: axis, then tiles, then routine knobs.  Axes a space lacks are skipped;
#: axes not named here run afterwards in space order.
DEFAULT_ORDER = ("partition", "n_chips", "tile_id", "trsm_seq_chips",
                 "flash_block_id", "flash_grid")


@dataclasses.dataclass
class BeamResult:
    """Top-k configs per dim plus the search's cost accounting.

    ``n_priced`` counts distinct (dim, config) cells the search
    *demanded* a price for — the cells a timing backend would have to
    measure to drive the same search.  The batched ``cost_fn`` call may
    vectorise over the full (dims x union) grid and discard the
    undemanded cells; that slack is free the way idle SIMD lanes are,
    and is not counted.
    """
    configs: list          # per dim: list of top_k GemmConfig
    costs: list            # per dim: list of top_k predicted times (s)
    n_priced: int          # distinct (dim, config) cells demanded
    n_space: int           # sum over dims of admissible space size
    width: int
    depth: int

    def best(self) -> list[GemmConfig]:
        return [cfgs[0] for cfgs in self.configs]

    @property
    def priced_fraction(self) -> float:
        return self.n_priced / max(self.n_space, 1)


def _default_cost_fn(spec, dtype_bytes):
    _spec = spec if spec is not None else TPUSpec()

    def cost_fn(dims, cfgs, routines):
        return estimate_batch_terms(dims, cfgs, _spec,
                                    dtype_bytes=dtype_bytes,
                                    routines=routines).total_s
    return cost_fn


def _space_cells(dims, space: ConfigSpace) -> int:
    """Sum of per-dim admissible space sizes (dims-aware gates make the
    size shape-dependent); memoised per distinct shape."""
    sizes: dict[tuple, int] = {}
    total = 0
    for d in dims:
        key = tuple(int(x) for x in d)
        if key not in sizes:
            sizes[key] = space.size(dims=key)
        total += sizes[key]
    return total


def beam_search(dims, space: ConfigSpace, cost_fn=None, width: int = 8,
                depth: int | None = None, *, routines=None, top_k: int = 1,
                spec: TPUSpec | None = None, dtype_bytes: int = 2,
                order=DEFAULT_ORDER) -> BeamResult:
    """Beam search each dim's best config(s) out of ``space``.

    ``cost_fn(dims, configs, routines) -> (D, C) array`` prices whole
    frontiers at once; ``None`` uses the noise-free analytic model.  One
    axis is refined per level (``depth`` defaults to all axes); partial
    states price as their canonical completion.  Returns ``top_k``
    configs per dim, cheapest first, ties in enumeration order.
    """
    dims = np.atleast_2d(np.asarray(dims, dtype=np.int64))
    n_dims = len(dims)
    if width < 1 or top_k < 1:
        raise ValueError(f"width={width} and top_k={top_k} must be >= 1")
    if cost_fn is None:
        cost_fn = _default_cost_fn(spec, dtype_bytes)
    n_levels = len(space.axes) if depth is None \
        else min(depth, len(space.axes))
    graphs = [SearchGraph(space, dims=d, order=order) for d in dims]
    frontiers: list[list[tuple]] = [[g.initial()] for g in graphs]

    priced: dict[GemmConfig, np.ndarray] = {}   # cfg -> (D,) cost column
    demanded: set[tuple[int, GemmConfig]] = set()
    for _level in range(n_levels):
        expansions: list[list[tuple]] = []      # per dim: (state, cfg, rank)
        for d in range(n_dims):
            g = graphs[d]
            rows = []
            for s in frontiers[d]:
                for v in g.actions(s):
                    s2 = g.apply(s, v)
                    try:
                        cfg = g.config(s2)
                    except ValueError:
                        continue   # branch admits no completion: dead end
                    rows.append((s2, cfg, space.rank_of(cfg)))
            if not rows:
                raise ValueError(
                    f"beam frontier went empty for dims {dims[d]!r} — "
                    "the space admits no completion (over-gated)")
            expansions.append(rows)

        new: list[GemmConfig] = []
        for d, rows in enumerate(expansions):
            for _, cfg, _ in rows:
                demanded.add((d, cfg))
                if cfg not in priced:
                    priced[cfg] = None  # reserve slot, keep first-seen order
                    new.append(cfg)
        if new:
            costs = np.asarray(cost_fn(dims, new, routines),
                               dtype=np.float64)
            for j, cfg in enumerate(new):
                priced[cfg] = costs[:, j]

        for d in range(n_dims):
            rows = sorted(expansions[d],
                          key=lambda r: (float(priced[r[1]][d]), r[2]))
            frontiers[d] = [s for s, _, _ in rows[:width]]

    configs: list[list[GemmConfig]] = []
    out_costs: list[list[float]] = []
    for d in range(n_dims):
        g = graphs[d]
        rows = sorted(((s, g.config(s)) for s in frontiers[d]),
                      key=lambda r: (float(priced[r[1]][d]),
                                     space.rank_of(r[1])))
        sel = rows[:top_k]
        configs.append([cfg for _, cfg in sel])
        out_costs.append([float(priced[cfg][d]) for _, cfg in sel])

    return BeamResult(configs, out_costs, len(demanded),
                      _space_cells(dims, space), width, n_levels)


def exhaustive_best(dims, space: ConfigSpace, cost_fn=None, *,
                    routines=None, top_k: int = 1,
                    spec: TPUSpec | None = None,
                    dtype_bytes: int = 2) -> BeamResult:
    """Price the whole space and argmin — the beam's ground truth.

    Same return shape as :func:`beam_search` (``width`` = the largest
    per-dim space, ``n_priced`` = every admissible cell), same
    first-occurrence tie-breaking as ``np.argmin`` over the enumeration.
    """
    dims = np.atleast_2d(np.asarray(dims, dtype=np.int64))
    if cost_fn is None:
        cost_fn = _default_cost_fn(spec, dtype_bytes)

    per_dim: list[list[GemmConfig]] = []
    union: list[GemmConfig] = []
    col: dict[GemmConfig, int] = {}
    cache: dict[tuple, list[GemmConfig]] = {}
    for d in dims:
        key = tuple(int(x) for x in d)
        if key not in cache:
            cache[key] = space.enumerate(dims=key)
        per_dim.append(cache[key])
        for cfg in cache[key]:
            if cfg not in col:
                col[cfg] = len(union)
                union.append(cfg)
    costs = np.asarray(cost_fn(dims, union, routines), dtype=np.float64)

    configs, out_costs, n_cells = [], [], 0
    for d, cfgs in enumerate(per_dim):
        n_cells += len(cfgs)
        row = costs[d, [col[c] for c in cfgs]]
        order = sorted(range(len(cfgs)), key=lambda i: (row[i], i))[:top_k]
        configs.append([cfgs[i] for i in order])
        out_costs.append([float(row[i]) for i in order])

    return BeamResult(configs, out_costs, int(costs.size), n_cells,
                      max(len(c) for c in per_dim), len(space.axes))
