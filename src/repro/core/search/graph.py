"""Action graph over a :class:`~repro.core.search.space.ConfigSpace`.

States are partial configs — tuples of ``(axis_name, value)`` pairs in
the order the policy assigned them.  Actions refine the next unassigned
axis with one of its gate-admissible values.  A state prices as its
*canonical completion* (space defaults / first-admissible fills), so a
whole frontier can be scored with one vectorised cost-model pass even
though most of its states are partial.

The expansion ``order`` is a policy choice, independent of the space's
canonical axis order: beam search refines ``partition`` before
``n_chips`` (four informative branches before the wide chip axis), while
tie-breaking and enumeration stay in canonical order so a full-width
beam still reproduces the exhaustive argmin exactly.
"""

from __future__ import annotations

from typing import Iterable

from repro.core.costmodel import GemmConfig
from repro.core.search.space import ConfigSpace

__all__ = ["SearchGraph"]

State = tuple  # tuple[tuple[str, object], ...]


class SearchGraph:
    def __init__(self, space: ConfigSpace, dims=None,
                 order: Iterable[str] | None = None):
        self.space = space
        self.dims = tuple(int(x) for x in dims) if dims is not None \
            else None
        names = [ax.name for ax in space.axes]
        if order is None:
            ordered = list(names)
        else:
            ordered = [nm for nm in order if nm in names]
            ordered += [nm for nm in names if nm not in ordered]
        self.order: tuple[str, ...] = tuple(ordered)
        self._axes = {ax.name: ax for ax in space.axes}

    def initial(self) -> State:
        return ()

    def is_complete(self, state: State) -> bool:
        return len(state) == len(self.order)

    def partial(self, state: State) -> dict:
        return dict(state)

    def actions(self, state: State) -> list:
        """Admissible values for the next unassigned axis (empty when
        the state is complete or over-gated)."""
        if self.is_complete(state):
            return []
        ax = self._axes[self.order[len(state)]]
        partial = dict(state)
        out = []
        for v in ax.values:
            trial = dict(partial)
            trial[ax.name] = v
            if self.space.check(trial, self.dims):
                out.append(v)
        return out

    def apply(self, state: State, value) -> State:
        return state + ((self.order[len(state)], value),)

    def config(self, state: State) -> GemmConfig:
        """The state's canonical completion — what the cost model prices."""
        return self.space.complete(dict(state), self.dims)
