"""Feature engineering for BLAS-3 runtime regression (paper Table II).

Group 1 (serial terms):   m, k, n, n_workers, m*k, m*n, k*n, m*k*n,
                          m*k + k*n + m*n
Group 2 (parallel terms): m/t, k/t, n/t, m*k/t, m*n/t, k*n/t, m*k*n/t,
                          (m*k + k*n + m*n)/t        with t = n_workers

On TPU the "worker" is a (submesh chips × kernel tile) configuration id;
the feature map receives the *chip count* as ``n_workers`` plus a tile
index — see DESIGN.md §Hardware adaptation.  The tile index enters as an
extra categorical-as-numeric column so the identical Table II structure
is preserved.

Routine extension (arXiv 2406.19621 analogue): when a ``routine_id`` is
given, six routine-aware columns are appended — a one-hot over
{syrk, trsm} (gemm is the all-zero baseline), the asymptotic flop scale
(gemm 1, syrk/trsm ½), the scaled work volume ``flops_scale * mkn`` and
its per-worker share, and a routine-specific aspect ratio (trsm: m/n,
the dependency-chain length per RHS column; syrk: k/m, update depth per
output row; gemm: 0).  ``routine_id=None`` emits the original 19-column
GEMM-only layout so models trained by pre-routine installations keep
receiving exactly the features they were fitted on.

Flash extension (the tuned-attention PR): when ``flash`` is also given
(the per-row ``(flash_bq, flash_bkv, flash_tri)`` config knobs), four
more columns append — a ``routine_attn`` one-hot and the three flash
knobs, zeroed on non-attn rows so gemm/syrk/trsm rows are bit-identical
to the 25-column layout plus zeros.  ``flash=None`` with a routine id
keeps emitting that 25-column layout (``ROUTINE_FEATURE_NAMES``) for
pre-flash artifacts; attn rows *require* flash knobs.  attn rides the
shared columns with m = Sq, k = head dim, n = Skv, ``seq_ratio`` = n/m
(KV length per query row — >1 on decode, 1 on square prefill).
"""

from __future__ import annotations

import numpy as np

from repro.core.costmodel import ROUTINES

__all__ = ["FEATURE_NAMES", "ROUTINE_FEATURE_NAMES", "LEGACY_FEATURE_NAMES",
           "ROUTINE_FLOP_SCALE",
           "build_features", "build_features_single"]

LEGACY_FEATURE_NAMES: list[str] = [
    # Group 1 — serial terms
    "m", "k", "n", "n_workers",
    "m*k", "m*n", "k*n", "m*k*n", "m*k+k*n+m*n",
    # Group 2 — parallel terms
    "m/t", "k/t", "n/t",
    "m*k/t", "m*n/t", "k*n/t", "m*k*n/t", "(m*k+k*n+m*n)/t",
    # TPU extension: kernel tile configuration id (0 when tuning chips only)
    # and the sharded-dimension id (0=M, 1=N, 2=K, 3=2D)
    "tile_id",
    "partition_id",
]

#: The 25-column BLAS-3 layout (generation 2) — what every pre-flash
#: routine-aware artifact was fitted on; still emitted by
#: ``build_features(..., flash=None)``.
ROUTINE_FEATURE_NAMES: list[str] = LEGACY_FEATURE_NAMES + [
    # BLAS-3 routine extension (gemm = all-zero one-hot baseline)
    "routine_syrk",
    "routine_trsm",
    "flops_scale",          # asymptotic flop ratio vs gemm: 1 / 0.5 / 0.5 / 1
    "mkn_scaled",           # flops_scale * m*k*n (routine-adjusted volume)
    "mkn_scaled/t",
    "seq_ratio",            # trsm: m/n; syrk: k/m; attn: n/m; gemm: 0
]

#: Generation 3: the flash-attention extension.  Appended at the end so
#: every generation-2 column keeps its index (test stubs and persisted
#: preprocess stats address columns positionally).
FEATURE_NAMES: list[str] = ROUTINE_FEATURE_NAMES + [
    "routine_attn",
    "flash_bq",             # flash (bq, bkv) block knobs; 0 off attn rows
    "flash_bkv",
    "flash_tri",            # 1 = block-sparse triangular KV grid
]

#: asymptotic flop count relative to a GEMM of the same (m, k, n).
#: attn is 4mkn (score + AV) x the causal 1/2 triangle = 2mkn == gemm.
ROUTINE_FLOP_SCALE: tuple[float, ...] = (1.0, 0.5, 0.5, 1.0)

assert len(ROUTINE_FLOP_SCALE) == len(ROUTINES)

_SYRK = ROUTINES.index("syrk")
_TRSM = ROUTINES.index("trsm")
_ATTN = ROUTINES.index("attn")


def build_features(m: np.ndarray, k: np.ndarray, n: np.ndarray,
                   n_workers: np.ndarray,
                   tile_id: np.ndarray | int = 0,
                   partition_id: np.ndarray | int = 0,
                   routine_id: np.ndarray | int | None = None,
                   flash: tuple | None = None
                   ) -> np.ndarray:
    """Vectorised Table II feature matrix.

    Three generations, selected by the optional arguments:
    ``routine_id=None`` — the legacy (N, 19) GEMM-only layout;
    ``routine_id`` given, ``flash=None`` — the (N, 25)
    ``ROUTINE_FEATURE_NAMES`` layout (attn rows are rejected: a
    pre-flash layout cannot express them);
    ``flash=(flash_bq, flash_bkv, flash_tri)`` (scalars or per-row
    arrays) — the full (N, len(FEATURE_NAMES)) layout.
    """
    m = np.asarray(m, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(n_workers, dtype=np.float64)
    tile = np.broadcast_to(np.asarray(tile_id, dtype=np.float64), m.shape)
    part = np.broadcast_to(np.asarray(partition_id, dtype=np.float64),
                           m.shape)

    mk = m * k
    mn = m * n
    kn = k * n
    mkn = m * k * n
    tot = mk + kn + mn

    cols = [
        m, k, n, t,
        mk, mn, kn, mkn, tot,
        m / t, k / t, n / t,
        mk / t, mn / t, kn / t, mkn / t, tot / t,
        tile,
        part,
    ]
    if routine_id is not None:
        rid = np.broadcast_to(
            np.asarray(routine_id, dtype=np.int64), m.shape)
        is_syrk = (rid == _SYRK).astype(np.float64)
        is_trsm = (rid == _TRSM).astype(np.float64)
        is_attn = (rid == _ATTN).astype(np.float64)
        scale = np.asarray(ROUTINE_FLOP_SCALE, dtype=np.float64)[rid]
        mkn_scaled = scale * mkn
        seq_ratio = is_trsm * (m / n) + is_syrk * (k / m) \
            + is_attn * (n / m)
        cols += [is_syrk, is_trsm, scale, mkn_scaled, mkn_scaled / t,
                 seq_ratio]
        if flash is None:
            if bool((rid == _ATTN).any()):
                raise ValueError(
                    "attn rows need flash=(flash_bq, flash_bkv, "
                    "flash_tri); the pre-flash 25-column layout cannot "
                    "express them")
        else:
            fbq, fbkv, ftri = (
                np.broadcast_to(np.asarray(f, dtype=np.float64), m.shape)
                for f in flash)
            # zeroed off attn rows: gemm/syrk/trsm rows stay bit-equal
            # to the generation-2 layout plus zero columns
            cols += [is_attn, is_attn * fbq, is_attn * fbkv,
                     is_attn * ftri]
    elif flash is not None:
        raise ValueError("flash knobs require routine_id")
    return np.stack(cols, axis=1)


def build_features_single(m: int, k: int, n: int, n_workers: int,
                          tile_id: int = 0,
                          partition_id: int = 0,
                          routine_id: int | None = None,
                          flash: tuple | None = None) -> np.ndarray:
    """(1, F) feature row for a single routine instance."""
    return build_features(np.array([m]), np.array([k]), np.array([n]),
                          np.array([n_workers]), np.array([tile_id]),
                          np.array([partition_id]),
                          None if routine_id is None
                          else np.array([routine_id]),
                          flash=flash)
