"""Feature engineering for GEMM runtime regression (paper Table II).

Group 1 (serial terms):   m, k, n, n_workers, m*k, m*n, k*n, m*k*n,
                          m*k + k*n + m*n
Group 2 (parallel terms): m/t, k/t, n/t, m*k/t, m*n/t, k*n/t, m*k*n/t,
                          (m*k + k*n + m*n)/t        with t = n_workers

On TPU the "worker" is a (submesh chips × kernel tile) configuration id;
the feature map receives the *chip count* as ``n_workers`` plus a tile
index — see DESIGN.md §Hardware adaptation.  The tile index enters as an
extra categorical-as-numeric column so the identical Table II structure
is preserved.
"""

from __future__ import annotations

import numpy as np

__all__ = ["FEATURE_NAMES", "build_features", "build_features_single"]

FEATURE_NAMES: list[str] = [
    # Group 1 — serial terms
    "m", "k", "n", "n_workers",
    "m*k", "m*n", "k*n", "m*k*n", "m*k+k*n+m*n",
    # Group 2 — parallel terms
    "m/t", "k/t", "n/t",
    "m*k/t", "m*n/t", "k*n/t", "m*k*n/t", "(m*k+k*n+m*n)/t",
    # TPU extension: kernel tile configuration id (0 when tuning chips only)
    # and the sharded-dimension id (0=M, 1=N, 2=K, 3=2D)
    "tile_id",
    "partition_id",
]


def build_features(m: np.ndarray, k: np.ndarray, n: np.ndarray,
                   n_workers: np.ndarray,
                   tile_id: np.ndarray | int = 0,
                   partition_id: np.ndarray | int = 0) -> np.ndarray:
    """Vectorised Table II feature matrix, shape (N, len(FEATURE_NAMES))."""
    m = np.asarray(m, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(n_workers, dtype=np.float64)
    tile = np.broadcast_to(np.asarray(tile_id, dtype=np.float64), m.shape)
    part = np.broadcast_to(np.asarray(partition_id, dtype=np.float64),
                           m.shape)

    mk = m * k
    mn = m * n
    kn = k * n
    mkn = m * k * n
    tot = mk + kn + mn

    cols = [
        m, k, n, t,
        mk, mn, kn, mkn, tot,
        m / t, k / t, n / t,
        mk / t, mn / t, kn / t, mkn / t, tot / t,
        tile,
        part,
    ]
    return np.stack(cols, axis=1)


def build_features_single(m: int, k: int, n: int, n_workers: int,
                          tile_id: int = 0,
                          partition_id: int = 0) -> np.ndarray:
    """(1, F) feature row for a single GEMM instance."""
    return build_features(np.array([m]), np.array([k]), np.array([n]),
                          np.array([n_workers]), np.array([tile_id]),
                          np.array([partition_id]))
