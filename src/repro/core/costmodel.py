"""Calibrated TPU v5e analytic performance model for distributed BLAS-3.

This is the install-time "timing program" of the paper (§III-B) for the
TPU target: the container is CPU-only, so routine timings at every
candidate worker configuration are produced by an analytic model of a
v5e pod instead of wall-clock measurement (DESIGN.md §Hardware
adaptation).  The model is intentionally *not* smooth: it contains wave
quantisation on the MXU grid, VMEM-overflow cliffs, ICI latency floors
and lognormal noise, so the learning problem retains the character of
the paper's measured data (skewed features, heteroscedastic noise,
non-obvious optimum).

Beyond plain GEMM the model covers the two BLAS-3 routines of the
follow-up paper (arXiv 2406.19621), interpreted on the shared (m, k, n)
triple:

  gemm — C[m,n] = A[m,k] @ B[k,n].  The baseline; unchanged.
  syrk — rank-k update writing only the lower triangle of C[m,n]
         (callers use m == n).  Computes the triangular fraction of the
         output tile grid, so its FLOPs are <= GEMM's for the same
         (m, k, n); output HBM traffic and the K-partition all-reduce
         shrink by the same triangular fraction.
  trsm — blocked substitution X[m,n] against a triangular A (k = update
         panel depth): half the multiply-adds of GEMM, triangular
         operand reads, and a *sequential dependency* along M — row
         panels retire in order, so at most TRSM_SEQ_CHIPS chips help on
         the M axis and every M-panel costs a dependent kernel launch.
  attn — causal flash attention on the (Sq, Dh, Skv) triple (m = query
         length, k = head dim, n = KV length; batch x heads is dispatch
         multiplicity, not part of the priced shape).  Score + AV FLOPs
         (4*m*k*n) at the causal triangular fraction of the flash tile
         grid; online softmax means Q and O stream exactly once and no
         (Sq, Skv) score matrix ever touches HBM.  The per-config flash
         knobs (``flash_block_id`` -> a (bq, bkv) FLASH_BLOCKS preset,
         ``flash_grid`` dense/tri) decide whether K/V blocks above the
         diagonal are still *streamed* (dense: skipped on the MXU via
         pl.when but every block is copied and every grid step launches)
         or never launched at all (tri: the block-sparse triangular
         grid) — that memory/launch gap is exactly what the tuner
         learns to price.

The same formulas (without noise) are reused by the roofline analysis —
keeping the tuner's world model and the §Roofline arithmetic consistent.

Hardware constants (per chip, TPU v5e):
  197 TFLOP/s bf16 peak · 819 GB/s HBM · ~50 GB/s/link ICI ·
  128 MB VMEM · MXU 128x128 systolic array.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

__all__ = [
    "TPUSpec", "GemmConfig", "TimeBreakdown", "BatchBreakdown",
    "candidate_configs", "chip_doublings", "config_arrays",
    "estimate_gemm_time",
    "estimate_routine_time", "estimate_batch_terms", "estimate_batch",
    "DEFAULT_TILES", "EXTENDED_TILES", "PARTITIONS",
    "ROUTINES", "DEFAULT_ROUTINE", "TRSM_SEQ_CHIPS",
    "FLASH_BLOCKS", "FLASH_GRIDS",
    "routine_ids",
]

#: Routines the stack understands; index = routine id feature.  The
#: first three are the BLAS-3 set (arXiv 2406.19621); ``attn`` is tuned
#: flash attention on the (Sq, Dh, Skv) triple.
ROUTINES: tuple[str, ...] = ("gemm", "syrk", "trsm", "attn")

#: The explicit default/fallback routine.  Call sites that don't tag a
#: routine dispatch as this, and tuners whose artifact lacks signal for
#: a requested routine fall back to it — always ROUTINES[0].
DEFAULT_ROUTINE: str = ROUTINES[0]

#: Default depth of TRSM's substitution pipeline along the sequential
#: (M) dimension: at most this many chips help on that axis; the rest
#: idle waiting on their predecessors' panels.  Since the search-space
#: refactor this is a *per-config knob* (``GemmConfig.trsm_seq_chips``,
#: an axis of the enlarged :class:`~repro.core.search.ConfigSpace`);
#: this constant is the historical default every pre-search config
#: carries.
TRSM_SEQ_CHIPS = 4

#: Flash-attention (bq, bkv) block presets; index = the
#: ``GemmConfig.flash_block_id`` knob.  Id 0 is the historical
#: hardcoded kernel block, so default-constructed configs (and every
#: persisted pre-flash artifact) keep meaning exactly what they meant.
FLASH_BLOCKS: tuple[tuple[int, int], ...] = (
    (512, 512),
    (256, 512),
    (512, 256),
    (256, 256),
    (1024, 512),
    (128, 512),
)

#: Flash KV-grid kinds: ``dense`` launches the full (gq x gkv) grid and
#: skips masked tiles on the MXU only; ``tri`` is the block-sparse
#: triangular grid that never launches (or streams) a fully-masked tile.
FLASH_GRIDS: tuple[str, ...] = ("dense", "tri")


def routine_ids(routines, n: int) -> np.ndarray:
    """Normalise a routine argument to an (n,) int array of ROUTINES ids.

    Accepts ``None`` (all gemm), a single routine name or id, or a
    sequence of names/ids with one entry per dim.
    """
    if routines is None:
        return np.zeros(n, dtype=np.int64)
    if isinstance(routines, str):
        return np.full(n, _routine_id(routines), dtype=np.int64)
    if isinstance(routines, (int, np.integer)):
        return np.full(n, _routine_id(routines), dtype=np.int64)
    ids = np.asarray([_routine_id(r) for r in routines], dtype=np.int64)
    if len(ids) != n:
        raise ValueError(
            f"got {len(ids)} routines for {n} dims; pass one per dim "
            "(or a single routine for the whole batch)")
    return ids


def _routine_id(routine) -> int:
    if isinstance(routine, (int, np.integer)):
        if not 0 <= int(routine) < len(ROUTINES):
            raise ValueError(f"unknown routine id {routine!r}; "
                             f"expected 0..{len(ROUTINES) - 1}")
        return int(routine)
    try:
        return ROUTINES.index(routine)
    except ValueError:
        raise ValueError(f"unknown routine {routine!r}; "
                         f"expected one of {ROUTINES}") from None


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    ici_links: int = 4                  # links per chip (2D torus)
    vmem_bytes: int = 128 * 2**20
    mxu_dim: int = 128                  # systolic array edge
    launch_overhead_s: float = 2e-6       # per kernel launch
    collective_latency_s: float = 0.2e-6  # ICI per-hop latency
    collective_dispatch_s: float = 5e-6   # software cost per collective
    #: per-grid-step overhead of the flash attention pipeline (DMA issue
    #: + sequential-axis bookkeeping); what the triangular grid saves on
    #: top of K/V traffic by never launching masked tiles
    flash_step_s: float = 0.2e-6
    max_chips: int = 512

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw * self.ici_links


# Kernel tile presets (bm, bk, bn).  Index = "tile_id" feature.
DEFAULT_TILES: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (256, 128, 256),
    (128, 512, 128),
    (256, 256, 256),
    (512, 128, 512),
    (512, 512, 512),
    (128, 128, 512),
    (512, 128, 128),
)

#: DEFAULT_TILES plus the presets only reachable through an explicitly
#: enlarged search space (``ConfigSpace.enlarged``).  The classic ids
#: 0..7 are unchanged, so every pre-search artifact / candidate list
#: keeps meaning exactly what it meant; ``candidate_configs`` defaults
#: stay on DEFAULT_TILES for bit-for-bit compatibility.
EXTENDED_TILES: tuple[tuple[int, int, int], ...] = DEFAULT_TILES + (
    (256, 512, 256),
    (512, 256, 512),
    (128, 256, 128),
    (1024, 128, 128),
)

PARTITIONS = ("M", "N", "K", "2D")
_PARTITIONS = PARTITIONS          # pre-refactor private alias


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """One candidate worker configuration = the paper's 'thread count'.

    n_chips        — submesh size the GEMM is dispatched on (1..512)
    partition      — which GEMM dimension(s) the submesh shards
    tile_id        — index into EXTENDED_TILES for the per-chip Pallas
                     kernel (ids 0..7 are the classic DEFAULT_TILES)
    trsm_seq_chips — TRSM substitution-pipeline depth: how many chips the
                     kernel lets cooperate along the sequential M axis.
                     Ignored by gemm/syrk.  Defaults to the historical
                     constant so three-argument construction (and every
                     persisted artifact) keeps its exact old meaning.
    flash_block_id — index into FLASH_BLOCKS for the attention kernel's
                     (bq, bkv) split.  Ignored by gemm/syrk/trsm.
    flash_grid     — flash KV-grid kind, "dense" or "tri" (block-sparse
                     triangular).  Both flash knobs default to the
                     pre-flash kernel behaviour (512x512 dense) so every
                     persisted artifact round-trips unchanged.
    """
    n_chips: int
    partition: str
    tile_id: int
    trsm_seq_chips: int = TRSM_SEQ_CHIPS
    flash_block_id: int = 0
    flash_grid: str = "dense"

    @property
    def tile(self) -> tuple[int, int, int]:
        return EXTENDED_TILES[self.tile_id]

    @property
    def flash_block(self) -> tuple[int, int]:
        """The attention kernel's (bq, bkv) block split."""
        return FLASH_BLOCKS[self.flash_block_id]

    @property
    def config_id(self) -> int:
        """Stable integer id (used for memoisation / logging).  Flash
        knobs at their defaults contribute 0, preserving every
        historical id."""
        return ((self.tile_id * len(_PARTITIONS)
                 + _PARTITIONS.index(self.partition)) * 64
                + self.trsm_seq_chips) * 1024 + self.n_chips \
            + ((self.flash_block_id * len(FLASH_GRIDS)
                + FLASH_GRIDS.index(self.flash_grid)) << 22)


@dataclasses.dataclass
class TimeBreakdown:
    """Per-term decomposition, mirroring the paper's Table VII columns:
    kernel-call (compute), data-copy (memory), thread-sync (collective)."""
    compute_s: float
    memory_s: float
    collective_s: float
    launch_s: float

    @property
    def total_s(self) -> float:
        # compute and HBM traffic overlap inside the kernel (systolic
        # pipeline); collectives + launches serialise with the kernel.
        return max(self.compute_s, self.memory_s) + self.collective_s \
            + self.launch_s


def chip_doublings(max_chips: int) -> list[int]:
    """Power-of-two chip counts up to ``max_chips``: ``[1, 2, 4, ...]``.

    Non-power-of-two values are truncated down to the largest power of
    two ``<= max_chips`` (``6 -> [1, 2, 4]``) — the behaviour the install
    grid has always had, now documented instead of silent.  ``max_chips``
    must be a positive integer; the historical ``int(math.log2(...))``
    raised a bare ``ValueError: math domain error`` on ``max_chips <= 0``.
    """
    if isinstance(max_chips, bool) or not isinstance(
            max_chips, (int, np.integer)):
        raise ValueError(
            f"max_chips must be an integer, got {max_chips!r}")
    if max_chips < 1:
        raise ValueError(f"max_chips must be >= 1, got {max_chips}")
    return [2 ** i for i in range(int(max_chips).bit_length())]


def candidate_configs(max_chips: int = 512, *,
                      tiles: Iterable[int] | None = None,
                      partitions: Iterable[str] = _PARTITIONS
                      ) -> list[GemmConfig]:
    """The candidate set the tuner argmins over (paper: 1..n_cores).

    Since the search refactor this is a thin exhaustive enumeration of
    the *default* :class:`~repro.core.search.ConfigSpace` — bit-for-bit
    the list the historical triple loop produced (chip doublings outer,
    then partitions with the 2D >= 4-chip gate, then tiles).  Callers
    wanting a larger space (extended tiles, 3*2^k chip counts, the TRSM
    pipeline knob) build a space explicitly and search it instead of
    enumerating.
    """
    from repro.core.search.space import ConfigSpace  # lazy: avoid cycle
    return ConfigSpace.default(max_chips, tiles=tiles,
                               partitions=partitions).enumerate()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _local_shape(m: int, k: int, n: int, cfg: GemmConfig,
                 routine: str = "gemm") -> tuple[int, int, int]:
    """Per-chip problem extents under the chosen partitioning.

    TRSM's substitution dependency runs along M: sharding M (directly or
    via 2D) only helps up to ``cfg.trsm_seq_chips`` chips (the config's
    pipeline-depth knob; default = the historical constant) — the rest
    wait on their predecessors' panels.
    """
    p = cfg.n_chips
    if cfg.partition == "M":
        pm = min(p, cfg.trsm_seq_chips) if routine == "trsm" else p
        return _ceil_div(m, pm), k, n
    if cfg.partition == "N":
        return m, k, _ceil_div(n, p)
    if cfg.partition == "K":
        return m, _ceil_div(k, p), n
    # 2D: factor p into the two most square factors, shard M x N
    pm = 2 ** (int(math.log2(p)) // 2)
    pn = p // pm
    if routine == "trsm":
        pm = min(pm, cfg.trsm_seq_chips)
    return _ceil_div(m, pm), k, _ceil_div(n, pn)


def _collective_bytes(m: int, k: int, n: int, cfg: GemmConfig,
                      dtype_bytes: int, routine: str = "gemm"
                      ) -> tuple[float, int]:
    """(bytes per chip moved over ICI, number of collective phases).

    Routine-aware: SYRK's K-partition all-reduce carries only the
    triangular half of C; TRSM's 2D rings use the dependency-capped M
    factor (idle chips gather nothing extra).
    """
    p = cfg.n_chips
    if p == 1:
        return 0.0, 0
    frac = (p - 1) / p
    if cfg.partition == "M":      # all-gather B
        return frac * k * n * dtype_bytes, 1
    if cfg.partition == "N":      # all-gather A
        return frac * m * k * dtype_bytes, 1
    if cfg.partition == "K":      # all-reduce partial C (2x traffic)
        coll = 2.0 * frac * m * n * dtype_bytes
        if routine == "syrk":     # only the triangle is reduced
            coll = coll * 0.5
        return coll, 2
    # 2D: all-gather A along pn ring, B along pm ring
    pm = 2 ** (int(math.log2(p)) // 2)
    pn = p // pm
    if routine == "trsm":
        pm = min(pm, cfg.trsm_seq_chips)
    bytes_a = (pn - 1) / pn * (m // max(pm, 1)) * k * dtype_bytes
    bytes_b = (pm - 1) / pm * k * (n // max(pn, 1)) * dtype_bytes
    return bytes_a + bytes_b, 2


def estimate_gemm_time(m: int, k: int, n: int, cfg: GemmConfig,
                       spec: TPUSpec = TPUSpec(), *,
                       dtype_bytes: int = 2,
                       rng: np.random.Generator | None = None
                       ) -> TimeBreakdown:
    """Analytic runtime of C[m,n] = A[m,k] @ B[k,n] under ``cfg``.

    The GEMM specialisation of :func:`estimate_routine_time` (identical
    arithmetic — the routine branches are no-ops for gemm).
    """
    return estimate_routine_time(m, k, n, cfg, spec, routine="gemm",
                                 dtype_bytes=dtype_bytes, rng=rng)


def estimate_routine_time(m: int, k: int, n: int, cfg: GemmConfig,
                          spec: TPUSpec = TPUSpec(), *,
                          routine: str = "gemm",
                          dtype_bytes: int = 2,
                          rng: np.random.Generator | None = None
                          ) -> TimeBreakdown:
    """Analytic runtime of one BLAS-3 routine call under ``cfg``.

    Terms:
      compute    — wave-quantised MXU time for the per-chip tile grid
                   (SYRK: triangular fraction of the output grid; TRSM:
                   half the multiply-adds)
      memory     — HBM traffic incl. tile re-reads (SYRK writes/re-reads
                   only triangular C tiles; TRSM reads a triangular A)
      collective — ICI ring time + per-hop latency floor (routine-aware,
                   see :func:`_collective_bytes`)
      launch     — per-kernel-invocation overhead; TRSM multiplies by the
                   M-panel dependency chain (panels retire sequentially)
    Noise (rng given): multiplicative lognormal + rare straggler spikes.

    This scalar path is the bit-for-bit reference for the vectorised
    :func:`estimate_batch_terms`.
    """
    routine = ROUTINES[_routine_id(routine)]
    lm, lk, ln = _local_shape(m, k, n, cfg, routine)
    if routine == "attn":
        # flash attention blocks along (Sq, Skv); the head dim (k) is
        # resident in VMEM, never tiled
        fbq, fbkv = cfg.flash_block
        bm, bk, bn = min(fbq, _pad(lm)), _pad(lk), min(fbkv, _pad(ln))
    else:
        bm, bk, bn = cfg.tile
        bm, bk, bn = min(bm, _pad(lm)), min(bk, _pad(lk)), min(bn, _pad(ln))

    gm, gk, gn = _ceil_div(lm, bm), _ceil_div(lk, bk), _ceil_div(ln, bn)

    # triangular fraction of the local output tile grid: the share of
    # (gm x gn) tiles a lower-triangular output actually touches.  Exact
    # for square grids (g(g+1)/2 tiles); <= 1 always, -> 1/2 as the grid
    # grows, == 1 for a single tile.
    tri_frac = 0.5 * (1.0 + 1.0 / max(gm, gn))

    # flash grid fraction: share of the (gm x gn) KV grid the kernel
    # actually *launches* — the dense grid streams every block and skips
    # masked MXU work via pl.when; the triangular grid never launches
    # above-diagonal tiles, so K/V traffic and step overhead shrink too
    grid_frac = tri_frac if cfg.flash_grid != "dense" else 1.0

    # ---- compute: padded-tile FLOPs at MXU efficiency --------------------
    mxu = spec.mxu_dim
    eff_m = bm / (_ceil_div(bm, mxu) * mxu)
    eff_n = bn / (_ceil_div(bn, mxu) * mxu)
    # sub-128 K still fills the pipeline after warmup; mild penalty
    eff_k = min(1.0, (bk + 16) / mxu) if bk < mxu else 1.0
    mxu_eff = max(eff_m * eff_n * min(eff_k, 1.0), 0.02)
    flops = 2.0 * (gm * bm) * (gk * bk) * (gn * bn)
    if routine == "syrk":
        flops = flops * tri_frac
    elif routine == "trsm":       # substitution: half the multiply-adds
        flops = flops * 0.5
    elif routine == "attn":       # score + AV matmuls, causal triangle
        # (MXU work is triangular on *both* grids — dense skips masked
        # tiles via pl.when; only traffic/launches differ)
        flops = flops * 2.0 * tri_frac
    compute_s = flops / (spec.peak_flops * mxu_eff)

    # ---- memory: blocked HBM traffic -------------------------------------
    bytes_a = lm * lk * gn * dtype_bytes          # A re-read per N block col
    bytes_b = lk * ln * gm * dtype_bytes          # B re-read per M block row
    bytes_c = lm * ln * (dtype_bytes + 2 * dtype_bytes * (gk - 1))
    if routine == "syrk":         # only triangular C tiles written/re-read
        bytes_c = bytes_c * tri_frac
    elif routine == "trsm":       # triangular operand panel reads
        bytes_a = bytes_a * 0.5
    elif routine == "attn":
        # online softmax: Q streams exactly once (resident across its KV
        # loop), K *and* V stream once per launched Q row (grid_frac of
        # the dense re-read), and the output O[m, k] is written once —
        # no (Sq, Skv) score matrix ever touches HBM
        bytes_a = lm * lk * dtype_bytes
        bytes_b = lk * ln * gm * (2 * dtype_bytes) * grid_frac
        bytes_c = lm * lk * dtype_bytes
    # VMEM overflow cliff: working set beyond VMEM spills accumulators
    working = (bm * bk + bk * bn + bm * bn) * dtype_bytes * 2  # dbl buffer
    spill = 1.0 if working <= spec.vmem_bytes else 4.0
    memory_s = spill * (bytes_a + bytes_b + bytes_c) / spec.hbm_bw

    # ---- collective: ring bandwidth + latency floor -----------------------
    coll_bytes, phases = _collective_bytes(m, k, n, cfg, dtype_bytes,
                                           routine)
    hops = max(cfg.n_chips - 1, 0)
    collective_s = (coll_bytes / spec.ici_bw_total
                    + phases * (hops * spec.collective_latency_s
                                + spec.collective_dispatch_s))

    launch_s = spec.launch_overhead_s * max(1.0, math.log2(cfg.n_chips + 1))
    if routine == "trsm":
        # dependency chain: every global M panel is a dependent launch
        launch_s = launch_s * _ceil_div(m, bm)
    elif routine == "attn":
        # per-grid-step pipeline overhead: the triangular grid pays it
        # only for launched (below-diagonal) tiles
        launch_s = launch_s + spec.flash_step_s * (gm * gn * grid_frac)

    tb = TimeBreakdown(compute_s, memory_s, collective_s, launch_s)
    if rng is not None:
        jitter = float(np.exp(rng.normal(0.0, 0.05)))
        straggler = 1.0
        if cfg.n_chips > 1 and rng.random() < 0.01:   # rare straggler
            straggler = 1.0 + float(rng.exponential(0.5))
        tb = TimeBreakdown(compute_s * jitter, memory_s * jitter,
                           collective_s * jitter * straggler, launch_s)
    return tb


def _pad(x: int) -> int:
    """Round up to the sublane multiple (8) so tiny dims stay legal."""
    return max(8, _ceil_div(x, 8) * 8)


@dataclasses.dataclass
class BatchBreakdown:
    """Vectorised :class:`TimeBreakdown`: each term is a (D, C) array over
    the dims x configs grid.  ``total_s`` applies the same overlap rule as
    the scalar path (compute/HBM overlap; collectives + launches serialise).
    """
    compute_s: np.ndarray
    memory_s: np.ndarray
    collective_s: np.ndarray
    launch_s: np.ndarray

    @property
    def total_s(self) -> np.ndarray:
        return np.maximum(self.compute_s, self.memory_s) \
            + self.collective_s + self.launch_s


def config_arrays(cfgs: list[GemmConfig]) -> dict[str, np.ndarray]:
    """Columnar view of a candidate set, shape (C,) per field."""
    tiles = np.asarray([c.tile for c in cfgs], dtype=np.int64)
    fblocks = np.asarray([c.flash_block for c in cfgs], dtype=np.int64)
    return {
        "n_chips": np.asarray([c.n_chips for c in cfgs], dtype=np.int64),
        "partition": np.asarray(
            [_PARTITIONS.index(c.partition) for c in cfgs], dtype=np.int64),
        "tile_id": np.asarray([c.tile_id for c in cfgs], dtype=np.int64),
        "trsm_seq_chips": np.asarray(
            [c.trsm_seq_chips for c in cfgs], dtype=np.int64),
        "bm": tiles[:, 0], "bk": tiles[:, 1], "bn": tiles[:, 2],
        "flash_block_id": np.asarray(
            [c.flash_block_id for c in cfgs], dtype=np.int64),
        "flash_bq": fblocks[:, 0], "flash_bkv": fblocks[:, 1],
        "flash_tri": np.asarray(
            [c.flash_grid != "dense" for c in cfgs], dtype=np.int64),
    }


def _ceil_div_f(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact ceil-division on float64-held integers.

    For integer-valued float64 operands with ``a < 2**53`` the IEEE
    quotient cannot cross an integer boundary (the gap to the nearest
    integer is >= 1/b while the rounding error is < (a/b) * 2**-53), so
    ``ceil(a / b)`` equals exact integer ceil-division — while the float
    division vectorises ~6x faster than int64 ``//``.
    """
    return np.ceil(a / b)


def _pad_f(x: np.ndarray) -> np.ndarray:
    return np.maximum(8.0, _ceil_div_f(x, 8.0) * 8.0)


def estimate_batch_terms(dims: np.ndarray, cfgs: list[GemmConfig],
                         spec: TPUSpec = TPUSpec(), *,
                         dtype_bytes: int = 2,
                         rng: np.random.Generator | None = None,
                         routines=None) -> BatchBreakdown:
    """Vectorised :func:`estimate_routine_time` over a (dims x configs)
    grid.

    One broadcasted NumPy pass instead of ``D * C`` scalar calls — the
    install-time "timing program" hot path.  ``routines`` is ``None``
    (all gemm), a single routine name, or one name/id per dim — rows of
    the grid may mix routines freely.  Noise-free output matches the
    scalar path bit-for-bit for every routine (each term applies the
    identical sequence of IEEE operations elementwise; routine
    multipliers are either exact power-of-two scalings or the same
    float64 products in the same order).  With ``rng`` the noise model is
    the same lognormal jitter + rare straggler spikes, drawn as (D, C)
    blocks (the draw order differs from the scalar loop, so noisy values
    match in distribution, not bitwise).
    """
    dims = np.atleast_2d(np.asarray(dims, dtype=np.int64))
    m = dims[:, 0:1].astype(np.float64)   # (D, 1) — broadcast against (C,)
    k = dims[:, 1:2].astype(np.float64)
    n = dims[:, 2:3].astype(np.float64)
    rids = routine_ids(routines, len(dims))
    is_syrk_d = (rids == ROUTINES.index("syrk"))[:, None]     # (D, 1)
    is_trsm_d = (rids == ROUTINES.index("trsm"))[:, None]
    is_attn_d = (rids == ROUTINES.index("attn"))[:, None]
    any_syrk = bool(is_syrk_d.any())
    any_trsm = bool(is_trsm_d.any())
    any_attn = bool(is_attn_d.any())
    ca = config_arrays(cfgs)

    # Local shapes, collectives and launch cost are tile-independent, so
    # compute them once per unique (n_chips, partition, trsm_seq_chips)
    # triple — typically ~8x fewer columns than the full candidate set —
    # and gather back to (D, C) by index afterwards.  (Routine only
    # varies along D, so the dedup over config columns survives the
    # routine axis.)
    max_seq = int(ca["trsm_seq_chips"].max())
    pp_keys = (ca["partition"] * (int(ca["n_chips"].max()) + 1)
               + ca["n_chips"]) * (max_seq + 1) + ca["trsm_seq_chips"]
    _, uniq_idx, inv = np.unique(pp_keys, return_index=True,
                                 return_inverse=True)
    p = ca["n_chips"][None, uniq_idx].astype(np.float64)    # (1, U)
    part = ca["partition"][None, uniq_idx]
    seq = ca["trsm_seq_chips"][None, uniq_idx].astype(np.float64)

    # ---- local shapes under each partitioning ----------------------------
    # 2D factorisation: p -> (pm, pn), the two most square power factors.
    pm2d = 2.0 ** np.floor(np.floor(np.log2(p)) / 2.0)
    pn2d = np.floor(p / pm2d)
    is_m = part == _PARTITIONS.index("M")
    is_n = part == _PARTITIONS.index("N")
    is_k = part == _PARTITIONS.index("K")
    is_2d = part == _PARTITIONS.index("2D")

    # TRSM: at most trsm_seq_chips chips help along the sequential M axis
    # (per-config pipeline-depth knob; every classic config carries the
    # historical default)
    if any_trsm:
        p_m = np.where(is_trsm_d, np.minimum(p, seq), p)
        pm2d_eff = np.where(is_trsm_d, np.minimum(pm2d, seq), pm2d)
    else:
        p_m, pm2d_eff = p, pm2d

    lm = np.where(is_m, _ceil_div_f(m, p_m),
                  np.where(is_2d, _ceil_div_f(m, pm2d_eff), m))  # (D, U)
    lk = np.where(is_k, _ceil_div_f(k, p), k)
    ln = np.where(is_n, _ceil_div_f(n, p),
                  np.where(is_2d, _ceil_div_f(n, pn2d), n))

    # ---- tile clamped to the (padded) local problem ----------------------
    pad_m, pad_k, pad_n = _pad_f(lm), _pad_f(lk), _pad_f(ln)
    lm, lk, ln = lm[:, inv], lk[:, inv], ln[:, inv]           # (D, C)
    bm = np.minimum(ca["bm"][None, :], pad_m[:, inv])
    bk = np.minimum(ca["bk"][None, :], pad_k[:, inv])
    bn = np.minimum(ca["bn"][None, :], pad_n[:, inv])
    if any_attn:
        # attn rows block along (Sq, Skv) via the config's flash preset;
        # the head dim is VMEM-resident, never tiled (see scalar path)
        bm = np.where(is_attn_d,
                      np.minimum(ca["flash_bq"][None, :], pad_m[:, inv]), bm)
        bk = np.where(is_attn_d, pad_k[:, inv], bk)
        bn = np.where(is_attn_d,
                      np.minimum(ca["flash_bkv"][None, :], pad_n[:, inv]), bn)
    gm = _ceil_div_f(lm, bm)
    gk = _ceil_div_f(lk, bk)
    gn = _ceil_div_f(ln, bn)

    # triangular fraction of the local output tile grid (see scalar path)
    tri_frac = 0.5 * (1.0 + 1.0 / np.maximum(gm, gn))
    if any_attn:
        # launched share of the flash KV grid (1.0 on the dense grid)
        grid_frac = np.where(ca["flash_tri"][None, :] == 1, tri_frac, 1.0)

    # ---- compute: padded-tile FLOPs at wave-quantised MXU efficiency -----
    mxu = float(spec.mxu_dim)
    eff_m = bm / (_ceil_div_f(bm, mxu) * mxu)
    eff_n = bn / (_ceil_div_f(bn, mxu) * mxu)
    eff_k = np.where(bk < mxu, np.minimum(1.0, (bk + 16) / mxu), 1.0)
    mxu_eff = np.maximum(eff_m * eff_n * np.minimum(eff_k, 1.0), 0.02)
    flops = 2.0 * (gm * bm) * (gk * bk) * (gn * bn)
    if any_syrk:
        flops = np.where(is_syrk_d, flops * tri_frac, flops)
    if any_trsm:
        flops = np.where(is_trsm_d, flops * 0.5, flops)
    if any_attn:
        flops = np.where(is_attn_d, flops * 2.0 * tri_frac, flops)
    compute_s = flops / (spec.peak_flops * mxu_eff)

    # ---- memory: blocked HBM traffic with VMEM-spill cliff ---------------
    bytes_a = lm * lk * gn * dtype_bytes
    bytes_b = lk * ln * gm * dtype_bytes
    bytes_c = lm * ln * (dtype_bytes + 2 * dtype_bytes * (gk - 1))
    if any_syrk:
        bytes_c = np.where(is_syrk_d, bytes_c * tri_frac, bytes_c)
    if any_trsm:
        bytes_a = np.where(is_trsm_d, bytes_a * 0.5, bytes_a)
    if any_attn:                  # online softmax (see scalar path)
        bytes_a = np.where(is_attn_d, lm * lk * dtype_bytes, bytes_a)
        bytes_b = np.where(is_attn_d,
                           lk * ln * gm * (2 * dtype_bytes) * grid_frac,
                           bytes_b)
        bytes_c = np.where(is_attn_d, lm * lk * dtype_bytes, bytes_c)
    working = (bm * bk + bk * bn + bm * bn) * dtype_bytes * 2
    spill = np.where(working <= spec.vmem_bytes, 1.0, 4.0)
    memory_s = spill * (bytes_a + bytes_b + bytes_c) / spec.hbm_bw

    # ---- collective: ring bandwidth + latency floor (per (p, part)) ------
    frac = (p - 1) / p
    coll_k = 2.0 * frac * m * n * dtype_bytes
    if any_syrk:                  # SYRK all-reduces only the triangle
        coll_k = np.where(is_syrk_d, coll_k * 0.5, coll_k)
    coll_bytes = np.where(
        is_m, frac * k * n * dtype_bytes,
        np.where(is_n, frac * m * k * dtype_bytes,
                 np.where(is_k, coll_k,
                          (pn2d - 1) / pn2d
                          * (m // np.maximum(pm2d_eff, 1)) * k * dtype_bytes
                          + (pm2d_eff - 1) / pm2d_eff
                          * k * (n // np.maximum(pn2d, 1)) * dtype_bytes)))
    phases = np.where(is_m | is_n, 1, 2)
    coll_bytes = np.where(p == 1, 0.0, coll_bytes)
    phases = np.where(p == 1, 0, phases)
    hops = np.maximum(p - 1, 0)
    collective_s = (coll_bytes / spec.ici_bw_total
                    + phases * (hops * spec.collective_latency_s
                                + spec.collective_dispatch_s))[:, inv]

    launch_s = spec.launch_overhead_s * np.maximum(1.0, np.log2(p + 1))
    launch_s = np.broadcast_to(launch_s[:, inv],
                               compute_s.shape).copy()
    if any_trsm:                  # dependent launch per global M panel
        launch_s = np.where(is_trsm_d, launch_s * _ceil_div_f(m, bm),
                            launch_s)
    if any_attn:                  # per-grid-step overhead, launched tiles
        launch_s = np.where(
            is_attn_d,
            launch_s + spec.flash_step_s * (gm * gn * grid_frac), launch_s)

    if rng is not None:
        jitter = np.exp(rng.normal(0.0, 0.05, size=compute_s.shape))
        straggler = np.where(
            (ca["n_chips"][None, :] > 1)
            & (rng.random(size=compute_s.shape) < 0.01),
            1.0 + rng.exponential(0.5, size=compute_s.shape), 1.0)
        return BatchBreakdown(compute_s * jitter, memory_s * jitter,
                              collective_s * jitter * straggler, launch_s)
    return BatchBreakdown(compute_s, memory_s, collective_s, launch_s)


def estimate_batch(dims: np.ndarray, cfgs: list[GemmConfig],
                   spec: TPUSpec = TPUSpec(), *, dtype_bytes: int = 2,
                   seed: int | None = 0, routines=None) -> np.ndarray:
    """Runtime matrix, shape (len(dims), len(cfgs)); noisy if seed given.

    Vectorised: one broadcasted pass over the whole grid (see
    :func:`estimate_batch_terms`) instead of the historical D*C scalar
    loop — ~2 orders of magnitude faster at install-scale grids.
    ``routines`` (None, one name, or one per dim) selects the BLAS-3
    routine each row of the grid is timed as.
    """
    rng = np.random.default_rng(seed) if seed is not None else None
    return estimate_batch_terms(dims, cfgs, spec, dtype_bytes=dtype_bytes,
                                rng=rng, routines=routines).total_s
