"""Calibrated TPU v5e analytic performance model for distributed GEMM.

This is the install-time "timing program" of the paper (§III-B) for the
TPU target: the container is CPU-only, so GEMM timings at every candidate
worker configuration are produced by an analytic model of a v5e pod
instead of wall-clock measurement (DESIGN.md §Hardware adaptation).  The
model is intentionally *not* smooth: it contains wave quantisation on the
MXU grid, VMEM-overflow cliffs, ICI latency floors and lognormal noise,
so the learning problem retains the character of the paper's measured
data (skewed features, heteroscedastic noise, non-obvious optimum).

The same formulas (without noise) are reused by the roofline analysis —
keeping the tuner's world model and the §Roofline arithmetic consistent.

Hardware constants (per chip, TPU v5e):
  197 TFLOP/s bf16 peak · 819 GB/s HBM · ~50 GB/s/link ICI ·
  128 MB VMEM · MXU 128x128 systolic array.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

__all__ = [
    "TPUSpec", "GemmConfig", "TimeBreakdown", "candidate_configs",
    "estimate_gemm_time", "estimate_batch", "DEFAULT_TILES",
]


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    ici_links: int = 4                  # links per chip (2D torus)
    vmem_bytes: int = 128 * 2**20
    mxu_dim: int = 128                  # systolic array edge
    launch_overhead_s: float = 2e-6       # per kernel launch
    collective_latency_s: float = 0.2e-6  # ICI per-hop latency
    collective_dispatch_s: float = 5e-6   # software cost per collective
    max_chips: int = 512

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw * self.ici_links


# Kernel tile presets (bm, bk, bn).  Index = "tile_id" feature.
DEFAULT_TILES: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (256, 128, 256),
    (128, 512, 128),
    (256, 256, 256),
    (512, 128, 512),
    (512, 512, 512),
    (128, 128, 512),
    (512, 128, 128),
)

_PARTITIONS = ("M", "N", "K", "2D")


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """One candidate worker configuration = the paper's 'thread count'.

    n_chips   — submesh size the GEMM is dispatched on (1..512)
    partition — which GEMM dimension(s) the submesh shards
    tile_id   — index into DEFAULT_TILES for the per-chip Pallas kernel
    """
    n_chips: int
    partition: str
    tile_id: int

    @property
    def tile(self) -> tuple[int, int, int]:
        return DEFAULT_TILES[self.tile_id]

    @property
    def config_id(self) -> int:
        """Stable integer id (used for memoisation / logging)."""
        return (self.tile_id * len(_PARTITIONS)
                + _PARTITIONS.index(self.partition)) * 1024 + self.n_chips


@dataclasses.dataclass
class TimeBreakdown:
    """Per-term decomposition, mirroring the paper's Table VII columns:
    kernel-call (compute), data-copy (memory), thread-sync (collective)."""
    compute_s: float
    memory_s: float
    collective_s: float
    launch_s: float

    @property
    def total_s(self) -> float:
        # compute and HBM traffic overlap inside the kernel (systolic
        # pipeline); collectives + launches serialise with the kernel.
        return max(self.compute_s, self.memory_s) + self.collective_s \
            + self.launch_s


def candidate_configs(max_chips: int = 512, *,
                      tiles: Iterable[int] | None = None,
                      partitions: Iterable[str] = _PARTITIONS
                      ) -> list[GemmConfig]:
    """The candidate set the tuner argmins over (paper: 1..n_cores)."""
    chips = [2 ** i for i in range(int(math.log2(max_chips)) + 1)]
    tile_ids = list(tiles) if tiles is not None else list(
        range(len(DEFAULT_TILES)))
    out = []
    for c in chips:
        for p in partitions:
            if p == "2D" and c < 4:
                continue  # 2D sharding needs a 2D submesh
            for t in tile_ids:
                out.append(GemmConfig(c, p, t))
    return out


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _local_shape(m: int, k: int, n: int, cfg: GemmConfig
                 ) -> tuple[int, int, int]:
    """Per-chip GEMM extents under the chosen partitioning."""
    p = cfg.n_chips
    if cfg.partition == "M":
        return _ceil_div(m, p), k, n
    if cfg.partition == "N":
        return m, k, _ceil_div(n, p)
    if cfg.partition == "K":
        return m, _ceil_div(k, p), n
    # 2D: factor p into the two most square factors, shard M x N
    pm = 2 ** (int(math.log2(p)) // 2)
    pn = p // pm
    return _ceil_div(m, pm), k, _ceil_div(n, pn)


def _collective_bytes(m: int, k: int, n: int, cfg: GemmConfig,
                      dtype_bytes: int) -> tuple[float, int]:
    """(bytes per chip moved over ICI, number of collective phases)."""
    p = cfg.n_chips
    if p == 1:
        return 0.0, 0
    frac = (p - 1) / p
    if cfg.partition == "M":      # all-gather B
        return frac * k * n * dtype_bytes, 1
    if cfg.partition == "N":      # all-gather A
        return frac * m * k * dtype_bytes, 1
    if cfg.partition == "K":      # all-reduce partial C (2x traffic)
        return 2.0 * frac * m * n * dtype_bytes, 2
    # 2D: all-gather A along pn ring, B along pm ring
    pm = 2 ** (int(math.log2(p)) // 2)
    pn = p // pm
    bytes_a = (pn - 1) / pn * (m // max(pm, 1)) * k * dtype_bytes
    bytes_b = (pm - 1) / pm * k * (n // max(pn, 1)) * dtype_bytes
    return bytes_a + bytes_b, 2


def estimate_gemm_time(m: int, k: int, n: int, cfg: GemmConfig,
                       spec: TPUSpec = TPUSpec(), *,
                       dtype_bytes: int = 2,
                       rng: np.random.Generator | None = None
                       ) -> TimeBreakdown:
    """Analytic runtime of C[m,n] = A[m,k] @ B[k,n] under ``cfg``.

    Terms:
      compute    — wave-quantised MXU time for the per-chip tile grid
      memory     — HBM traffic incl. tile re-reads (blocked GEMM reads A
                   once per N-block column and B once per M-block row)
      collective — ICI ring time + per-hop latency floor
      launch     — per-kernel-invocation overhead
    Noise (rng given): multiplicative lognormal + rare straggler spikes.
    """
    lm, lk, ln = _local_shape(m, k, n, cfg)
    bm, bk, bn = cfg.tile
    bm, bk, bn = min(bm, _pad(lm)), min(bk, _pad(lk)), min(bn, _pad(ln))

    gm, gk, gn = _ceil_div(lm, bm), _ceil_div(lk, bk), _ceil_div(ln, bn)

    # ---- compute: padded-tile FLOPs at MXU efficiency --------------------
    mxu = spec.mxu_dim
    eff_m = bm / (_ceil_div(bm, mxu) * mxu)
    eff_n = bn / (_ceil_div(bn, mxu) * mxu)
    # sub-128 K still fills the pipeline after warmup; mild penalty
    eff_k = min(1.0, (bk + 16) / mxu) if bk < mxu else 1.0
    mxu_eff = max(eff_m * eff_n * min(eff_k, 1.0), 0.02)
    flops = 2.0 * (gm * bm) * (gk * bk) * (gn * bn)
    compute_s = flops / (spec.peak_flops * mxu_eff)

    # ---- memory: blocked-GEMM HBM traffic --------------------------------
    bytes_a = lm * lk * gn * dtype_bytes          # A re-read per N block col
    bytes_b = lk * ln * gm * dtype_bytes          # B re-read per M block row
    bytes_c = lm * ln * (dtype_bytes + 2 * dtype_bytes * (gk - 1))
    # VMEM overflow cliff: working set beyond VMEM spills accumulators
    working = (bm * bk + bk * bn + bm * bn) * dtype_bytes * 2  # dbl buffer
    spill = 1.0 if working <= spec.vmem_bytes else 4.0
    memory_s = spill * (bytes_a + bytes_b + bytes_c) / spec.hbm_bw

    # ---- collective: ring bandwidth + latency floor -----------------------
    coll_bytes, phases = _collective_bytes(m, k, n, cfg, dtype_bytes)
    hops = max(cfg.n_chips - 1, 0)
    collective_s = (coll_bytes / spec.ici_bw_total
                    + phases * (hops * spec.collective_latency_s
                                + spec.collective_dispatch_s))

    launch_s = spec.launch_overhead_s * max(1.0, math.log2(cfg.n_chips + 1))

    tb = TimeBreakdown(compute_s, memory_s, collective_s, launch_s)
    if rng is not None:
        jitter = float(np.exp(rng.normal(0.0, 0.05)))
        straggler = 1.0
        if cfg.n_chips > 1 and rng.random() < 0.01:   # rare straggler
            straggler = 1.0 + float(rng.exponential(0.5))
        tb = TimeBreakdown(compute_s * jitter, memory_s * jitter,
                           collective_s * jitter * straggler, launch_s)
    return tb


def _pad(x: int) -> int:
    """Round up to the sublane multiple (8) so tiny dims stay legal."""
    return max(8, _ceil_div(x, 8) * 8)


def estimate_batch(dims: np.ndarray, cfgs: list[GemmConfig],
                   spec: TPUSpec = TPUSpec(), *, dtype_bytes: int = 2,
                   seed: int | None = 0) -> np.ndarray:
    """Runtime matrix, shape (len(dims), len(cfgs)); noisy if seed given."""
    rng = np.random.default_rng(seed) if seed is not None else None
    out = np.empty((len(dims), len(cfgs)))
    for i, (m, k, n) in enumerate(np.asarray(dims, dtype=np.int64)):
        for j, cfg in enumerate(cfgs):
            out[i, j] = estimate_gemm_time(
                int(m), int(k), int(n), cfg, spec,
                dtype_bytes=dtype_bytes, rng=rng).total_s
    return out
