"""Scrambled Halton sequence sampling of the GEMM input domain.

The paper (§IV-B) samples (m, k, n) with a *scrambled* Halton sequence so
that the training set is low-discrepancy across the whole domain,
including slim/fat and tiny/huge matrices.  Scrambling (random digit
permutations, Mascagni & Chi 2004) breaks the inter-dimensional
correlation plain Halton suffers from in higher bases.

Deviation from the paper recorded in DESIGN.md: the paper lists bases
(2, 3, 4); base 4 is not coprime with base 2 which destroys the
low-discrepancy property the cited reference requires, so we use the
first three primes (2, 3, 5).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "halton_sequence",
    "scrambled_halton",
    "sample_gemm_dims",
    "sample_gemm_dims_mixture",
    "gemm_bytes",
]

# First six primes: (m, k, n) sampling uses the leading three; the
# config-space lattice sampler (ConfigSpace.sample) draws one base per
# axis and enlarged spaces have four axes.  Extra bases never change the
# leading columns — each dimension's stream only depends on its own base.
_DEFAULT_BASES = (2, 3, 5, 7, 11, 13)


def _digit_permutations(base: int, rng: np.random.Generator) -> np.ndarray:
    """A random permutation of {0..base-1} fixing 0.

    Fixing 0 keeps the radical-inverse map well defined (trailing zeros
    must stay zeros, otherwise the sequence escapes [0, 1)).
    """
    perm = 1 + rng.permutation(base - 1)
    return np.concatenate([[0], perm])


def _radical_inverse(indices: np.ndarray, base: int,
                     perm: np.ndarray | None) -> np.ndarray:
    """Vectorised (scrambled) radical inverse of ``indices`` in ``base``."""
    idx = indices.astype(np.int64).copy()
    out = np.zeros(idx.shape, dtype=np.float64)
    inv_base = 1.0 / base
    factor = inv_base
    while np.any(idx > 0):
        digits = idx % base
        if perm is not None:
            digits = perm[digits]
        out += digits * factor
        idx //= base
        factor *= inv_base
    return out


def halton_sequence(n: int, dims: int = 3, *, start: int = 1,
                    bases: tuple[int, ...] | None = None) -> np.ndarray:
    """Plain Halton points in [0, 1)^dims, shape (n, dims)."""
    bases = bases or _DEFAULT_BASES
    if dims > len(bases):
        raise ValueError(f"need {dims} bases, have {len(bases)}")
    indices = np.arange(start, start + n)
    cols = [_radical_inverse(indices, bases[d], None) for d in range(dims)]
    return np.stack(cols, axis=1)


def scrambled_halton(n: int, dims: int = 3, *, seed: int = 0,
                     start: int = 1,
                     bases: tuple[int, ...] | None = None) -> np.ndarray:
    """Scrambled Halton points in [0, 1)^dims, shape (n, dims)."""
    bases = bases or _DEFAULT_BASES
    if dims > len(bases):
        raise ValueError(f"need {dims} bases, have {len(bases)}")
    rng = np.random.default_rng(seed)
    indices = np.arange(start, start + n)
    cols = []
    for d in range(dims):
        perm = _digit_permutations(bases[d], rng)
        cols.append(_radical_inverse(indices, bases[d], perm))
    return np.stack(cols, axis=1)


def gemm_bytes(m: np.ndarray, k: np.ndarray, n: np.ndarray,
               dtype_bytes: int = 4) -> np.ndarray:
    """Aggregate operand footprint: dtype_bytes * (mk + kn + mn)  (§IV-B)."""
    m = np.asarray(m, dtype=np.int64)
    k = np.asarray(k, dtype=np.int64)
    n = np.asarray(n, dtype=np.int64)
    return dtype_bytes * (m * k + k * n + m * n)


def sample_gemm_dims(n_samples: int, *, mem_limit_bytes: int,
                     dim_min: int = 8, dim_max: int = 65536,
                     dtype_bytes: int = 4, seed: int = 0,
                     log_space: bool = True) -> np.ndarray:
    """Sample (m, k, n) triples under a memory budget (paper §IV-B).

    Points are drawn from a scrambled Halton sequence, mapped to the
    dimension range (log-uniformly by default — matrix dims span four
    orders of magnitude), and rejected when the aggregate operand
    footprint exceeds ``mem_limit_bytes``.  Rejection preserves the
    low-discrepancy property inside the accepted region.

    Returns an (n_samples, 3) int64 array.
    """
    accepted: list[np.ndarray] = []
    start = 1
    total = 0
    lo, hi = np.log2(dim_min), np.log2(dim_max)
    while total < n_samples:
        batch = max(256, 2 * (n_samples - total))
        u = scrambled_halton(batch, 3, seed=seed, start=start)
        start += batch
        if log_space:
            dims = np.exp2(lo + u * (hi - lo))
        else:
            dims = dim_min + u * (dim_max - dim_min)
        dims = np.maximum(dim_min, np.round(dims)).astype(np.int64)
        keep = gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2],
                          dtype_bytes) <= mem_limit_bytes
        kept = dims[keep]
        if kept.size:
            accepted.append(kept)
            total += len(kept)
        if start > 10_000_000:  # pragma: no cover - domain misconfigured
            raise RuntimeError("halton rejection sampling failed to fill")
    return np.concatenate(accepted, axis=0)[:n_samples]


def _sample_region(n: int, lo: np.ndarray, hi: np.ndarray, *,
                   mem_limit_bytes: int, dim_min: int, dim_max: int,
                   dtype_bytes: int, seed: int) -> np.ndarray:
    """Up to ``n`` accepted samples inside one log2 box (rejection on the
    memory budget; the box is clipped to the global dim range first)."""
    lo = np.maximum(lo, np.log2(dim_min))
    hi = np.minimum(hi, np.log2(dim_max))
    if np.any(hi <= lo):                    # box outside the domain
        return np.empty((0, 3), dtype=np.int64)
    accepted: list[np.ndarray] = []
    start, total, tried = 1, 0, 0
    while total < n and tried < 64 * max(n, 8):
        batch = max(64, 2 * (n - total))
        u = scrambled_halton(batch, 3, seed=seed, start=start)
        start += batch
        tried += batch
        dims = np.maximum(dim_min,
                          np.round(np.exp2(lo + u * (hi - lo)))
                          ).astype(np.int64)
        keep = gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2],
                          dtype_bytes) <= mem_limit_bytes
        kept = dims[keep]
        if kept.size:
            accepted.append(kept)
            total += len(kept)
    if not accepted:
        return np.empty((0, 3), dtype=np.int64)
    return np.concatenate(accepted, axis=0)[:n]


def sample_gemm_dims_mixture(
        n_samples: int,
        regions: list[tuple[tuple[float, float, float],
                            tuple[float, float, float], float]], *,
        mem_limit_bytes: int, bias: float = 0.75, dim_min: int = 8,
        dim_max: int = 65536, dtype_bytes: int = 4, seed: int = 0,
        log_space: bool = False) -> np.ndarray:
    """Workload-biased (m, k, n) sampling (mixture of Halton streams).

    ``regions`` is ``[(log2_lo, log2_hi, weight), ...]`` — typically a
    :meth:`WorkloadProfile.region_boxes` shape histogram.  A ``bias``
    fraction of the budget is apportioned across the regions by weight
    and drawn from an independent scrambled-Halton stream per region
    (low-discrepancy *within* each region, log-uniform over its box);
    the remaining ``1 - bias`` is the uniform floor, drawn by
    :func:`sample_gemm_dims` over the whole domain so coverage never
    collapses onto the observed workload.  Regions that cannot fill
    their quota (e.g. mostly above the memory budget) hand the
    shortfall back to the floor.  The returned rows are shuffled with a
    ``seed``-derived permutation so sample index carries no region
    structure.  All samples respect the memory budget; deterministic
    given ``seed``.
    """
    if not 0.0 <= bias <= 1.0:
        raise ValueError(f"bias={bias} outside [0, 1]")
    if not regions or bias == 0.0:
        return sample_gemm_dims(
            n_samples, mem_limit_bytes=mem_limit_bytes, dim_min=dim_min,
            dim_max=dim_max, dtype_bytes=dtype_bytes, seed=seed,
            log_space=log_space)
    from repro.core.workload import apportion  # shared allocator

    n_bias = int(round(bias * n_samples))
    quotas = apportion([max(float(w), 0.0) for *_, w in regions], n_bias)
    parts: list[np.ndarray] = []
    drawn = 0
    for i, ((lo, hi, _), q) in enumerate(zip(regions, quotas)):
        if q <= 0:
            continue
        got = _sample_region(
            q, np.asarray(lo, dtype=np.float64),
            np.asarray(hi, dtype=np.float64),
            mem_limit_bytes=mem_limit_bytes, dim_min=dim_min,
            dim_max=dim_max, dtype_bytes=dtype_bytes,
            seed=seed + 100_003 * (i + 1))
        if got.size:
            parts.append(got)
            drawn += len(got)
    n_floor = n_samples - drawn
    if n_floor > 0:
        parts.append(sample_gemm_dims(
            n_floor, mem_limit_bytes=mem_limit_bytes, dim_min=dim_min,
            dim_max=dim_max, dtype_bytes=dtype_bytes, seed=seed,
            log_space=log_space))
    dims = np.concatenate(parts, axis=0)[:n_samples]
    # distinct stream from the caller's plain default_rng(seed): the
    # installer permutes its routine assignment with exactly that rng
    # over the same n, and two identical permutations cancel in the
    # (dim, routine) pairing — routine id would re-align with the
    # region block order, the very stratification this samples against
    perm = np.random.default_rng([seed, 0x5A]).permutation(len(dims))
    return dims[perm]
