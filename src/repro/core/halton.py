"""Scrambled Halton sequence sampling of the GEMM input domain.

The paper (§IV-B) samples (m, k, n) with a *scrambled* Halton sequence so
that the training set is low-discrepancy across the whole domain,
including slim/fat and tiny/huge matrices.  Scrambling (random digit
permutations, Mascagni & Chi 2004) breaks the inter-dimensional
correlation plain Halton suffers from in higher bases.

Deviation from the paper recorded in DESIGN.md: the paper lists bases
(2, 3, 4); base 4 is not coprime with base 2 which destroys the
low-discrepancy property the cited reference requires, so we use the
first three primes (2, 3, 5).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "halton_sequence",
    "scrambled_halton",
    "sample_gemm_dims",
    "gemm_bytes",
]

_DEFAULT_BASES = (2, 3, 5)


def _digit_permutations(base: int, rng: np.random.Generator) -> np.ndarray:
    """A random permutation of {0..base-1} fixing 0.

    Fixing 0 keeps the radical-inverse map well defined (trailing zeros
    must stay zeros, otherwise the sequence escapes [0, 1)).
    """
    perm = 1 + rng.permutation(base - 1)
    return np.concatenate([[0], perm])


def _radical_inverse(indices: np.ndarray, base: int,
                     perm: np.ndarray | None) -> np.ndarray:
    """Vectorised (scrambled) radical inverse of ``indices`` in ``base``."""
    idx = indices.astype(np.int64).copy()
    out = np.zeros(idx.shape, dtype=np.float64)
    inv_base = 1.0 / base
    factor = inv_base
    while np.any(idx > 0):
        digits = idx % base
        if perm is not None:
            digits = perm[digits]
        out += digits * factor
        idx //= base
        factor *= inv_base
    return out


def halton_sequence(n: int, dims: int = 3, *, start: int = 1,
                    bases: tuple[int, ...] | None = None) -> np.ndarray:
    """Plain Halton points in [0, 1)^dims, shape (n, dims)."""
    bases = bases or _DEFAULT_BASES
    if dims > len(bases):
        raise ValueError(f"need {dims} bases, have {len(bases)}")
    indices = np.arange(start, start + n)
    cols = [_radical_inverse(indices, bases[d], None) for d in range(dims)]
    return np.stack(cols, axis=1)


def scrambled_halton(n: int, dims: int = 3, *, seed: int = 0,
                     start: int = 1,
                     bases: tuple[int, ...] | None = None) -> np.ndarray:
    """Scrambled Halton points in [0, 1)^dims, shape (n, dims)."""
    bases = bases or _DEFAULT_BASES
    if dims > len(bases):
        raise ValueError(f"need {dims} bases, have {len(bases)}")
    rng = np.random.default_rng(seed)
    indices = np.arange(start, start + n)
    cols = []
    for d in range(dims):
        perm = _digit_permutations(bases[d], rng)
        cols.append(_radical_inverse(indices, bases[d], perm))
    return np.stack(cols, axis=1)


def gemm_bytes(m: np.ndarray, k: np.ndarray, n: np.ndarray,
               dtype_bytes: int = 4) -> np.ndarray:
    """Aggregate operand footprint: dtype_bytes * (mk + kn + mn)  (§IV-B)."""
    m = np.asarray(m, dtype=np.int64)
    k = np.asarray(k, dtype=np.int64)
    n = np.asarray(n, dtype=np.int64)
    return dtype_bytes * (m * k + k * n + m * n)


def sample_gemm_dims(n_samples: int, *, mem_limit_bytes: int,
                     dim_min: int = 8, dim_max: int = 65536,
                     dtype_bytes: int = 4, seed: int = 0,
                     log_space: bool = True) -> np.ndarray:
    """Sample (m, k, n) triples under a memory budget (paper §IV-B).

    Points are drawn from a scrambled Halton sequence, mapped to the
    dimension range (log-uniformly by default — matrix dims span four
    orders of magnitude), and rejected when the aggregate operand
    footprint exceeds ``mem_limit_bytes``.  Rejection preserves the
    low-discrepancy property inside the accepted region.

    Returns an (n_samples, 3) int64 array.
    """
    accepted: list[np.ndarray] = []
    start = 1
    total = 0
    lo, hi = np.log2(dim_min), np.log2(dim_max)
    while total < n_samples:
        batch = max(256, 2 * (n_samples - total))
        u = scrambled_halton(batch, 3, seed=seed, start=start)
        start += batch
        if log_space:
            dims = np.exp2(lo + u * (hi - lo))
        else:
            dims = dim_min + u * (dim_max - dim_min)
        dims = np.maximum(dim_min, np.round(dims)).astype(np.int64)
        keep = gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2],
                          dtype_bytes) <= mem_limit_bytes
        kept = dims[keep]
        if kept.size:
            accepted.append(kept)
            total += len(kept)
        if start > 10_000_000:  # pragma: no cover - domain misconfigured
            raise RuntimeError("halton rejection sampling failed to fill")
    return np.concatenate(accepted, axis=0)[:n_samples]
