"""Runtime tuner (paper §III-C Fig 3 + §IV-A).

Loads the installation artifact once, then per BLAS-3 call predicts the
runtime of every candidate worker configuration and dispatches on the
argmin.  Implements the paper's memoisation: "the software is designed to
remember the last GEMM input and ML predictions; if the current GEMM
matrix dimensions are the same as the previous, the software will read
and apply the predictions ... without re-evaluation."  Beyond the paper
we keep a bounded LRU dict of *all* seen shapes, not just the last one
(training loops interleave a handful of distinct GEMM shapes — the
last-only cache thrashes; recorded in EXPERIMENTS.md §Perf), and the
cache key is ``(routine, m, k, n)`` so gemm / syrk / trsm calls with the
same dims never alias each other's choices.

Artifact compatibility: installations written before the routine
extension carry 19-column GEMM-only features and a v1 warm-start block.
``from_artifact`` detects both (via the persisted ``feature_names``) and
keeps serving them — gemm selections use the legacy feature layout, and
asking such a tuner for syrk/trsm raises instead of silently feeding the
model columns it was never fitted on.  The same guard applies to *new*
artifacts installed over a subset of ROUTINES (the persisted
``install.routines`` list): a gemm-only install has constant routine
feature columns, so its model has no idea how syrk/trsm behave — the
tuner refuses rather than hand out gemm-quality picks for them.
"""

from __future__ import annotations

import collections
import threading
import warnings
from typing import Any, Iterable

import numpy as np

from repro.core.costmodel import (
    GemmConfig,
    PARTITIONS,
    ROUTINES,
    TRSM_SEQ_CHIPS,
    routine_ids,
)
from repro.core.features import build_features
from repro.core.installer import load_artifact
from repro.core.preprocessing import PreprocessPipeline
from repro.core.search.beam import beam_search
from repro.core.search.space import ConfigSpace
from repro.core.workload import WorkloadProfile

__all__ = ["AdsalaTuner"]

_PARTITIONS = ("M", "N", "K", "2D")

#: cache / warm-start key: (routine, m, k, n)
Key = tuple[str, int, int, int]


def _normalise_routines(shapes: list, routines) -> list[str]:
    """One routine name per shape, via the shared costmodel validator."""
    return [ROUTINES[i] for i in routine_ids(routines, len(shapes))]


def _flash_columns(cands: list[GemmConfig]
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-candidate (flash_bq, flash_bkv, flash_tri) feature columns."""
    return (np.asarray([c.flash_block[0] for c in cands], float),
            np.asarray([c.flash_block[1] for c in cands], float),
            np.asarray([float(c.flash_grid != "dense") for c in cands]))


class AdsalaTuner:
    """Predict-then-argmin worker-configuration selector."""

    def __init__(self, model: Any, pipe: PreprocessPipeline,
                 candidates: list[GemmConfig], *,
                 max_chips: int | None = None,
                 cache_size: int = 256,
                 feature_names: list[str] | None = None,
                 routines: tuple[str, ...] | None = None,
                 workload: WorkloadProfile | None = None,
                 space: ConfigSpace | None = None,
                 search_width: int | None = None) -> None:
        if max_chips is not None:
            candidates = [c for c in candidates if c.n_chips <= max_chips]
        if not candidates:
            raise ValueError("empty candidate set")
        self.model = model
        self.pipe = pipe
        self.candidates = candidates
        self.cache_size = cache_size
        #: the ConfigSpace dispatch-time search explores.  Artifacts
        #: since the search refactor persist theirs (``"space"`` block);
        #: otherwise reconstruct the default space the candidate list
        #: implies, so ``select(search=...)`` always has one.
        if space is None:
            present = tuple(p for p in PARTITIONS
                            if any(c.partition == p for c in candidates))
            space = ConfigSpace.default(
                max(c.n_chips for c in candidates),
                tiles=tuple(sorted({c.tile_id for c in candidates})),
                partitions=present)
            # Flash-aware installs enumerate from with_flash(); when the
            # candidate list carries non-default flash knobs the implied
            # space must too, else those candidates (and any warm-start
            # entries using them) fall outside `space.contains`.
            if any(c.flash_block_id != 0 or c.flash_grid != "dense"
                   for c in candidates):
                space = space.with_flash(block_ids=tuple(
                    sorted({c.flash_block_id for c in candidates})))
        self.space = space
        #: default beam width for ``select(search=True)``; None means
        #: fixed-candidate argmin unless a call opts in.
        self.search_width = search_width
        #: the WorkloadProfile the install grid was weighted by (None =
        #: uniform install / no provenance).  Serving code compares the
        #: live recorded mix against it (see :meth:`workload_drift`).
        self.workload = workload
        #: HardwareFingerprint the artifact was installed for (None =
        #: legacy artifact / no provenance); set by from_artifact.
        self.fingerprint = None
        #: ``describe_backend`` dict of the backend that timed the
        #: install grid (None = legacy artifact).  The serving
        #: re-install loop uses it to rebuild the same kind of backend.
        self.backend_info = None
        #: ``"transfer"`` provenance block for transfer-installed
        #: artifacts (donor path + fitted correction); None otherwise.
        self.transfer_info = None
        # fingerprint-mismatch warning latch: warn once per tuner, not
        # once per dispatch (see check_fingerprint)
        self._fp_warned = False
        # Three feature generations (see repro.core.features): gen-1
        # GEMM-only artifacts predate the routine columns, gen-2 BLAS-3
        # artifacts predate the flash columns.  Keep feeding each model
        # the exact layout it was fitted on.
        self._legacy_features = (feature_names is not None
                                 and "routine_syrk" not in feature_names)
        self._flash_features = (feature_names is None
                                or "routine_attn" in feature_names)
        # Routines the model was actually trained on (None = all):
        # selections outside this set would be extrapolation the model
        # has zero signal for, so they raise instead.
        if self._legacy_features and routines is None:
            routines = ("gemm",)
        elif not self._flash_features and routines is None:
            routines = ("gemm", "syrk", "trsm")
        self.routines = tuple(ROUTINES) if routines is None \
            else tuple(routines)
        for r in self.routines:
            if r not in ROUTINES:
                raise ValueError(f"unknown routine {r!r}; "
                                 f"expected one of {ROUTINES}")
        # key -> (config, predicted times).  times is None for warm-start
        # entries restored from the install artifact (only the argmin is
        # persisted); select_with_times lazily re-evaluates those.
        self._cache: collections.OrderedDict[
            Key, tuple[GemmConfig, np.ndarray | None]] = \
            collections.OrderedDict()
        # Guards the LRU dict + stats counters: serving threads hammer
        # select/select_many while a background re-install swaps tuners
        # (repro.serve.reinstall), and OrderedDict mutation is not safe
        # under concurrent move_to_end/popitem.  Model prediction runs
        # OUTSIDE the lock — concurrent selects never serialise on the
        # expensive part, and a duplicated evaluation of the same key is
        # benign (deterministic model, both writes agree).
        self._lock = threading.RLock()
        self.stats = {"calls": 0, "cache_hits": 0, "evaluations": 0}
        # pre-built candidate feature columns (constant across calls)
        self._chips = np.asarray([c.n_chips for c in candidates], float)
        self._tiles = np.asarray([c.tile_id for c in candidates], float)
        self._parts = np.asarray(
            [_PARTITIONS.index(c.partition) for c in candidates], float)
        self._flash = _flash_columns(candidates)

    @classmethod
    def from_artifact(cls, artifact_dir: str, *,
                      local_fingerprint: Any | None = None,
                      **kw: Any) -> "AdsalaTuner":
        """Load a persisted install.  ``local_fingerprint`` (a
        :class:`~repro.core.registry.HardwareFingerprint`) triggers a
        provenance check: an artifact installed for different hardware
        warns once.  Artifacts predating the ``"fingerprint"`` block
        load exactly as before (no provenance, no check)."""
        model, pipe, cands, config = load_artifact(artifact_dir)
        kw.setdefault("feature_names", config.get("feature_names"))
        installed = config.get("install", {}).get("routines")
        if installed is not None:
            kw.setdefault("routines", tuple(installed))
        if config.get("workload") is not None:
            kw.setdefault("workload",
                          WorkloadProfile.from_dict(config["workload"]))
        # Post-refactor artifacts persist the exact space the install
        # searched; legacy ones fall back to the constructor's implied
        # default space (reconstructed from the candidate list).
        if config.get("space") is not None:
            kw.setdefault("space", ConfigSpace.from_dict(config["space"]))
        tuner = cls(model, pipe, cands, **kw)
        ws = config.get("warm_start")
        # A max_chips filter renumbers/narrows the candidate set, so the
        # persisted warm choices no longer describe this tuner's search
        # space — start cold in that case.
        if ws and kw.get("max_chips") is None:
            if "cache_size" not in kw:
                # default capacity (256) is smaller than the default
                # install budget (400 dims): grow so the whole persisted
                # warm set survives; an explicit cache_size wins.
                tuner.cache_size = max(tuner.cache_size, len(ws["dims"]))
            # v1 blocks (pre-routine artifacts) carry no "routines" list:
            # every entry is a gemm choice.  v2 stores argmin indices
            # into the candidate list; v3 stores explicit config dicts
            # (beam-found configs need not sit in a fixed list).
            routines = ws.get("routines") or ["gemm"] * len(ws["dims"])
            # Validate against what the model has signal for: a
            # hand-edited or mixed-version artifact can carry warm
            # entries for routines outside the installed set, argmin
            # indices outside the candidate list, or configs outside
            # the persisted space.  Preloading those would serve stale
            # predictions from cache hits where live dispatch degrades
            # to gemm / raises — drop them instead.
            entries, dropped = [], 0
            if int(ws.get("version", 1)) >= 3:
                for r, d, cd in zip(routines, ws["dims"], ws["configs"]):
                    try:
                        c = GemmConfig(cd["n_chips"], cd["partition"],
                                       cd["tile_id"],
                                       cd.get("trsm_seq_chips",
                                              TRSM_SEQ_CHIPS),
                                       cd.get("flash_block_id", 0),
                                       cd.get("flash_grid", "dense"))
                    except (KeyError, TypeError):
                        dropped += 1
                        continue
                    if (r not in tuner.routines or len(d) != 3
                            or not tuner.space.contains(c)):
                        dropped += 1
                        continue
                    entries.append(((r, *d), c))
            else:
                for r, d, j in zip(routines, ws["dims"], ws["best"]):
                    if (r not in tuner.routines or len(d) != 3
                            or not 0 <= int(j) < len(cands)):
                        dropped += 1
                        continue
                    entries.append(((r, *d), cands[int(j)]))
            if dropped:
                warnings.warn(
                    f"{artifact_dir}: dropped {dropped}/{len(routines)} "
                    f"warm-start entries outside the installed routines "
                    f"{tuner.routines} / candidate space (hand-edited "
                    "or mixed-version artifact?)", stacklevel=2)
            tuner.warm_start(entries)
        # provenance (absent on legacy artifacts — loading must still
        # work, the tuner just has nothing to check against)
        if config.get("fingerprint") is not None:
            from repro.core.registry import HardwareFingerprint  # no cycle
            tuner.fingerprint = HardwareFingerprint.from_dict(
                config["fingerprint"])
        tuner.backend_info = config.get("backend")
        tuner.transfer_info = config.get("transfer")
        if local_fingerprint is not None:
            tuner.check_fingerprint(local_fingerprint)
        return tuner

    def check_fingerprint(self, local: Any) -> bool:
        """True when the artifact's installed fingerprint matches this
        machine's (same registry key), or when the artifact carries no
        provenance.  A mismatch warns ONCE per tuner — dispatch-path
        callers may check freely without flooding the log."""
        if self.fingerprint is None or local is None:
            return True
        if self.fingerprint.key() == local.key():
            return True
        if not self._fp_warned:
            self._fp_warned = True
            warnings.warn(
                f"artifact was installed for "
                f"{self.fingerprint.key()} but is being served on "
                f"{local.key()} (distance "
                f"{self.fingerprint.distance(local):.3f}) — timings "
                "transfer only approximately; run a transfer install "
                "for this machine", stacklevel=2)
        return False

    def workload_drift(self, observed_mix: dict[str, float]
                       ) -> float | None:
        """Total-variation distance between the artifact's installed
        workload-profile routine mix and an observed serving mix (e.g.
        ``DispatchRecorder.routine_mix()``); None when the artifact
        carries no profile.  Large values mean the install budget was
        spent on a different workload than the one being served."""
        if self.workload is None:
            return None
        return self.workload.drift(observed_mix)

    # ------------------------------------------------------------------
    def _key(self, m: int, k: int, n: int, routine: str = "gemm") -> Key:
        return (routine, int(m), int(k), int(n))

    def peek(self, m: int, k: int, n: int,
             routine: str = "gemm") -> bool:
        """True when ``(routine, m, k, n)`` is already memoised — the
        next :meth:`select` for it will be a cache hit with no model
        evaluation.  Observability only: touches neither the LRU
        recency order nor the stats counters (the DispatchRecorder uses
        this to label events without perturbing what it measures)."""
        return self._key(m, k, n, routine) in self._cache

    def warm_start(self, entries: Iterable[
            tuple[tuple, GemmConfig]]) -> None:
        """Seed the memo cache with (shape -> config) choices computed at
        install time (persisted in the artifact's ``warm_start`` block).
        Keys are ``(routine, m, k, n)``; bare 3-tuples mean gemm."""
        with self._lock:
            for key, cfg in entries:
                if len(key) == 3:
                    key = ("gemm", *key)
                routine, m, k, n = key
                key = self._key(m, k, n, routine)
                self._cache[key] = (cfg, None)
                self._cache.move_to_end(key)
            self._evict()

    def _evict(self) -> None:
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    #: shapes per model-predict chunk.  Tree-ensemble predictors walk
    #: (rows x trees) working sets depth-many times; past ~16 shapes the
    #: set falls out of cache and one huge predict is *slower* than the
    #: scalar loop (measured 118ms vs 60ms for 64 shapes x 76 candidates).
    _PREDICT_CHUNK = 16

    def predicted_times_many(self, shapes: Iterable[tuple[int, int, int]],
                             routines=None, *,
                             candidates: list[GemmConfig] | None = None
                             ) -> np.ndarray:
        """Predicted runtimes for every (shape x candidate), shape (S, C).

        Batched feature build + preprocess + model predict; chunked to
        ``_PREDICT_CHUNK`` shapes per predict call to stay cache-resident.
        ``routines`` is None (all gemm), one name, or one name/id per
        shape.  ``candidates`` overrides the tuner's fixed list — this is
        how beam search prices arbitrary frontier configs with the same
        model (the feature set carries no ``trsm_seq_chips`` column, so
        configs differing only in that knob predict identically).
        """
        if candidates is None:
            cands = self.candidates
            chips, tiles, parts = self._chips, self._tiles, self._parts
            flash = self._flash
        else:
            cands = list(candidates)
            chips = np.asarray([c.n_chips for c in cands], float)
            tiles = np.asarray([c.tile_id for c in cands], float)
            parts = np.asarray(
                [_PARTITIONS.index(c.partition) for c in cands], float)
            flash = _flash_columns(cands)
        C = len(cands)
        shapes = list(shapes)
        if not shapes:
            return np.empty((0, C))
        names = _normalise_routines(shapes, routines)
        unseen = sorted({r for r in names if r not in self.routines})
        if unseen:
            raise ValueError(
                f"this artifact was installed for routines "
                f"{self.routines}; it has no training signal for "
                f"{unseen} — re-install with InstallConfig(routines=...) "
                "to tune them")
        rids = np.asarray([ROUTINES.index(r) for r in names], float)
        d = np.atleast_2d(np.asarray(shapes, dtype=np.float64))
        S = len(d)
        out = np.empty((S, C))
        for lo in range(0, S, self._PREDICT_CHUNK):
            chunk = d[lo:lo + self._PREDICT_CHUNK]
            B = len(chunk)
            X = build_features(
                np.repeat(chunk[:, 0], C), np.repeat(chunk[:, 1], C),
                np.repeat(chunk[:, 2], C),
                np.tile(chips, B), np.tile(tiles, B),
                np.tile(parts, B),
                None if self._legacy_features
                else np.repeat(rids[lo:lo + B], C).astype(np.int64),
                flash=tuple(np.tile(f, B) for f in flash)
                if self._flash_features and not self._legacy_features
                else None)
            out[lo:lo + B] = np.exp(
                self.model.predict(self.pipe.transform(X))).reshape(B, C)
        return out

    def predicted_times(self, m: int, k: int, n: int,
                        routine: str = "gemm") -> np.ndarray:
        """Predicted runtime (seconds) for every candidate config."""
        return self.predicted_times_many([(m, k, n)],
                                         routines=routine)[0]

    def select(self, m: int, k: int, n: int,
               routine: str = "gemm", *,
               search: bool | int | None = None) -> GemmConfig:
        """Optimal worker configuration for this routine call (memoised).

        ``search`` opts a cache miss into a dispatch-time beam search
        over :attr:`space` instead of the fixed-candidate argmin:
        ``True`` uses the artifact's default width (``search_width``,
        else 8), an int sets the width, ``False`` forces the fixed path,
        ``None`` defers to ``search_width``.
        """
        return self.select_many([(m, k, n)], routines=routine,
                                search=search)[0]

    def select_many(self, shapes: Iterable[tuple[int, int, int]],
                    routines=None, *,
                    search: bool | int | None = None) -> list[GemmConfig]:
        """Optimal configuration per shape, via ONE batched evaluation.

        Cache-missed shapes are deduplicated and predicted together (a
        grouped/MoE dispatch with E experts costs one model call, not E);
        hits keep the scalar path's LRU semantics.  ``routines`` follows
        :meth:`predicted_times_many`; ``search`` follows :meth:`select`
        — the beam path prices whole frontiers through the same model
        (one batched prediction per level) and can return configs
        outside the fixed candidate list when the artifact's space is
        wider.
        """
        shapes = list(shapes)
        names = _normalise_routines(shapes, routines)
        keys = [self._key(m, k, n, r)
                for (m, k, n), r in zip(shapes, names)]
        eff = search if search is not None else self.search_width
        if eff is True:
            eff = self.search_width or 8
        # Pass 1 (locked): classify hits vs misses.  Hit configs are
        # snapshotted immediately — a concurrent caller may evict them
        # from the LRU before pass 2 re-acquires the lock.
        hits: dict[Key, GemmConfig] = {}
        missing: list[Key] = []
        seen: set[Key] = set()
        with self._lock:
            self.stats["calls"] += len(keys)
            for key in keys:
                if key in self._cache:
                    hits.setdefault(key, self._cache[key][0])
                elif key not in seen:
                    seen.add(key)
                    missing.append(key)
            if missing:
                self.stats["evaluations"] += len(missing)
        # Evaluate misses OUTSIDE the lock: the model predict is the
        # expensive part and must not serialise concurrent serving
        # threads (a racing thread may duplicate an evaluation of the
        # same key — benign, the model is deterministic).
        chosen: dict[Key, tuple[GemmConfig, np.ndarray | None]] = {}
        if missing:
            if eff:
                res = beam_search(
                    np.asarray([k[1:] for k in missing], dtype=np.int64),
                    self.space,
                    cost_fn=lambda dd, cc, rr: self.predicted_times_many(
                        [tuple(int(x) for x in d) for d in dd],
                        routines=rr, candidates=cc),
                    width=int(eff), routines=[k[0] for k in missing])
                for key, cfgs in zip(missing, res.configs):
                    # beam picks are not a row over self.candidates, so
                    # there is no times vector to memoise (None = lazy
                    # re-evaluation in select_with_times, like warm start)
                    chosen[key] = (cfgs[0], None)
            else:
                times = self.predicted_times_many(
                    [k[1:] for k in missing],
                    routines=[k[0] for k in missing])
                best = np.argmin(times, axis=1)
                for key, j, t in zip(missing, best, times):
                    chosen[key] = (self.candidates[int(j)], t)
        # Pass 2 (locked): publish evaluations, refresh LRU recency.
        out = []
        served: set[Key] = set()
        with self._lock:
            for key, entry in chosen.items():
                self._cache[key] = entry
            for key in keys:
                # every occurrence beyond the one that paid an
                # evaluation is a cache hit, mirroring the scalar
                # path's per-call counters
                if key in seen and key not in served:
                    served.add(key)
                else:
                    self.stats["cache_hits"] += 1
                if key not in self._cache:
                    # hit evicted by a concurrent caller between the
                    # passes: reinsert the snapshot taken under lock
                    self._cache[key] = (hits[key], None)
                self._cache.move_to_end(key)
                out.append(self._cache[key][0])
            self._evict()
        return out

    def select_with_times(self, m: int, k: int, n: int,
                          routine: str = "gemm"
                          ) -> tuple[GemmConfig, np.ndarray]:
        key = self._key(m, k, n, routine)
        entry = None
        for _ in range(4):         # concurrent eviction between the
            self.select(m, k, n, routine)   # select and the read is
            with self._lock:                # possible; retry (bounded)
                entry = self._cache.get(key)
            if entry is not None:
                break
        if entry is None:          # pathological thrash: compute direct
            times = self.predicted_times(m, k, n, routine)
            return self.candidates[int(np.argmin(times))], times
        cfg, times = entry
        if times is None:          # warm-start entry: argmin only
            times = self.predicted_times(m, k, n, routine)
            with self._lock:
                self._cache[key] = (cfg, times)
        return cfg, times

    # ------------------------------------------------------------------
    def swap_from_artifact(self, artifact_dir: str, *,
                           carry_warm: bool = True,
                           **kw: Any) -> "AdsalaTuner":
        """Build this tuner's replacement from a freshly installed
        artifact (the in-memory half of an online re-install hot-swap).

        Returns a NEW tuner — the caller publishes it with one reference
        assignment (see :class:`repro.serve.reinstall.ReinstallManager`),
        so in-flight selects finish on whichever tuner they started on
        and a torn old/new mix is impossible.  The LRU cache lives
        inside each instance, i.e. it is keyed per-artifact by
        construction: no stale choice of the outgoing model can survive
        into the replacement.

        ``carry_warm`` transplants the *working set*, not the choices:
        the outgoing cache's hot ``(routine, m, k, n)`` keys (filtered
        to routines the new artifact has signal for) are re-selected
        through the new model in one batched pass before the swap
        becomes visible, so post-swap traffic starts on cache hits
        without ever serving the old artifact's picks.
        """
        new = type(self).from_artifact(artifact_dir, **kw)
        if carry_warm:
            with self._lock:
                hot = list(self._cache.keys())
            hot = [key for key in hot if key[0] in new.routines]
            if hot:
                new.cache_size = max(new.cache_size,
                                     len(new._cache) + len(hot))
                new.select_many([key[1:] for key in hot],
                                routines=[key[0] for key in hot])
                new.stats = {"calls": 0, "cache_hits": 0,
                             "evaluations": 0}
        return new
