"""Runtime tuner (paper §III-C Fig 3 + §IV-A).

Loads the installation artifact once, then per GEMM call predicts the
runtime of every candidate worker configuration and dispatches on the
argmin.  Implements the paper's memoisation: "the software is designed to
remember the last GEMM input and ML predictions; if the current GEMM
matrix dimensions are the same as the previous, the software will read
and apply the predictions ... without re-evaluation."  Beyond the paper
we keep a bounded LRU dict of *all* seen shapes, not just the last one
(training loops interleave a handful of distinct GEMM shapes — the
last-only cache thrashes; recorded in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import collections
from typing import Any

import numpy as np

from repro.core.costmodel import GemmConfig
from repro.core.features import build_features
from repro.core.installer import load_artifact
from repro.core.preprocessing import PreprocessPipeline

__all__ = ["AdsalaTuner"]

_PARTITIONS = ("M", "N", "K", "2D")


class AdsalaTuner:
    """Predict-then-argmin worker-configuration selector."""

    def __init__(self, model: Any, pipe: PreprocessPipeline,
                 candidates: list[GemmConfig], *,
                 max_chips: int | None = None,
                 cache_size: int = 256) -> None:
        if max_chips is not None:
            candidates = [c for c in candidates if c.n_chips <= max_chips]
        if not candidates:
            raise ValueError("empty candidate set")
        self.model = model
        self.pipe = pipe
        self.candidates = candidates
        self.cache_size = cache_size
        self._cache: collections.OrderedDict[
            tuple[int, int, int], tuple[GemmConfig, np.ndarray]] = \
            collections.OrderedDict()
        self.stats = {"calls": 0, "cache_hits": 0, "evaluations": 0}
        # pre-built candidate feature columns (constant across calls)
        C = len(candidates)
        self._chips = np.asarray([c.n_chips for c in candidates], float)
        self._tiles = np.asarray([c.tile_id for c in candidates], float)
        self._parts = np.asarray(
            [_PARTITIONS.index(c.partition) for c in candidates], float)
        self._ones = np.ones(C)

    @classmethod
    def from_artifact(cls, artifact_dir: str, **kw: Any) -> "AdsalaTuner":
        model, pipe, cands, _ = load_artifact(artifact_dir)
        return cls(model, pipe, cands, **kw)

    # ------------------------------------------------------------------
    def predicted_times(self, m: int, k: int, n: int) -> np.ndarray:
        """Predicted runtime (seconds) for every candidate config."""
        X = build_features(self._ones * m, self._ones * k, self._ones * n,
                           self._chips, self._tiles, self._parts)
        return np.exp(self.model.predict(self.pipe.transform(X)))

    def select(self, m: int, k: int, n: int) -> GemmConfig:
        """Optimal worker configuration for this GEMM (memoised)."""
        self.stats["calls"] += 1
        key = (int(m), int(k), int(n))
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.stats["cache_hits"] += 1
            return hit[0]
        self.stats["evaluations"] += 1
        times = self.predicted_times(m, k, n)
        cfg = self.candidates[int(np.argmin(times))]
        self._cache[key] = (cfg, times)
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return cfg

    def select_with_times(self, m: int, k: int, n: int
                          ) -> tuple[GemmConfig, np.ndarray]:
        cfg = self.select(m, k, n)
        return cfg, self._cache[(int(m), int(k), int(n))][1]
