"""Workload profiles: what the node actually dispatches, as install input.

The paper's premise is that the installed model should reflect the GEMM
tasks the node will run (§III-B), and the BLAS-3 follow-up (arXiv
2406.19621) installs per-routine models — yet a uniform Halton grid
spreads the install budget evenly over the whole memory-limited box
regardless of where serving volume concentrates.  A
:class:`WorkloadProfile` closes that loop: it summarises recorded
dispatches (from a live :class:`~repro.kernels.recorder.DispatchRecorder`
or the per-cell ``dispatch`` blocks ``repro.launch.dryrun`` persists)
into

* **routine weights** — the fraction of dispatch volume per BLAS-3
  routine, weighted by flops (default) or by count-weighted events, and
* a **shape-region histogram** — dispatch volume bucketed into log2
  octave cells of the (m, k, n) box, i.e. region
  ``[2^i, 2^(i+1)) x [2^j, 2^(j+1)) x [2^l, 2^(l+1))`` per cell.

The installer consumes both: routine quotas replace blind round-robin
cycling, and a mixture sampler (:func:`repro.core.halton.
sample_gemm_dims_mixture`) biases a configurable fraction of the Halton
budget into the observed regions — low-discrepancy *within* each region,
with a uniform floor over the full box so coverage never collapses onto
the profile.  Profiles JSON round-trip, merge across cells/archs, and
are persisted into the install artifact so the runtime tuner can warn
when the serving mix drifts from what was installed.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core.costmodel import ROUTINES
from repro.core.features import ROUTINE_FLOP_SCALE

__all__ = ["WorkloadProfile", "shape_cell", "apportion"]

#: log2 octave cell of one (m, k, n) triple
Cell = tuple[int, int, int]


def shape_cell(m: int, k: int, n: int) -> Cell:
    """The log2 octave cell containing ``(m, k, n)``."""
    return (int(math.floor(math.log2(max(int(m), 1)))),
            int(math.floor(math.log2(max(int(k), 1)))),
            int(math.floor(math.log2(max(int(n), 1)))))


def apportion(weights: Iterable[float], n: int) -> list[int]:
    """Split ``n`` units proportionally to ``weights`` (largest-remainder
    method, a.k.a. Hamilton apportionment).  Exact: the result sums to
    ``n``; all-zero/empty weights split ``n`` as evenly as possible."""
    w = np.asarray(list(weights), dtype=np.float64)
    if w.size == 0:
        return []
    if not np.any(w > 0):
        w = np.ones_like(w)
    w = np.maximum(w, 0.0)
    exact = n * w / w.sum()
    base = np.floor(exact).astype(int)
    rem = n - int(base.sum())
    if rem:
        # ties broken by index order (stable argsort) for determinism
        order = np.argsort(-(exact - base), kind="stable")
        base[order[:rem]] += 1
    return base.tolist()


def _event_weight(routine: str, m: int, k: int, n: int, count: int,
                  by: str) -> float:
    if by == "events":
        return float(count)
    scale = ROUTINE_FLOP_SCALE[ROUTINES.index(routine)]
    return 2.0 * count * m * k * n * scale


@dataclasses.dataclass
class WorkloadProfile:
    """Normalised per-routine / per-shape-region dispatch volume.

    ``routine_weights`` and ``cells`` each sum to 1 (or are empty for an
    empty profile); ``total`` keeps the raw pre-normalisation volume so
    profiles merge proportionally to how much traffic each one saw.
    ``source`` is free-form provenance (arch, cell, recorder, ...)
    persisted alongside the install artifact.
    """

    routine_weights: dict[str, float] = \
        dataclasses.field(default_factory=dict)
    cells: dict[Cell, float] = dataclasses.field(default_factory=dict)
    by: str = "flops"
    total: float = 0.0
    source: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.by not in ("flops", "events"):
            raise ValueError(f"by={self.by!r}; expected 'flops' or "
                             "'events'")
        for r in self.routine_weights:
            if r not in ROUTINES:
                raise ValueError(f"unknown routine {r!r}; "
                                 f"expected one of {ROUTINES}")

    # -- constructors --------------------------------------------------
    @classmethod
    def from_events(cls, events: Iterable[Any], *, by: str = "flops",
                    source: dict | None = None) -> "WorkloadProfile":
        """Build from DispatchEvent-shaped records (``routine``, ``m``,
        ``k``, ``n``, ``count`` attributes)."""
        routines: dict[str, float] = {}
        cells: dict[Cell, float] = {}
        total = 0.0
        for e in events:
            w = _event_weight(e.routine, e.m, e.k, e.n, e.count, by)
            routines[e.routine] = routines.get(e.routine, 0.0) + w
            cell = shape_cell(e.m, e.k, e.n)
            cells[cell] = cells.get(cell, 0.0) + w
            total += w
        return cls(routine_weights=_normalise(routines),
                   cells=_normalise(cells), by=by, total=total,
                   source=dict(source or {}))

    @classmethod
    def from_recorder(cls, recorder: Any, *, by: str = "flops",
                      source: dict | None = None) -> "WorkloadProfile":
        """Build from an (exited or still-active) DispatchRecorder."""
        src = {"kind": "recorder"}
        src.update(source or {})
        return cls.from_events(recorder.events, by=by, source=src)

    @classmethod
    def from_dispatch_block(cls, block: Mapping[str, Any], *,
                            by: str = "flops",
                            source: dict | None = None
                            ) -> "WorkloadProfile":
        """Build from the per-cell ``dispatch`` block a dry-run persists.

        Blocks written since the shape table landed carry a ``shapes``
        list (one aggregated row per distinct (routine, m, k, n)); those
        yield the full profile.  Older blocks only recorded the routine
        mix — the profile then has routine weights but no shape cells,
        and the installer falls back to uniform shape sampling.
        """
        src = {"kind": "dryrun"}
        src.update(source or {})
        shapes = block.get("shapes")
        if shapes:
            rows = [_Row(s["routine"], s["m"], s["k"], s["n"],
                         s.get("dispatches", s.get("events", 1)))
                    for s in shapes]
            return cls.from_events(rows, by=by, source=src)
        mix_key = "routine_mix" if by == "flops" else "routine_mix_events"
        mix = dict(block.get(mix_key) or {})
        summary = block.get("summary") or {}
        # "events" weighting means count-weighted dispatches everywhere
        # in this module (a vmapped site traced once still carries its
        # batch multiplicity) — summary's "events" field is raw traced
        # sites, the wrong volume for merge weights
        vol_key = "flops" if by == "flops" else "dispatches"
        total = sum(row.get(vol_key, 0.0) for row in summary.values())
        return cls(routine_weights=_normalise(mix), cells={}, by=by,
                   total=float(total), source=src)

    @classmethod
    def merge(cls, profiles: Iterable["WorkloadProfile"], *,
              weights: Iterable[float] | None = None,
              source: dict | None = None) -> "WorkloadProfile":
        """Volume-weighted combination across cells / archs.

        ``weights`` defaults to each profile's raw ``total`` (a cell
        that dispatched 10x the flops contributes 10x), falling back to
        equal weights when no profile recorded a total.
        """
        profiles = list(profiles)
        if not profiles:
            return cls(source=dict(source or {"kind": "merge"}))
        bys = {p.by for p in profiles}
        if len(bys) > 1:
            raise ValueError(f"cannot merge profiles with mixed "
                             f"weightings {sorted(bys)}")
        if weights is None:
            w = [p.total for p in profiles]
            if not any(w):
                w = [1.0] * len(profiles)
        else:
            w = list(weights)
            if len(w) != len(profiles):
                raise ValueError(f"got {len(w)} weights for "
                                 f"{len(profiles)} profiles")
        routines: dict[str, float] = {}
        cells: dict[Cell, float] = {}
        for p, wi in zip(profiles, w):
            for r, v in p.routine_weights.items():
                routines[r] = routines.get(r, 0.0) + wi * v
            for c, v in p.cells.items():
                cells[c] = cells.get(c, 0.0) + wi * v
        src = {"kind": "merge", "n_profiles": len(profiles),
               "sources": [p.source for p in profiles]}
        src.update(source or {})
        return cls(routine_weights=_normalise(routines),
                   cells=_normalise(cells), by=profiles[0].by,
                   total=float(sum(p.total for p in profiles)),
                   source=src)

    # -- install-side consumers ----------------------------------------
    def routine_quotas(self, routines: Iterable[str], n: int, *,
                       floor: float = 0.25) -> dict[str, int]:
        """Per-routine sample quotas for an ``n``-sample install budget.

        A ``floor`` fraction of the budget is split evenly across the
        requested ``routines`` (so a routine the profile never observed
        — or observed at zero weight — still gets install coverage and
        the model retains signal for it); the remainder is allocated
        proportionally to the profile's routine weights.  Quotas sum to
        exactly ``n``.
        """
        routines = list(routines)
        if not routines:
            raise ValueError("empty routine list")
        if not 0.0 <= floor <= 1.0:
            raise ValueError(f"floor={floor} outside [0, 1]")
        weights = [self.routine_weights.get(r, 0.0) for r in routines]
        if not any(weights):
            # empty profile (or no overlap): pure even split
            even = apportion([1.0] * len(routines), n)
            return dict(zip(routines, even))
        n_floor = int(round(floor * n))
        base = apportion([1.0] * len(routines), n_floor)
        prop = apportion(weights, n - n_floor)
        return {r: b + p for r, b, p in zip(routines, base, prop)}

    def region_boxes(self) -> list[tuple[tuple[float, float, float],
                                         tuple[float, float, float],
                                         float]]:
        """``(log2_lo, log2_hi, weight)`` per occupied shape cell, the
        input format of :func:`repro.core.halton.sample_gemm_dims_mixture`.
        """
        return [((float(a), float(b), float(c)),
                 (float(a + 1), float(b + 1), float(c + 1)), w)
                for (a, b, c), w in sorted(self.cells.items())]

    def sample_dims(self, n_samples: int, *, mem_limit_bytes: int,
                    bias: float = 0.75, dtype_bytes: int = 4,
                    seed: int = 0, dim_min: int = 8,
                    dim_max: int = 65536,
                    log_space: bool = False) -> np.ndarray:
        """Profile-biased (m, k, n) samples; uniform when cell-less."""
        from repro.core.halton import (sample_gemm_dims,
                                       sample_gemm_dims_mixture)
        if not self.cells or bias <= 0.0:
            return sample_gemm_dims(
                n_samples, mem_limit_bytes=mem_limit_bytes,
                dtype_bytes=dtype_bytes, seed=seed, dim_min=dim_min,
                dim_max=dim_max, log_space=log_space)
        return sample_gemm_dims_mixture(
            n_samples, self.region_boxes(), bias=bias,
            mem_limit_bytes=mem_limit_bytes, dtype_bytes=dtype_bytes,
            seed=seed, dim_min=dim_min, dim_max=dim_max,
            log_space=log_space)

    # -- serve-side consumer -------------------------------------------
    def drift(self, observed: "Mapping[str, float] | WorkloadProfile"
              ) -> float:
        """Total-variation distance between this (installed) profile and
        an observed serving mix, in [0, 1].  0 = identical, 1 = disjoint
        support; symmetric in its two distributions.

        ``observed`` is either a bare routine mix (e.g.
        ``DispatchRecorder.routine_mix()``) — routine-weight TV only —
        or a full :class:`WorkloadProfile`, in which case the result is
        the max of the routine-mix TV and the shape-cell-histogram TV
        (when both profiles carry cells): a serving mix that kept its
        routine split but moved to very different GEMM shapes has
        drifted just as surely, and the re-install trigger
        (:class:`repro.serve.reinstall.ReinstallManager`) must see it.
        """
        p = _normalise(dict(self.routine_weights))
        if isinstance(observed, WorkloadProfile):
            d = _tv(p, _normalise(dict(observed.routine_weights)))
            if self.cells and observed.cells:
                d = max(d, _tv(_normalise(dict(self.cells)),
                               _normalise(dict(observed.cells))))
            return d
        return _tv(p, _normalise(dict(observed)))

    # -- serialisation -------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "version": 1,
            "by": self.by,
            "total": self.total,
            "routine_weights": dict(self.routine_weights),
            "cells": [{"cell": list(c), "weight": w}
                      for c, w in sorted(self.cells.items())],
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "WorkloadProfile":
        cells = {tuple(int(x) for x in row["cell"]): float(row["weight"])
                 for row in d.get("cells", [])}
        return cls(routine_weights={str(r): float(w) for r, w in
                                    (d.get("routine_weights") or
                                     {}).items()},
                   cells=cells, by=d.get("by", "flops"),
                   total=float(d.get("total", 0.0)),
                   source=dict(d.get("source") or {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "WorkloadProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def table(self) -> str:
        """Human-readable summary (routine mix + top shape regions)."""
        lines = [f"workload profile (by {self.by}, total "
                 f"{self.total:.3g}):"]
        for r, w in sorted(self.routine_weights.items(),
                           key=lambda kv: -kv[1]):
            lines.append(f"  {r:8s} {w:6.1%}")
        top = sorted(self.cells.items(), key=lambda kv: -kv[1])[:8]
        for (a, b, c), w in top:
            lines.append(f"  m~2^{a:<2d} k~2^{b:<2d} n~2^{c:<2d} "
                         f"{w:6.1%}")
        if len(self.cells) > 8:
            lines.append(f"  ... {len(self.cells) - 8} more regions")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class _Row:
    """Minimal event-shaped record for from_dispatch_block."""

    routine: str
    m: int
    k: int
    n: int
    count: int = 1


def _normalise(d: dict) -> dict:
    total = sum(d.values())
    if total <= 0:
        return {}
    return {k: v / total for k, v in d.items()}


def _tv(p: Mapping[Any, float], q: Mapping[Any, float]) -> float:
    """Total-variation distance between two normalised distributions.

    Clamped to 1.0: the float sum over near-disjoint supports can land
    an epsilon above it, and drift is documented as in [0, 1].
    """
    return min(1.0, 0.5 * sum(abs(p.get(k, 0.0) - q.get(k, 0.0))
                              for k in set(p) | set(q)))
