"""Per-architecture artifact registry: fingerprint-keyed install cells.

The source paper's headline result is inherently *per-architecture* —
separate models trained on a Cascade Lake node and a Zen 3 node — and
the BLAS-3 follow-up (arXiv 2406.19621) shows the per-routine models
must be re-fit per machine.  Until now every artifact in this repo was
one anonymous directory with no record of which hardware timed it.

This module gives artifacts an address:

* :class:`HardwareFingerprint` — what this node *is*: CPU model, core
  count, cache sizes, device-mesh shape, plus a timed micro-probe
  signature (achieved GFLOP/s at a few GEMM sizes).  The stable fields
  form a deterministic :meth:`~HardwareFingerprint.key` (same machine,
  same key, across processes); the probe feeds
  :meth:`~HardwareFingerprint.distance` so *similar* machines rank near
  each other even when their keys differ.
* :class:`ArtifactRegistry` — one root directory holding one install
  cell per fingerprint key::

      <root>/<key>/fingerprint.json
      <root>/<key>/artifact/           live artifact (paper's two files)
      <root>/<key>/artifact.tmp|.prev  PR-8 lifecycle siblings

  Each cell reuses the installer's atomic tmp/COMMIT/``.prev``
  lifecycle helpers, so per-cell commit, rollback and crash-window
  repair behave exactly like the single-artifact serving loop.
* **transfer installs** — :meth:`ArtifactRegistry.nearest` picks the
  closest populated cell and :meth:`ArtifactRegistry.install` with
  ``transfer_from`` warm-starts from that donor's gathered timing rows,
  measuring only a few dozen calibration cells on the local backend
  (the model-driven adaptive-libraries line, arXiv 1806.07060: transfer
  the device model, spend a small calibration budget instead of a full
  re-install).

jax-free on purpose, like ``repro.serve.reinstall``: fingerprinting and
registry resolution must run anywhere the timing backends do.
"""

from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import re
import shutil
import time
import warnings
from typing import Any

import numpy as np

from repro.core.installer import (
    ARTIFACT_COMMIT,
    InstallConfig,
    InstallReport,
    artifact_tmp_dir,
    commit_artifact,
    install,
    is_artifact,
    resolve_artifact,
    rollback_artifact,
)

__all__ = ["HardwareFingerprint", "ArtifactRegistry", "ResolvedArtifact",
           "resolve_serving_artifact"]

#: sidecar naming one registry cell's hardware
FINGERPRINT_FILE = "fingerprint.json"
#: the live artifact inside a cell (tmp/prev siblings derive from it)
ARTIFACT_SUBDIR = "artifact"

#: GEMM edge sizes the micro-probe times (small on purpose: the probe
#: runs at fingerprint-collection time, e.g. serve boot)
PROBE_SIZES = (64, 128, 256)


def _read_cpu_model() -> str:
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    import platform

    return platform.processor() or platform.machine() or "unknown-cpu"


def _read_cache_kb() -> tuple[int, int, int]:
    """(L1d, L2, L3) sizes in KB from sysfs; 0 when unknown."""
    sizes = {1: 0, 2: 0, 3: 0}
    for idx in glob.glob(
            "/sys/devices/system/cpu/cpu0/cache/index*"):
        try:
            with open(os.path.join(idx, "level")) as f:
                level = int(f.read().strip())
            with open(os.path.join(idx, "type")) as f:
                typ = f.read().strip()
            with open(os.path.join(idx, "size")) as f:
                raw = f.read().strip()
        except (OSError, ValueError):
            continue
        if level == 1 and typ != "Data":
            continue
        if level not in sizes:
            continue
        m = re.fullmatch(r"(\d+)([KMG]?)", raw)
        if not m:
            continue
        val = int(m.group(1))
        if m.group(2):                       # sysfs reports "32K" etc
            val *= {"K": 1, "M": 1024, "G": 1024 * 1024}[m.group(2)]
        else:                                # bare bytes, just in case
            val = max(1, val // 1024)
        sizes[level] = val
    return (sizes[1], sizes[2], sizes[3])


def _slug(text: str, max_len: int = 24) -> str:
    s = re.sub(r"[^a-z0-9]+", "-", text.lower()).strip("-")
    return s[:max_len].rstrip("-") or "cpu"


@dataclasses.dataclass(frozen=True)
class HardwareFingerprint:
    """What one node is, as install provenance and a registry address.

    The **stable** fields (CPU model, cores, caches, mesh shape) define
    :meth:`key` — deterministic across processes on the same machine,
    so a node always lands in the same registry cell.  The **probe**
    signature (achieved GFLOP/s at :data:`PROBE_SIZES`) varies slightly
    run to run and is deliberately excluded from the key; it only
    contributes to :meth:`distance`, where a few percent of timing
    noise cannot reorder machines whose hardware actually differs.
    """

    cpu_model: str
    cores: int
    cache_kb: tuple[int, int, int] = (0, 0, 0)
    mesh_shape: tuple[int, ...] = (1,)
    probe_sizes: tuple[int, ...] = ()
    probe_gflops: tuple[float, ...] = ()

    @classmethod
    def collect(cls, *, mesh_shape: tuple[int, ...] = (1,),
                probe_sizes: tuple[int, ...] = PROBE_SIZES,
                probe_repeats: int = 3, seed: int = 0
                ) -> "HardwareFingerprint":
        """Fingerprint the current host.  ``probe_sizes=()`` skips the
        timed micro-probe (key-only use, e.g. addressing a cell without
        paying ~10ms of GEMM warm-up)."""
        gflops = []
        rng = np.random.default_rng(seed)
        for s in probe_sizes:
            a = rng.standard_normal((s, s)).astype(np.float32)
            b = rng.standard_normal((s, s)).astype(np.float32)
            (a @ b).sum()                    # warmup: BLAS thread spin-up
            reps = []
            for _ in range(max(1, probe_repeats)):
                t0 = time.perf_counter()
                c = a @ b
                dt = time.perf_counter() - t0
                del c
                reps.append(max(dt, 1e-9))
            gflops.append(2.0 * s ** 3 / float(np.median(reps)) / 1e9)
        return cls(cpu_model=_read_cpu_model(),
                   cores=os.cpu_count() or 1,
                   cache_kb=_read_cache_kb(),
                   mesh_shape=tuple(int(d) for d in mesh_shape),
                   probe_sizes=tuple(int(s) for s in probe_sizes),
                   probe_gflops=tuple(round(g, 3) for g in gflops))

    def key(self) -> str:
        """Deterministic registry-cell slug from the stable fields only
        (probe timings jitter across processes; the key must not)."""
        stable = (self.cpu_model, self.cores, tuple(self.cache_kb),
                  tuple(self.mesh_shape))
        digest = hashlib.sha1(repr(stable).encode()).hexdigest()[:8]
        mesh = "x".join(str(d) for d in self.mesh_shape)
        return (f"{_slug(self.cpu_model)}-c{self.cores}"
                f"-m{mesh}-{digest}")

    def distance(self, other: "HardwareFingerprint") -> float:
        """Symmetric dissimilarity for :meth:`ArtifactRegistry.nearest`.

        Stable-field mismatches dominate (a different CPU model is a
        different architecture no matter what the probe says); the probe
        term is the mean |log2| GFLOP/s ratio over the sizes both sides
        measured, so two nodes of the same SKU under different turbo
        states stay close while a genuinely slower part drifts away.
        """
        d = 0.0
        if self.cpu_model != other.cpu_model:
            # dominates every same-SKU term below (cores/cache/probe
            # sum to < 2 for realistic same-model spreads): a different
            # microarchitecture always ranks behind any same-model node
            d += 2.0
        if tuple(self.mesh_shape) != tuple(other.mesh_shape):
            d += 0.5
        d += 0.5 * abs(np.log2(max(self.cores, 1)
                               / max(other.cores, 1)))
        for c1, c2 in zip(self.cache_kb, other.cache_kb):
            if c1 > 0 and c2 > 0:
                d += 0.25 * abs(np.log2(c1 / c2))
        common = [(g1, g2) for s1, g1 in zip(self.probe_sizes,
                                             self.probe_gflops)
                  for s2, g2 in zip(other.probe_sizes, other.probe_gflops)
                  if s1 == s2 and g1 > 0 and g2 > 0]
        if common:
            d += float(np.mean([abs(np.log2(g1 / g2))
                                for g1, g2 in common]))
        return float(d)

    def to_dict(self) -> dict:
        return {"cpu_model": self.cpu_model, "cores": self.cores,
                "cache_kb": list(self.cache_kb),
                "mesh_shape": list(self.mesh_shape),
                "probe_sizes": list(self.probe_sizes),
                "probe_gflops": list(self.probe_gflops),
                "key": self.key()}

    @classmethod
    def from_dict(cls, d: dict) -> "HardwareFingerprint":
        return cls(cpu_model=d["cpu_model"], cores=int(d["cores"]),
                   cache_kb=tuple(int(c) for c in d.get(
                       "cache_kb", (0, 0, 0))),
                   mesh_shape=tuple(int(m) for m in d.get(
                       "mesh_shape", (1,))),
                   probe_sizes=tuple(int(s) for s in d.get(
                       "probe_sizes", ())),
                   probe_gflops=tuple(float(g) for g in d.get(
                       "probe_gflops", ())))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "HardwareFingerprint":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class ArtifactRegistry:
    """One artifact cell per hardware fingerprint under a shared root.

    Every cell is a full PR-8 artifact lifecycle in miniature: installs
    stage into ``artifact.tmp``, commit atomically behind the ``COMMIT``
    sentinel, keep the displaced artifact at ``artifact.prev`` for
    one-call rollback, and :meth:`resolve` repairs the mid-commit crash
    window at boot — all namespaced so a heterogeneous fleet sharing the
    root (e.g. on NFS) never mixes timings across architectures.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- addressing -----------------------------------------------------
    def cell_dir(self, fp: HardwareFingerprint) -> str:
        return os.path.join(self.root, fp.key())

    def artifact_dir(self, fp: HardwareFingerprint) -> str:
        """The cell's live-artifact path (tmp/prev siblings derive from
        it via the installer lifecycle helpers)."""
        return os.path.join(self.cell_dir(fp), ARTIFACT_SUBDIR)

    def register(self, fp: HardwareFingerprint) -> str:
        """Create the cell (idempotent), persist its fingerprint sidecar
        and return the cell's artifact path."""
        cell = self.cell_dir(fp)
        os.makedirs(cell, exist_ok=True)
        fp.save(os.path.join(cell, FINGERPRINT_FILE))
        return self.artifact_dir(fp)

    def fingerprints(self) -> list[HardwareFingerprint]:
        """Registered cell fingerprints, sorted by key (deterministic)."""
        out = []
        for path in sorted(glob.glob(os.path.join(
                self.root, "*", FINGERPRINT_FILE))):
            try:
                out.append(HardwareFingerprint.load(path))
            except (OSError, KeyError, ValueError, json.JSONDecodeError):
                warnings.warn(f"skipping unreadable registry cell "
                              f"sidecar {path}", stacklevel=2)
        return out

    def resolve(self, fp: HardwareFingerprint) -> str | None:
        """Crash-repaired live artifact path for this fingerprint's own
        cell, or None when the cell is empty (cold node)."""
        return resolve_artifact(self.artifact_dir(fp))

    def nearest(self, fp: HardwareFingerprint, *,
                exclude_self: bool = True
                ) -> tuple[HardwareFingerprint, str] | None:
        """The closest cell that actually holds a servable artifact:
        ``(cell_fingerprint, artifact_path)``, or None when the registry
        has no populated cell (other than ``fp``'s own, when excluded).
        Distance ties break by key for determinism."""
        own = fp.key()
        best: tuple[float, str, HardwareFingerprint, str] | None = None
        for cand in self.fingerprints():
            if exclude_self and cand.key() == own:
                continue
            art = resolve_artifact(self.artifact_dir(cand))
            if art is None:
                continue
            entry = (fp.distance(cand), cand.key(), cand, art)
            if best is None or entry[:2] < best[:2]:
                best = entry
        if best is None:
            return None
        return best[2], best[3]

    # -- per-cell lifecycle --------------------------------------------
    def rollback(self, fp: HardwareFingerprint) -> None:
        """Swap the cell's ``artifact.prev`` back in (pure renames)."""
        rollback_artifact(self.artifact_dir(fp))

    def install(self, fp: HardwareFingerprint, backend: Any,
                cfg: InstallConfig, *,
                transfer_from: "str | Any | None" = None,
                verbose: bool = False) -> InstallReport:
        """Run a full install into this fingerprint's cell, atomically.

        The install stages into ``artifact.tmp``, stamps the ``COMMIT``
        sentinel only after both artifact files (plus the gathered-rows
        ``grid.npz``) are complete, and promotes with
        :func:`~repro.core.installer.commit_artifact` — a killed
        install leaves the cell's previous artifact serving.

        ``transfer_from`` is a donor artifact path (or ``"nearest"`` to
        let the registry pick the closest populated cell): the install
        then warm-starts from the donor's gathered rows and only times
        calibration cells locally (see
        :func:`~repro.core.installer.transfer_gather`).
        """
        art = self.register(fp)
        if transfer_from == "nearest":
            near = self.nearest(fp)
            transfer_from = near[1] if near is not None else None
        icfg = dataclasses.replace(cfg, fingerprint=fp)
        tmp = artifact_tmp_dir(art)
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        report = install(backend, icfg, artifact_dir=tmp,
                         transfer_from=transfer_from, verbose=verbose)
        with open(os.path.join(tmp, ARTIFACT_COMMIT), "w") as f:
            f.write("ok")
        commit_artifact(tmp, art)
        report.artifact_dir = art
        return report

    def adopt(self, fp: HardwareFingerprint, donor_artifact: str) -> str:
        """Cold-start a cell by *copying* a donor artifact into it (the
        zero-measurement fallback when no local install has run yet —
        e.g. serve boot on a cold node that wants its own cell for the
        re-install loop to target).  Atomic like any install: copy to
        tmp, COMMIT, promote.  Returns the cell's live artifact path."""
        if not is_artifact(donor_artifact):
            raise FileNotFoundError(
                f"no donor artifact at {donor_artifact}")
        art = self.register(fp)
        tmp = artifact_tmp_dir(art)
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        shutil.copytree(donor_artifact, tmp)
        sentinel = os.path.join(tmp, ARTIFACT_COMMIT)
        if not os.path.isfile(sentinel):
            with open(sentinel, "w") as f:
                f.write("ok")
        commit_artifact(tmp, art)
        return art


@dataclasses.dataclass
class ResolvedArtifact:
    """What :func:`resolve_serving_artifact` found for this node."""

    #: servable artifact path (None: registry entirely cold)
    path: str | None
    #: this node's fingerprint (the cell it *should* serve from)
    local: HardwareFingerprint
    #: fingerprint of the cell ``path`` came from (== local on an exact
    #: hit; a neighbour's on fallback; None when nothing resolved)
    cell: HardwareFingerprint | None
    #: True when ``path`` is the local fingerprint's own cell
    exact: bool


def resolve_serving_artifact(root: str, *,
                             fingerprint: HardwareFingerprint
                             | None = None,
                             mesh_shape: tuple[int, ...] = (1,),
                             allow_fallback: bool = True
                             ) -> ResolvedArtifact:
    """Resolve the artifact a serving process on *this* machine should
    load: fingerprint the host, prefer its own registry cell, and fall
    back to the nearest populated neighbour (with a warning — neighbour
    timings transfer only approximately; run a transfer install to make
    the cell local).
    """
    reg = ArtifactRegistry(root)
    fp = fingerprint if fingerprint is not None else \
        HardwareFingerprint.collect(mesh_shape=mesh_shape)
    own = reg.resolve(fp)
    if own is not None:
        return ResolvedArtifact(path=own, local=fp, cell=fp, exact=True)
    if allow_fallback:
        near = reg.nearest(fp)
        if near is not None:
            cell, art = near
            warnings.warn(
                f"registry {root} has no artifact for this machine "
                f"({fp.key()}); serving from nearest cell {cell.key()} "
                f"at distance {fp.distance(cell):.3f} — run a transfer "
                "install (ArtifactRegistry.install(..., "
                "transfer_from='nearest')) to localise it", stacklevel=2)
            return ResolvedArtifact(path=art, local=fp, cell=cell,
                                    exact=False)
    return ResolvedArtifact(path=None, local=fp, cell=None, exact=False)
