"""Common regressor interface, metrics, splits and hyper-parameter search."""

from __future__ import annotations

import itertools
from typing import Any, Callable, Iterator, Protocol, runtime_checkable

import numpy as np

__all__ = [
    "Regressor", "rmse", "normalised_rmse", "stratified_train_test_split",
    "KFold", "grid_search",
]


@runtime_checkable
class Regressor(Protocol):
    def fit(self, X: np.ndarray, y: np.ndarray) -> "Regressor": ...
    def predict(self, X: np.ndarray) -> np.ndarray: ...
    def get_params(self) -> dict[str, Any]: ...
    def to_dict(self) -> dict: ...


def rmse(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    return float(np.sqrt(np.mean((y_true - y_pred) ** 2)))


def normalised_rmse(y_true: np.ndarray, y_pred: np.ndarray,
                    baseline_pred: np.ndarray | None = None) -> float:
    """RMSE normalised by the worst linear baseline, as in Tables III/IV.

    The paper normalises so the weakest model (ElasticNet) sits at 1.00;
    we normalise by the RMSE of predicting the training mean, which gives
    the same ordering and a scale-free number.
    """
    base = rmse(y_true, np.full_like(y_true, np.mean(y_true))
                if baseline_pred is None else baseline_pred)
    return rmse(y_true, y_pred) / max(base, 1e-30)


def _stratify_bins(y: np.ndarray, n_bins: int) -> np.ndarray:
    """Quantile-bin a continuous target for stratified splitting (§IV-C)."""
    y = np.asarray(y, dtype=np.float64)
    qs = np.quantile(y, np.linspace(0, 1, n_bins + 1)[1:-1])
    return np.searchsorted(qs, y)


def stratified_train_test_split(
    X: np.ndarray, y: np.ndarray, *, test_fraction: float = 0.3,
    n_bins: int = 10, seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stratified split on quantile bins of the (continuous) label.

    The paper uses stratified sampling "to ensure a similar distribution
    in the train set, test set, and validation sets" with a 30 % test
    fraction.
    """
    rng = np.random.default_rng(seed)
    bins = _stratify_bins(y, n_bins)
    test_idx: list[np.ndarray] = []
    for b in np.unique(bins):
        idx = np.nonzero(bins == b)[0]
        rng.shuffle(idx)
        n_test = int(round(test_fraction * len(idx)))
        test_idx.append(idx[:n_test])
    test = np.concatenate(test_idx) if test_idx else np.empty(0, dtype=int)
    mask = np.ones(len(y), dtype=bool)
    mask[test] = False
    train = np.nonzero(mask)[0]
    return X[train], X[test], np.asarray(y)[train], np.asarray(y)[test]


class KFold:
    """Stratified k-fold on label quantile bins."""

    def __init__(self, n_splits: int = 5, *, n_bins: int = 10, seed: int = 0):
        self.n_splits = n_splits
        self.n_bins = n_bins
        self.seed = seed

    def split(self, y: np.ndarray) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        bins = _stratify_bins(y, self.n_bins)
        folds: list[list[int]] = [[] for _ in range(self.n_splits)]
        for b in np.unique(bins):
            idx = np.nonzero(bins == b)[0]
            rng.shuffle(idx)
            for i, j in enumerate(idx):
                folds[i % self.n_splits].append(j)
        all_idx = np.arange(len(y))
        for f in folds:
            val = np.asarray(sorted(f))
            train = np.setdiff1d(all_idx, val, assume_unique=False)
            yield train, val


def grid_search(
    make_model: Callable[..., Regressor],
    param_grid: dict[str, list[Any]],
    X: np.ndarray, y: np.ndarray, *,
    n_splits: int = 5, seed: int = 0,
    max_candidates: int | None = None,
) -> tuple[dict[str, Any], float]:
    """Exhaustive grid search with stratified k-fold CV; returns best params.

    The paper tunes every candidate model's hyper-parameters with CV
    folds ("we use cross validation folds rather than the leave-one-out
    method ... to reduce its computational cost").
    """
    keys = list(param_grid)
    combos = list(itertools.product(*(param_grid[k] for k in keys)))
    if max_candidates is not None and len(combos) > max_candidates:
        rng = np.random.default_rng(seed)
        pick = rng.choice(len(combos), size=max_candidates, replace=False)
        combos = [combos[i] for i in pick]
    kf = KFold(n_splits=n_splits, seed=seed)
    best_params: dict[str, Any] = {}
    best_score = np.inf
    for combo in combos:
        params = dict(zip(keys, combo))
        scores = []
        for train, val in kf.split(y):
            if len(train) == 0 or len(val) == 0:
                # singleton strata all land in fold 0, so tiny
                # (calibration-scale) datasets can produce empty folds
                continue
            model = make_model(**params)
            model.fit(X[train], y[train])
            scores.append(rmse(y[val], model.predict(X[val])))
        score = float(np.mean(scores)) if scores else np.inf
        if score < best_score:
            best_score, best_params = score, params
    return best_params, best_score
