"""k-nearest-neighbour regressor (paper Table I 'KNN Regressor').

Included so the model-selection benchmark can reproduce the paper's
finding that kNN's slow evaluation makes it unsuitable despite decent
accuracy.
"""

from __future__ import annotations

from typing import Any

import numpy as np

__all__ = ["KNNRegressor"]


class KNNRegressor:
    def __init__(self, k: int = 5, weights: str = "distance") -> None:
        self.k = k
        self.weights = weights
        self.X_: np.ndarray | None = None
        self.y_: np.ndarray | None = None

    def get_params(self) -> dict[str, Any]:
        return {"k": self.k, "weights": self.weights}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "KNNRegressor":
        self.X_ = np.asarray(X, dtype=np.float64)
        self.y_ = np.asarray(y, dtype=np.float64)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if self.X_ is None:
            raise RuntimeError("not fitted")
        X = np.asarray(X, dtype=np.float64)
        k = min(self.k, len(self.y_))
        sq_train = np.sum(self.X_ * self.X_, axis=1)
        out = np.empty(X.shape[0])
        for i in range(X.shape[0]):            # brute force — kNN is the
            d2 = sq_train - 2.0 * (self.X_ @ X[i]) + X[i] @ X[i]   # slow model
            nn = np.argpartition(d2, k - 1)[:k]
            if self.weights == "distance":
                w = 1.0 / (np.sqrt(np.maximum(d2[nn], 0.0)) + 1e-9)
                out[i] = float(np.sum(w * self.y_[nn]) / np.sum(w))
            else:
                out[i] = float(self.y_[nn].mean())
        return out

    def to_dict(self) -> dict:
        return {"kind": "KNNRegressor", "params": self.get_params(),
                "X": self.X_.tolist(), "y": self.y_.tolist()}

    @classmethod
    def from_dict(cls, d: dict) -> "KNNRegressor":
        obj = cls(**d["params"])
        obj.X_ = np.asarray(d["X"])
        obj.y_ = np.asarray(d["y"])
        return obj
