"""Random Forest regressor (paper Table I) on the shared tree engine."""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.ml.tree import (
    PackedEnsemble,
    TreeArrays,
    build_tree,
)

__all__ = ["RandomForestRegressor"]


class RandomForestRegressor:
    """Bagged CART ensemble with per-node feature subsampling."""

    def __init__(self, n_estimators: int = 100, max_depth: int = 10,
                 min_samples_leaf: int = 1,
                 max_features: float | str = 0.5,
                 bootstrap: bool = True, seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.seed = seed
        self.trees_: list[TreeArrays] = []
        self._packed: PackedEnsemble | None = None

    def get_params(self) -> dict[str, Any]:
        return {"n_estimators": self.n_estimators,
                "max_depth": self.max_depth,
                "min_samples_leaf": self.min_samples_leaf,
                "max_features": self.max_features,
                "bootstrap": self.bootstrap, "seed": self.seed}

    def _n_features_per_split(self, n_feat: int) -> int:
        if self.max_features == "sqrt":
            return max(1, int(np.sqrt(n_feat)))
        return max(1, int(round(float(self.max_features) * n_feat)))

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = X.shape[0]
        rng = np.random.default_rng(self.seed)
        mf = self._n_features_per_split(X.shape[1])
        self.trees_ = []
        for _ in range(self.n_estimators):
            idx = (rng.integers(0, n, size=n) if self.bootstrap
                   else np.arange(n))
            self.trees_.append(build_tree(
                X[idx], -y[idx], np.ones(len(idx)),
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                max_features=mf, rng=rng))
        self._packed = PackedEnsemble(self.trees_)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees_:
            raise RuntimeError("not fitted")
        if self._packed is None:
            self._packed = PackedEnsemble(self.trees_)
        return self._packed.predict_mean(X)

    def to_dict(self) -> dict:
        return {"kind": "RandomForestRegressor", "params": self.get_params(),
                "trees": [t.to_dict() for t in self.trees_]}

    @classmethod
    def from_dict(cls, d: dict) -> "RandomForestRegressor":
        obj = cls(**d["params"])
        obj.trees_ = [TreeArrays.from_dict(t) for t in d["trees"]]
        obj._packed = PackedEnsemble(obj.trees_)
        return obj
