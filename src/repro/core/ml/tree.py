"""CART regression trees on a shared gradient/hessian split engine.

One vectorised builder serves the whole tree family of the paper's
Table I:

* CART / Random-Forest trees: squared loss on (optionally weighted)
  targets is the special case g = -w*y, h = w, λ = 0 — the leaf value
  becomes the weighted mean and the split gain the weighted variance
  reduction.
* XGBoost-style boosting passes true (g, h) with L2 regularisation λ and
  min-split-gain γ (Chen & Guestrin 2016, eq. 7).

Trees are stored as flat arrays (feature / threshold / children / value)
so runtime prediction — the latency the paper's model-selection criterion
charges against each model — is a handful of vectorised numpy gathers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["TreeArrays", "build_tree", "tree_predict", "tree_predict_row",
           "PackedEnsemble", "DecisionTreeRegressor"]


@dataclasses.dataclass
class TreeArrays:
    feature: np.ndarray    # int32, -1 for leaves
    threshold: np.ndarray  # float64
    left: np.ndarray       # int32
    right: np.ndarray      # int32
    value: np.ndarray      # float64 (leaf prediction; internal nodes too)

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def to_dict(self) -> dict:
        return {
            "feature": self.feature.tolist(),
            "threshold": self.threshold.tolist(),
            "left": self.left.tolist(),
            "right": self.right.tolist(),
            "value": self.value.tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TreeArrays":
        return cls(
            feature=np.asarray(d["feature"], dtype=np.int32),
            threshold=np.asarray(d["threshold"], dtype=np.float64),
            left=np.asarray(d["left"], dtype=np.int32),
            right=np.asarray(d["right"], dtype=np.int32),
            value=np.asarray(d["value"], dtype=np.float64),
        )


def _tree_depth(tree: TreeArrays) -> int:
    """Depth of a TreeArrays (root = depth 0)."""
    best = 0
    stack = [(0, 0)]
    while stack:
        node, d = stack.pop()
        best = max(best, d)
        if tree.feature[node] >= 0:
            stack.append((int(tree.left[node]), d + 1))
            stack.append((int(tree.right[node]), d + 1))
    return best


def _leaf_value(g_sum: float, h_sum: float, lam: float) -> float:
    return -g_sum / (h_sum + lam) if (h_sum + lam) > 0 else 0.0


def _best_split(X: np.ndarray, g: np.ndarray, h: np.ndarray, *,
                lam: float, min_child_weight: float,
                min_samples_leaf: int,
                feature_subset: np.ndarray | None = None
                ) -> tuple[float, int, float]:
    """Best (gain, feature, threshold) over all features via prefix sums."""
    n, n_feat = X.shape
    G, H = g.sum(), h.sum()
    parent_score = G * G / (H + lam)
    best_gain, best_feat, best_thr = 0.0, -1, 0.0
    feats = feature_subset if feature_subset is not None else range(n_feat)
    for j in feats:
        order = np.argsort(X[:, j], kind="stable")
        xs = X[order, j]
        gl = np.cumsum(g[order])[:-1]
        hl = np.cumsum(h[order])[:-1]
        gr = G - gl
        hr = H - hl
        # valid split positions: value actually changes + leaf constraints
        valid = xs[1:] > xs[:-1]
        pos = np.arange(1, n)
        valid &= (pos >= min_samples_leaf) & (n - pos >= min_samples_leaf)
        valid &= (hl >= min_child_weight) & (hr >= min_child_weight)
        if not valid.any():
            continue
        gain = gl * gl / (hl + lam) + gr * gr / (hr + lam) - parent_score
        gain = np.where(valid, gain, -np.inf)
        i = int(np.argmax(gain))
        if gain[i] > best_gain:
            best_gain = float(gain[i])
            best_feat = int(j)
            best_thr = 0.5 * (xs[i] + xs[i + 1])
    return best_gain, best_feat, best_thr


def build_tree(X: np.ndarray, g: np.ndarray, h: np.ndarray, *,
               max_depth: int = 6, lam: float = 0.0, gamma: float = 0.0,
               min_samples_leaf: int = 1, min_child_weight: float = 0.0,
               max_features: int | None = None,
               rng: np.random.Generator | None = None) -> TreeArrays:
    """Depth-first greedy tree construction on (g, h)."""
    X = np.asarray(X, dtype=np.float64)
    g = np.asarray(g, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    n_feat = X.shape[1]

    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[float] = []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        value.append(0.0)
        return len(feature) - 1

    def grow(idx: np.ndarray, depth: int) -> int:
        node = new_node()
        gs, hs = g[idx].sum(), h[idx].sum()
        value[node] = _leaf_value(gs, hs, lam)
        if depth >= max_depth or len(idx) < 2 * min_samples_leaf:
            return node
        subset = None
        if max_features is not None and max_features < n_feat:
            r = rng if rng is not None else np.random.default_rng(0)
            subset = r.choice(n_feat, size=max_features, replace=False)
        gain, feat, thr = _best_split(
            X[idx], g[idx], h[idx], lam=lam,
            min_child_weight=min_child_weight,
            min_samples_leaf=min_samples_leaf, feature_subset=subset)
        if feat < 0 or 0.5 * gain <= gamma:
            return node
        mask = X[idx, feat] <= thr
        feature[node] = feat
        threshold[node] = thr
        left[node] = grow(idx[mask], depth + 1)
        right[node] = grow(idx[~mask], depth + 1)
        return node

    grow(np.arange(X.shape[0]), 0)
    return TreeArrays(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float64),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.asarray(value, dtype=np.float64),
    )


class PackedEnsemble:
    """All trees of an ensemble packed into one node table for inference.

    Prediction descends every (sample, tree) pair simultaneously with
    vectorised gathers — ``max_depth`` iterations instead of a Python
    loop over trees.  This is the runtime path whose latency the paper's
    model-selection criterion (t_eval) charges; a per-tree Python loop
    would mis-measure tree ensembles by ~100x versus their compiled
    counterparts (XGBoost C++), inverting the paper's selection outcome.
    """

    def __init__(self, trees: list[TreeArrays]) -> None:
        offsets = np.cumsum([0] + [t.n_nodes for t in trees[:-1]])
        self.roots = np.asarray(offsets, dtype=np.intp)
        self.n_trees = len(trees)
        feature = np.concatenate([t.feature for t in trees]).astype(np.intp)
        threshold = np.concatenate([t.threshold for t in trees])
        self.value = np.concatenate([t.value for t in trees])
        left = np.concatenate(
            [t.left + o for t, o in zip(trees, offsets)]).astype(np.intp)
        right = np.concatenate(
            [t.right + o for t, o in zip(trees, offsets)]).astype(np.intp)
        # self-looping leaves: feature 0, threshold +inf, children = self —
        # a lane that lands on a leaf stays put if it is ever walked again.
        leaf = feature < 0
        self_idx = np.arange(len(feature), dtype=np.intp)
        self.interior = ~leaf
        self.feature = np.where(leaf, 0, feature)
        self.threshold = np.where(leaf, np.inf, threshold)
        self.left = np.where(leaf, self_idx, left)
        self.right = np.where(leaf, self_idx, right)
        depths = [_tree_depth(t) for t in trees]
        self.min_depth = min(depths)
        self.max_depth = max(depths)

    def predict_all(self, X: np.ndarray) -> np.ndarray:
        """Per-tree predictions, shape (n_samples, n_trees).

        Flat ``take``-based descent: every (sample, tree) pair is one
        lane walking one level per iteration with 4 gathers + 1 compare.
        The first ``min_depth`` levels run mask-free over all n*T lanes
        — exact even when a lane hits a shallow leaf early, because
        leaves self-loop.  Past ``min_depth`` (where whole trees start
        finishing) lanes sitting on a leaf are retired from the working
        set, so the deep tail levels (set by the single deepest tree)
        touch a shrinking fraction of the lanes.  Balanced ensembles
        keep the mask-free walk end-to-end; mixed-depth ones (AdaBoost
        stumps next to full CARTs, leaf-wise LightGBM trees) skip most
        of the tail work.
        """
        X = np.ascontiguousarray(X, dtype=np.float64)
        n, f_dim = X.shape
        T = len(self.roots)
        node = np.tile(self.roots, n)                       # (n*T,) flat
        row_off = np.repeat(np.arange(n, dtype=np.intp) * f_dim, T)
        x_flat = X.ravel()
        for _ in range(self.min_depth):
            f = self.feature.take(node)
            fv = x_flat.take(row_off + f)
            go_left = fv <= self.threshold.take(node)
            node = np.where(go_left, self.left.take(node),
                            self.right.take(node))
        lanes = np.flatnonzero(self.interior.take(node))
        for _ in range(self.max_depth - self.min_depth):
            if not lanes.size:
                break
            at = node.take(lanes)
            f = self.feature.take(at)
            fv = x_flat.take(row_off.take(lanes) + f)
            go_left = fv <= self.threshold.take(at)
            at = np.where(go_left, self.left.take(at), self.right.take(at))
            node[lanes] = at
            lanes = lanes[self.interior.take(at)]
        return self.value.take(node).reshape(n, T)

    def predict_sum(self, X: np.ndarray) -> np.ndarray:
        return self.predict_all(X).sum(axis=1)

    def predict_mean(self, X: np.ndarray) -> np.ndarray:
        return self.predict_all(X).mean(axis=1)


def tree_predict(tree: TreeArrays, X: np.ndarray) -> np.ndarray:
    """Vectorised iterative descent of all samples through one tree."""
    X = np.asarray(X, dtype=np.float64)
    n = X.shape[0]
    node = np.zeros(n, dtype=np.int32)
    active = tree.feature[node] >= 0
    while active.any():
        f = tree.feature[node[active]]
        thr = tree.threshold[node[active]]
        go_left = X[active, f] <= thr
        nxt = np.where(go_left, tree.left[node[active]],
                       tree.right[node[active]])
        node[active] = nxt
        active = tree.feature[node] >= 0
    return tree.value[node]


def tree_predict_row(tree: TreeArrays, x: np.ndarray) -> float:
    """Scalar one-row descent — the reference the vectorised walkers
    (``tree_predict``, ``PackedEnsemble.predict_all``) are parity-tested
    against."""
    node = 0
    while tree.feature[node] >= 0:
        if x[tree.feature[node]] <= tree.threshold[node]:
            node = int(tree.left[node])
        else:
            node = int(tree.right[node])
    return float(tree.value[node])


class DecisionTreeRegressor:
    """CART regressor (paper Table I 'Decision Tree')."""

    def __init__(self, max_depth: int = 6, min_samples_leaf: int = 1) -> None:
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.tree_: TreeArrays | None = None

    def get_params(self) -> dict[str, Any]:
        return {"max_depth": self.max_depth,
                "min_samples_leaf": self.min_samples_leaf}

    def fit(self, X: np.ndarray, y: np.ndarray,
            sample_weight: np.ndarray | None = None
            ) -> "DecisionTreeRegressor":
        y = np.asarray(y, dtype=np.float64)
        w = (np.ones_like(y) if sample_weight is None
             else np.asarray(sample_weight, dtype=np.float64))
        # squared loss from pred=0: g = -w*y, h = w  → leaf = weighted mean
        self.tree_ = build_tree(
            X, -w * y, w, max_depth=self.max_depth,
            min_samples_leaf=self.min_samples_leaf)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if self.tree_ is None:
            raise RuntimeError("not fitted")
        return tree_predict(self.tree_, X)

    def to_dict(self) -> dict:
        return {"kind": "DecisionTreeRegressor", "params": self.get_params(),
                "tree": self.tree_.to_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionTreeRegressor":
        obj = cls(**d["params"])
        obj.tree_ = TreeArrays.from_dict(d["tree"])
        return obj
