"""Boosted tree family: XGBoost-style GBT, AdaBoost.R2, LightGBM-style
histogram GBT (paper Table I tree-based models)."""

from __future__ import annotations

import heapq
from typing import Any

import numpy as np

from repro.core.ml.tree import (
    DecisionTreeRegressor,
    PackedEnsemble,
    TreeArrays,
    build_tree,
    tree_predict,
)

__all__ = [
    "XGBRegressor", "AdaBoostR2Regressor", "HistGradientBoostingRegressor",
]


class XGBRegressor:
    """Second-order gradient boosting with L2 leaf regularisation.

    Squared loss: g_i = pred_i - y_i, h_i = 1 (Chen & Guestrin 2016).
    Supports shrinkage (eta), row subsampling and column subsampling —
    the knobs the paper tunes via CV.
    """

    def __init__(self, n_estimators: int = 200, max_depth: int = 5,
                 learning_rate: float = 0.1, reg_lambda: float = 1.0,
                 gamma: float = 0.0, subsample: float = 1.0,
                 colsample: float = 1.0, min_child_weight: float = 1.0,
                 seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.learning_rate = learning_rate
        self.reg_lambda = reg_lambda
        self.gamma = gamma
        self.subsample = subsample
        self.colsample = colsample
        self.min_child_weight = min_child_weight
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: list[TreeArrays] = []
        self._packed: PackedEnsemble | None = None

    def get_params(self) -> dict[str, Any]:
        return {"n_estimators": self.n_estimators, "max_depth": self.max_depth,
                "learning_rate": self.learning_rate,
                "reg_lambda": self.reg_lambda, "gamma": self.gamma,
                "subsample": self.subsample, "colsample": self.colsample,
                "min_child_weight": self.min_child_weight, "seed": self.seed}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "XGBRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, n_feat = X.shape
        rng = np.random.default_rng(self.seed)
        self.base_ = float(y.mean())
        pred = np.full(n, self.base_)
        self.trees_ = []
        mf = max(1, int(round(self.colsample * n_feat)))
        for _ in range(self.n_estimators):
            g = pred - y
            h = np.ones(n)
            if self.subsample < 1.0:
                idx = rng.choice(n, size=max(2, int(self.subsample * n)),
                                 replace=False)
            else:
                idx = np.arange(n)
            tree = build_tree(
                X[idx], g[idx], h[idx], max_depth=self.max_depth,
                lam=self.reg_lambda, gamma=self.gamma,
                min_child_weight=self.min_child_weight,
                max_features=mf if mf < n_feat else None, rng=rng)
            pred += self.learning_rate * tree_predict(tree, X)
            self.trees_.append(tree)
        self._packed = PackedEnsemble(self.trees_)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees_:
            raise RuntimeError("not fitted")
        if self._packed is None:
            self._packed = PackedEnsemble(self.trees_)
        return self.base_ + self.learning_rate * self._packed.predict_sum(X)

    def to_dict(self) -> dict:
        return {"kind": "XGBRegressor", "params": self.get_params(),
                "base": self.base_,
                "trees": [t.to_dict() for t in self.trees_]}

    @classmethod
    def from_dict(cls, d: dict) -> "XGBRegressor":
        obj = cls(**d["params"])
        obj.base_ = float(d["base"])
        obj.trees_ = [TreeArrays.from_dict(t) for t in d["trees"]]
        obj._packed = PackedEnsemble(obj.trees_)
        return obj


class AdaBoostR2Regressor:
    """AdaBoost.R2 (Drucker 1997) with CART weak learners and the
    weighted-median combination rule."""

    def __init__(self, n_estimators: int = 50, max_depth: int = 4,
                 learning_rate: float = 1.0, seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.learning_rate = learning_rate
        self.seed = seed
        self.estimators_: list[DecisionTreeRegressor] = []
        self.betas_: list[float] = []
        self._packed: PackedEnsemble | None = None

    def get_params(self) -> dict[str, Any]:
        return {"n_estimators": self.n_estimators, "max_depth": self.max_depth,
                "learning_rate": self.learning_rate, "seed": self.seed}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "AdaBoostR2Regressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = X.shape[0]
        rng = np.random.default_rng(self.seed)
        w = np.full(n, 1.0 / n)
        self.estimators_, self.betas_ = [], []
        for _ in range(self.n_estimators):
            # resample according to weights (classic R2 formulation)
            idx = rng.choice(n, size=n, replace=True, p=w)
            est = DecisionTreeRegressor(max_depth=self.max_depth)
            est.fit(X[idx], y[idx])
            pred = est.predict(X)
            err = np.abs(pred - y)
            emax = err.max()
            if emax <= 0:
                self.estimators_.append(est)
                self.betas_.append(1e-10)
                break
            loss = err / emax                      # linear loss
            ebar = float(np.sum(w * loss))
            if ebar >= 0.5:
                if not self.estimators_:           # keep at least one
                    self.estimators_.append(est)
                    self.betas_.append(1.0)
                break
            beta = ebar / (1.0 - ebar)
            self.estimators_.append(est)
            self.betas_.append(beta)
            w = w * np.power(beta, self.learning_rate * (1.0 - loss))
            w /= w.sum()
        self._packed = PackedEnsemble([e.tree_ for e in self.estimators_])
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.estimators_:
            raise RuntimeError("not fitted")
        if self._packed is None:
            self._packed = PackedEnsemble([e.tree_ for e in self.estimators_])
        preds = self._packed.predict_all(X)
        logw = np.log(1.0 / np.maximum(np.asarray(self.betas_), 1e-12))
        order = np.argsort(preds, axis=1)
        sorted_preds = np.take_along_axis(preds, order, axis=1)
        cum = np.cumsum(logw[order], axis=1)
        target = 0.5 * cum[:, -1:]
        pick = np.argmax(cum >= target, axis=1)
        return sorted_preds[np.arange(len(pick)), pick]

    def to_dict(self) -> dict:
        return {"kind": "AdaBoostR2Regressor", "params": self.get_params(),
                "betas": list(map(float, self.betas_)),
                "estimators": [e.to_dict() for e in self.estimators_]}

    @classmethod
    def from_dict(cls, d: dict) -> "AdaBoostR2Regressor":
        obj = cls(**d["params"])
        obj.betas_ = list(d["betas"])
        obj.estimators_ = [DecisionTreeRegressor.from_dict(e)
                           for e in d["estimators"]]
        obj._packed = PackedEnsemble([e.tree_ for e in obj.estimators_])
        return obj


class HistGradientBoostingRegressor:
    """LightGBM-style GBT: quantile-binned features + leaf-wise growth.

    Features are pre-binned into ``max_bins`` quantile buckets; each
    boosting round grows a tree *best-first* (largest-gain leaf expanded
    next, up to ``max_leaves``), with split search over histogram bins —
    the two ideas that distinguish LightGBM from depth-wise XGBoost.
    """

    def __init__(self, n_estimators: int = 200, max_leaves: int = 31,
                 learning_rate: float = 0.1, reg_lambda: float = 1.0,
                 max_bins: int = 64, min_samples_leaf: int = 5,
                 seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_leaves = max_leaves
        self.learning_rate = learning_rate
        self.reg_lambda = reg_lambda
        self.max_bins = max_bins
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: list[TreeArrays] = []
        self.bin_edges_: list[np.ndarray] = []
        self._packed: PackedEnsemble | None = None

    def get_params(self) -> dict[str, Any]:
        return {"n_estimators": self.n_estimators,
                "max_leaves": self.max_leaves,
                "learning_rate": self.learning_rate,
                "reg_lambda": self.reg_lambda, "max_bins": self.max_bins,
                "min_samples_leaf": self.min_samples_leaf, "seed": self.seed}

    # -- binning -----------------------------------------------------------
    def _fit_bins(self, X: np.ndarray) -> np.ndarray:
        self.bin_edges_ = []
        binned = np.empty(X.shape, dtype=np.int16)
        for j in range(X.shape[1]):
            qs = np.quantile(X[:, j],
                             np.linspace(0, 1, self.max_bins + 1)[1:-1])
            edges = np.unique(qs)
            self.bin_edges_.append(edges)
            binned[:, j] = np.searchsorted(edges, X[:, j]).astype(np.int16)
        return binned

    def _grow_tree(self, binned: np.ndarray, g: np.ndarray, h: np.ndarray
                   ) -> TreeArrays:
        lam = self.reg_lambda
        feature: list[int] = []
        threshold: list[float] = []
        left: list[int] = []
        right: list[int] = []
        value: list[float] = []

        def new_node(gs: float, hs: float) -> int:
            feature.append(-1)
            threshold.append(0.0)
            left.append(-1)
            right.append(-1)
            value.append(-gs / (hs + lam))
            return len(feature) - 1

        def best_split(idx: np.ndarray):
            gs, hs = g[idx].sum(), h[idx].sum()
            parent = gs * gs / (hs + lam)
            best = (0.0, -1, -1)  # gain, feat, bin
            for j in range(binned.shape[1]):
                nb = len(self.bin_edges_[j]) + 1
                if nb < 2:
                    continue
                b = binned[idx, j]
                gh = np.zeros(nb)
                hh = np.zeros(nb)
                ch = np.zeros(nb)
                np.add.at(gh, b, g[idx])
                np.add.at(hh, b, h[idx])
                np.add.at(ch, b, 1.0)
                gl = np.cumsum(gh)[:-1]
                hl = np.cumsum(hh)[:-1]
                cl = np.cumsum(ch)[:-1]
                gr, hr, cr = gs - gl, hs - hl, len(idx) - cl
                ok = (cl >= self.min_samples_leaf) & (cr >= self.min_samples_leaf)
                if not ok.any():
                    continue
                gain = np.where(
                    ok, gl * gl / (hl + lam) + gr * gr / (hr + lam) - parent,
                    -np.inf)
                i = int(np.argmax(gain))
                if gain[i] > best[0]:
                    best = (float(gain[i]), j, i)
            return best

        # best-first growth
        all_idx = np.arange(binned.shape[0])
        root = new_node(g.sum(), h.sum())
        heap: list[tuple[float, int, int, Any]] = []
        gain, feat, b = best_split(all_idx)
        counter = 0
        if feat >= 0:
            heapq.heappush(heap, (-gain, counter, root, (all_idx, feat, b)))
        n_leaves = 1
        while heap and n_leaves < self.max_leaves:
            _, _, node, (idx, feat, b) = heapq.heappop(heap)
            mask = binned[idx, feat] <= b
            li, ri = idx[mask], idx[~mask]
            feature[node] = feat
            edges = self.bin_edges_[feat]
            threshold[node] = float(edges[min(b, len(edges) - 1)])
            ln = new_node(g[li].sum(), h[li].sum())
            rn = new_node(g[ri].sum(), h[ri].sum())
            left[node], right[node] = ln, rn
            n_leaves += 1
            for child, cidx in ((ln, li), (rn, ri)):
                if len(cidx) >= 2 * self.min_samples_leaf:
                    cg, cf, cb = best_split(cidx)
                    if cf >= 0:
                        counter += 1
                        heapq.heappush(heap, (-cg, counter, child,
                                              (cidx, cf, cb)))
        return TreeArrays(
            feature=np.asarray(feature, dtype=np.int32),
            threshold=np.asarray(threshold, dtype=np.float64),
            left=np.asarray(left, dtype=np.int32),
            right=np.asarray(right, dtype=np.int32),
            value=np.asarray(value, dtype=np.float64))

    def fit(self, X: np.ndarray, y: np.ndarray
            ) -> "HistGradientBoostingRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        binned = self._fit_bins(X)
        self.base_ = float(y.mean())
        pred = np.full(len(y), self.base_)
        self.trees_ = []
        for _ in range(self.n_estimators):
            g = pred - y
            h = np.ones(len(y))
            tree = self._grow_tree(binned, g, h)
            pred += self.learning_rate * tree_predict(tree, X)
            self.trees_.append(tree)
        self._packed = PackedEnsemble(self.trees_)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees_:
            raise RuntimeError("not fitted")
        if self._packed is None:
            self._packed = PackedEnsemble(self.trees_)
        return self.base_ + self.learning_rate * self._packed.predict_sum(X)

    def to_dict(self) -> dict:
        return {"kind": "HistGradientBoostingRegressor",
                "params": self.get_params(), "base": self.base_,
                "bin_edges": [e.tolist() for e in self.bin_edges_],
                "trees": [t.to_dict() for t in self.trees_]}

    @classmethod
    def from_dict(cls, d: dict) -> "HistGradientBoostingRegressor":
        obj = cls(**d["params"])
        obj.base_ = float(d["base"])
        obj.bin_edges_ = [np.asarray(e) for e in d["bin_edges"]]
        obj.trees_ = [TreeArrays.from_dict(t) for t in d["trees"]]
        obj._packed = PackedEnsemble(obj.trees_)
        return obj
