"""Pure-numpy ML model zoo for ADSALA (paper §II-B / Table I).

The container ships no sklearn/xgboost, so every candidate model from the
paper's comparison — linear family, tree family, kNN — is implemented
here from scratch, with a common ``fit``/``predict`` interface, flat-array
tree inference (the runtime evaluation path whose latency the paper's
model selection criterion penalises), and persistence to plain dicts.
"""

from repro.core.ml.base import (
    KFold,
    Regressor,
    grid_search,
    rmse,
    stratified_train_test_split,
)
from repro.core.ml.linear import (
    BayesianRidgeRegression,
    ElasticNetRegression,
    LinearRegression,
    RidgeRegression,
)
from repro.core.ml.tree import DecisionTreeRegressor
from repro.core.ml.forest import RandomForestRegressor
from repro.core.ml.boosting import (
    AdaBoostR2Regressor,
    HistGradientBoostingRegressor,
    XGBRegressor,
)
from repro.core.ml.knn import KNNRegressor
from repro.core.ml.registry import MODEL_REGISTRY, default_param_grids, make_model

__all__ = [
    "Regressor", "rmse", "stratified_train_test_split", "KFold",
    "grid_search",
    "LinearRegression", "RidgeRegression", "ElasticNetRegression",
    "BayesianRidgeRegression",
    "DecisionTreeRegressor", "RandomForestRegressor",
    "AdaBoostR2Regressor", "XGBRegressor", "HistGradientBoostingRegressor",
    "KNNRegressor",
    "MODEL_REGISTRY", "default_param_grids", "make_model",
]
