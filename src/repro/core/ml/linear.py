"""Linear model family: OLS, ridge, ElasticNet (coordinate descent),
Bayesian ridge (evidence maximisation).  Paper Table I "Linear Models"."""

from __future__ import annotations

from typing import Any

import numpy as np

__all__ = [
    "LinearRegression", "RidgeRegression", "ElasticNetRegression",
    "BayesianRidgeRegression",
]


class _LinearBase:
    coef_: np.ndarray
    intercept_: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, dtype=np.float64) @ self.coef_ + self.intercept_

    def to_dict(self) -> dict:
        return {
            "kind": type(self).__name__,
            "params": self.get_params(),
            "coef": self.coef_.tolist(),
            "intercept": float(self.intercept_),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "_LinearBase":
        obj = cls(**d["params"])
        obj.coef_ = np.asarray(d["coef"], dtype=np.float64)
        obj.intercept_ = float(d["intercept"])
        return obj


class LinearRegression(_LinearBase):
    """Ordinary least squares via lstsq."""

    def __init__(self) -> None:
        pass

    def get_params(self) -> dict[str, Any]:
        return {}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "LinearRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        Xa = np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)
        w, *_ = np.linalg.lstsq(Xa, y, rcond=None)
        self.coef_, self.intercept_ = w[:-1], float(w[-1])
        return self


class RidgeRegression(_LinearBase):
    def __init__(self, alpha: float = 1.0) -> None:
        self.alpha = alpha

    def get_params(self) -> dict[str, Any]:
        return {"alpha": self.alpha}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RidgeRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        mu, ym = X.mean(axis=0), y.mean()
        Xc, yc = X - mu, y - ym
        f = X.shape[1]
        A = Xc.T @ Xc + self.alpha * np.eye(f)
        self.coef_ = np.linalg.solve(A, Xc.T @ yc)
        self.intercept_ = float(ym - mu @ self.coef_)
        return self


class ElasticNetRegression(_LinearBase):
    """ElasticNet by cyclic coordinate descent with soft thresholding."""

    def __init__(self, alpha: float = 1.0, l1_ratio: float = 0.5,
                 max_iter: int = 500, tol: float = 1e-6) -> None:
        self.alpha = alpha
        self.l1_ratio = l1_ratio
        self.max_iter = max_iter
        self.tol = tol

    def get_params(self) -> dict[str, Any]:
        return {"alpha": self.alpha, "l1_ratio": self.l1_ratio,
                "max_iter": self.max_iter, "tol": self.tol}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "ElasticNetRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, f = X.shape
        mu, ym = X.mean(axis=0), y.mean()
        Xc, yc = X - mu, y - ym
        l1 = self.alpha * self.l1_ratio * n
        l2 = self.alpha * (1.0 - self.l1_ratio) * n
        col_sq = np.sum(Xc * Xc, axis=0) + l2
        w = np.zeros(f)
        resid = yc.copy()
        for _ in range(self.max_iter):
            w_max = 0.0
            delta_max = 0.0
            for j in range(f):
                if col_sq[j] <= l2 + 1e-30:  # constant column
                    continue
                rho = Xc[:, j] @ resid + w[j] * (col_sq[j] - l2)
                new_w = np.sign(rho) * max(abs(rho) - l1, 0.0) / col_sq[j]
                if new_w != w[j]:
                    resid -= (new_w - w[j]) * Xc[:, j]
                    delta_max = max(delta_max, abs(new_w - w[j]))
                    w[j] = new_w
                w_max = max(w_max, abs(w[j]))
            if delta_max <= self.tol * max(w_max, 1e-12):
                break
        self.coef_ = w
        self.intercept_ = float(ym - mu @ w)
        return self


class BayesianRidgeRegression(_LinearBase):
    """Bayesian ridge via evidence (type-II ML) iteration.

    Hyper-priors on weight precision α and noise precision β are updated
    with the MacKay fixed-point rules on the eigen-decomposition of XᵀX.
    """

    def __init__(self, max_iter: int = 300, tol: float = 1e-4) -> None:
        self.max_iter = max_iter
        self.tol = tol
        self.alpha_: float = 1.0
        self.beta_: float = 1.0

    def get_params(self) -> dict[str, Any]:
        return {"max_iter": self.max_iter, "tol": self.tol}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "BayesianRidgeRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, f = X.shape
        mu, ym = X.mean(axis=0), y.mean()
        Xc, yc = X - mu, y - ym
        G = Xc.T @ Xc
        eigvals, eigvecs = np.linalg.eigh(G)
        eigvals = np.maximum(eigvals, 0.0)
        Xty = Xc.T @ yc
        alpha, beta = 1.0, 1.0 / max(np.var(yc), 1e-12)
        w = np.zeros(f)
        for _ in range(self.max_iter):
            # posterior mean in the eigenbasis
            denom = alpha + beta * eigvals
            w_new = eigvecs @ ((beta * (eigvecs.T @ Xty)) / denom)
            gamma = float(np.sum(beta * eigvals / denom))
            resid = yc - Xc @ w_new
            sse = float(resid @ resid)
            alpha_new = gamma / max(float(w_new @ w_new), 1e-12)
            beta_new = max(n - gamma, 1e-12) / max(sse, 1e-12)
            done = (abs(alpha_new - alpha) <= self.tol * alpha
                    and abs(beta_new - beta) <= self.tol * beta)
            alpha, beta, w = alpha_new, beta_new, w_new
            if done:
                break
        self.alpha_, self.beta_ = alpha, beta
        self.coef_ = w
        self.intercept_ = float(ym - mu @ w)
        return self
