"""Model registry: names <-> constructors <-> default CV grids <-> persistence.

The names mirror the rows of the paper's Tables III/IV.
"""

from __future__ import annotations

from typing import Any, Callable

from repro.core.ml.boosting import (
    AdaBoostR2Regressor,
    HistGradientBoostingRegressor,
    XGBRegressor,
)
from repro.core.ml.forest import RandomForestRegressor
from repro.core.ml.knn import KNNRegressor
from repro.core.ml.linear import (
    BayesianRidgeRegression,
    ElasticNetRegression,
    LinearRegression,
    RidgeRegression,
)
from repro.core.ml.tree import DecisionTreeRegressor

__all__ = ["MODEL_REGISTRY", "default_param_grids", "make_model",
           "model_from_dict"]

MODEL_REGISTRY: dict[str, Callable[..., Any]] = {
    "linear_regression": LinearRegression,
    "ridge": RidgeRegression,
    "elasticnet": ElasticNetRegression,
    "bayesian_regression": BayesianRidgeRegression,
    "decision_tree": DecisionTreeRegressor,
    "random_forest": RandomForestRegressor,
    "adaboost": AdaBoostR2Regressor,
    "xgboost": XGBRegressor,
    "lightgbm": HistGradientBoostingRegressor,
    "knn": KNNRegressor,
}

_KIND_TO_CLS = {
    "LinearRegression": LinearRegression,
    "RidgeRegression": RidgeRegression,
    "ElasticNetRegression": ElasticNetRegression,
    "BayesianRidgeRegression": BayesianRidgeRegression,
    "DecisionTreeRegressor": DecisionTreeRegressor,
    "RandomForestRegressor": RandomForestRegressor,
    "AdaBoostR2Regressor": AdaBoostR2Regressor,
    "XGBRegressor": XGBRegressor,
    "HistGradientBoostingRegressor": HistGradientBoostingRegressor,
    "KNNRegressor": KNNRegressor,
}


def make_model(name: str, **params: Any) -> Any:
    if name not in MODEL_REGISTRY:
        raise KeyError(f"unknown model {name!r}; have {list(MODEL_REGISTRY)}")
    return MODEL_REGISTRY[name](**params)


def model_from_dict(d: dict) -> Any:
    cls = _KIND_TO_CLS[d["kind"]]
    return cls.from_dict(d)


def default_param_grids(budget: str = "small") -> dict[str, dict[str, list]]:
    """CV grids per model.  'small' keeps install-time tuning tractable on
    one CPU core; 'full' matches a production install."""
    if budget == "small":
        return {
            "linear_regression": {},
            "elasticnet": {"alpha": [0.001, 0.1], "l1_ratio": [0.2, 0.8]},
            "bayesian_regression": {},
            "decision_tree": {"max_depth": [4, 8], "min_samples_leaf": [2, 8]},
            "random_forest": {"n_estimators": [30], "max_depth": [8, 12]},
            "adaboost": {"n_estimators": [20], "max_depth": [4]},
            "xgboost": {"n_estimators": [100], "max_depth": [4, 6],
                        "learning_rate": [0.1]},
            "lightgbm": {"n_estimators": [100], "max_leaves": [15, 31]},
            "knn": {"k": [3, 7]},
        }
    return {
        "linear_regression": {},
        "elasticnet": {"alpha": [1e-4, 1e-3, 1e-2, 0.1, 1.0],
                       "l1_ratio": [0.1, 0.5, 0.9]},
        "bayesian_regression": {},
        "decision_tree": {"max_depth": [4, 6, 8, 12],
                          "min_samples_leaf": [1, 2, 4, 8]},
        "random_forest": {"n_estimators": [50, 100, 200],
                          "max_depth": [8, 12, 16],
                          "max_features": [0.3, 0.5, 0.8]},
        "adaboost": {"n_estimators": [25, 50, 100], "max_depth": [3, 4, 6],
                     "learning_rate": [0.5, 1.0]},
        "xgboost": {"n_estimators": [100, 200, 400],
                    "max_depth": [4, 5, 6, 8],
                    "learning_rate": [0.05, 0.1, 0.2],
                    "reg_lambda": [0.5, 1.0, 2.0],
                    "subsample": [0.8, 1.0]},
        "lightgbm": {"n_estimators": [100, 200, 400],
                     "max_leaves": [15, 31, 63],
                     "learning_rate": [0.05, 0.1, 0.2]},
        "knn": {"k": [3, 5, 7, 11], "weights": ["distance", "uniform"]},
    }
