from repro.roofline.analytic import (
    RooflineTerms,
    analytic_collective_bytes,
    analytic_hbm_bytes,
    fwd_flops,
    roofline_for_cell,
    step_flops,
)

__all__ = ["fwd_flops", "step_flops", "analytic_hbm_bytes",
           "analytic_collective_bytes", "roofline_for_cell",
           "RooflineTerms"]
