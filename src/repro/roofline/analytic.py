"""Analytic roofline terms per (arch x shape x mesh).

Why analytic: XLA's ``cost_analysis()`` counts loop bodies ONCE, so a
scan-over-layers module under-reports FLOPs/bytes by ~n_layers x (we
validated: granite-8b train_4k unrolled = 7.10e16 HLO FLOPs vs scanned
1.93e15 x 36 layers = 6.95e16, within 2%).  Rather than compile every
cell unrolled (161 s/cell here, and inner scans — attention chunks,
sLSTM time steps — would still be uncounted), this module enumerates
the einsums of each architecture exactly; the dry-run HLO numbers are
kept as per-device lower-bound cross-checks.

Terms (assignment formulas, TPU v5e constants):
    compute    = FLOPs / (chips * 197e12)
    memory     = HBM bytes / (chips * 819e9)
    collective = ICI bytes per chip / (4 links * 50e9)
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, ShapeSpec

__all__ = ["fwd_flops", "step_flops", "analytic_hbm_bytes",
           "analytic_collective_bytes", "roofline_for_cell",
           "RooflineTerms"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 4 * 50e9


# ---------------------------------------------------------------------------
# FLOPs (2mnk per matmul; attention quadratic terms averaged over causal)
# ---------------------------------------------------------------------------

def _attn_flops_per_token(cfg: ArchConfig, kind: str, ctx: float) -> float:
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        proj = 2 * (d * cfg.q_lora_rank + cfg.q_lora_rank * h * qk
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    + cfg.kv_lora_rank * h * (cfg.qk_nope_dim
                                              + cfg.v_head_dim)
                    + h * cfg.v_head_dim * d)
        attn = 2 * ctx * h * (qk + cfg.v_head_dim)
        return proj + attn
    window = cfg.local_window if kind == "local" else cfg.window
    eff_ctx = min(ctx, window) if window else ctx
    proj = 2 * d * hd * (h + 2 * cfg.n_kv_heads) + 2 * h * hd * d
    attn = 2 * eff_ctx * h * hd * 2
    return proj + attn


def _mixer_flops_per_token(cfg: ArchConfig, kind: str, ctx: float) -> float:
    d = cfg.d_model
    if kind in ("attn", "local"):
        return _attn_flops_per_token(cfg, kind, ctx)
    if kind == "rglru":
        w = cfg.lru_width or d
        # wx, wy, conv, gates (2 WxW), recurrence, wo
        return 2 * d * w * 2 + 2 * cfg.conv_width * w \
            + 2 * w * w * 2 + 10 * w + 2 * w * d
    if kind == "mlstm":
        di = 2 * d
        dh = di // cfg.n_heads
        chunk = 256.0
        return (2 * d * 2 * di + 3 * 2 * di * di + 2 * di * d
                + 2 * chunk * di * 2            # intra-chunk attention
                + 4 * cfg.n_heads * dh * dh)    # state update/query
    if kind == "slstm":
        return 2 * d * 4 * d * 2 + 2 * d * 2 * d + 2 * d * d
    raise ValueError(kind)


def _mlp_flops_per_token(cfg: ArchConfig, layer_idx: int) -> float:
    d = cfg.d_model
    if cfg.n_experts and layer_idx >= cfg.first_dense_layers:
        ff = cfg.d_ff_expert or cfg.d_ff
        experts = cfg.top_k + cfg.n_shared_experts
        return experts * 3 * 2 * d * ff + 2 * d * cfg.n_experts
    if cfg.mlp_kind == "none":
        return 0.0
    ff = (cfg.d_ff_dense if cfg.n_experts
          and layer_idx < cfg.first_dense_layers else cfg.d_ff)
    mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return mult * 2 * d * ff


def fwd_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Forward FLOPs for the whole global batch of this shape."""
    pattern = cfg.pattern or ("attn",)
    if shape.kind == "decode":
        n_tok = float(shape.global_batch)       # one new token each
        ctx = float(shape.seq_len)
    else:
        n_tok = float(shape.tokens)
        ctx = shape.seq_len / 2.0               # causal average
    per_tok = 0.0
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        per_tok += _mixer_flops_per_token(cfg, kind, ctx)
        per_tok += _mlp_flops_per_token(cfg, i)
    per_tok += 2 * cfg.d_model * cfg.vocab      # unembed / logits
    total = per_tok * n_tok
    if cfg.family == "audio":
        # encoder runs once per sample over encoder_len frames
        d, ff = cfg.d_model, cfg.d_ff
        enc_tok = (4 * 2 * d * d + 2 * ctx_enc(cfg) * cfg.n_heads
                   * cfg.resolved_head_dim * 2 + 2 * 2 * d * ff)
        total += (enc_tok * cfg.encoder_len * shape.global_batch
                  * cfg.n_encoder_layers)
        # cross attention per decoder token
        cross = (2 * d * d * 2 + 2 * cfg.encoder_len * cfg.n_heads
                 * cfg.resolved_head_dim * 2 + 2 * d * d)
        total += cross * n_tok * cfg.n_layers
    return total


def ctx_enc(cfg: ArchConfig) -> float:
    return cfg.encoder_len / 1.0    # non-causal: full context


def step_flops(cfg: ArchConfig, shape: ShapeSpec, *,
               remat: bool = True) -> float:
    """FLOPs of one step of this cell.

    train  : fwd + bwd (2x fwd) + remat re-forward (1x fwd) = 4x fwd
    prefill/decode: 1x fwd
    """
    f = fwd_flops(cfg, shape)
    if shape.kind == "train":
        return f * (4.0 if remat else 3.0)
    return f


# ---------------------------------------------------------------------------
# HBM traffic (per device, per step)
# ---------------------------------------------------------------------------

def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec,
                       n_devices: int) -> float:
    """Per-device HBM bytes for one step (weights + states + activations).

    Weights are fully sharded (TP x FSDP); activation traffic counts one
    write + one read of each layer's residual-stream tensors in bf16.
    """
    params = cfg.param_count()
    p_dev = params / n_devices
    if shape.kind == "train":
        # bf16 reads fwd/bwd/remat + fp32 grad write + adam m,v rw + write
        weight_traffic = p_dev * (2 + 2 + 2 + 4 + 16 + 2)
        tok_dev = shape.tokens / n_devices
        act_traffic = tok_dev * cfg.d_model * cfg.n_layers * 2 * 8
        return weight_traffic + act_traffic
    if shape.kind == "prefill":
        weight_traffic = p_dev * 2
        tok_dev = shape.tokens / n_devices
        act_traffic = tok_dev * cfg.d_model * cfg.n_layers * 2 * 4
        return weight_traffic + act_traffic
    # decode: every active weight read once; cache read + small write
    active_dev = cfg.active_param_count() / n_devices
    cache_bytes = _cache_bytes(cfg, shape) / n_devices
    return active_dev * 2 + cache_bytes * 2


def _cache_bytes(cfg: ArchConfig, shape: ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    if cfg.attn_kind == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
        return b * s * per_tok * 2.0 * cfg.n_layers
    pattern = cfg.pattern or ("attn",)
    total = 0.0
    hd = cfg.resolved_head_dim
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if kind == "attn":
            eff = min(s, cfg.window) if cfg.window else s
            total += b * eff * cfg.n_kv_heads * hd * 2 * 2.0
        elif kind == "local":
            total += b * min(s, cfg.local_window) \
                * cfg.n_kv_heads * hd * 2 * 2.0
        elif kind == "rglru":
            total += b * (cfg.lru_width or cfg.d_model) * 4.0
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            dh = di // cfg.n_heads
            total += b * cfg.n_heads * dh * dh * 4.0
        elif kind == "slstm":
            total += b * cfg.d_model * 4.0 * 4
    return total


# ---------------------------------------------------------------------------
# Collective traffic (per device, per step)
# ---------------------------------------------------------------------------

def analytic_collective_bytes(cfg: ArchConfig, shape: ShapeSpec,
                              mesh_shape: dict[str, int]) -> float:
    """Per-chip ICI bytes: TP activation all-reduces + FSDP weight
    gathers + DP gradient reduction + MoE all-to-alls."""
    tp = mesh_shape.get("model", 1)
    dp = 1
    for k, v in mesh_shape.items():
        if k != "model":
            dp *= v
    n_dev = tp * dp
    params = cfg.param_count()
    ring = lambda p: 2 * (p - 1) / p            # all-reduce ring factor
    gat = lambda p: (p - 1) / p                 # (all-)gather factor

    total = 0.0
    if shape.kind == "decode":
        tok_dev = shape.global_batch / dp
    else:
        tok_dev = shape.tokens / n_dev if shape.kind == "train" \
            else shape.tokens / n_dev
    act = tok_dev * cfg.d_model * 2.0           # one residual tensor bf16

    passes = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    # 2 TP all-reduces per layer per pass (attn out, mlp out)
    total += cfg.n_layers * passes * ring(tp) * act

    # FSDP: gather weights fwd + bwd; reduce-scatter grads (train only)
    if shape.kind == "train":
        w_dev = params * 2.0 / tp               # bf16 shard on this tp rank
        total += 2 * gat(dp) * w_dev            # fwd + bwd gathers
        total += ring(dp) * params * 4.0 / tp   # fp32 grad reduction
    elif shape.kind == "prefill":
        total += gat(dp) * params * 2.0 / tp
    else:
        # decode: REFUTED hypothesis (§Perf B1) — the compiled HLO shows
        # XLA keeps FSDP-sharded weights stationary and partial-sums the
        # (tiny) activations over the data axes instead of gathering
        # weights: per layer one extra psum of the ff-slice activations.
        total += cfg.n_layers * passes * ring(dp) * act

    # MoE all-to-all: bucket bytes out + back per MoE layer per pass
    if cfg.n_experts and shape.kind != "decode":
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        cap_factor = 1.25
        bucket = tok_dev * cfg.top_k * cap_factor * cfg.d_model * 2.0
        if cfg.n_experts % tp == 0:
            total += moe_layers * passes / 2 * 2 * gat(tp) * bucket
        else:
            # expert-TP: psum of expert outputs instead
            total += moe_layers * passes / 2 * ring(tp) * bucket
    return total


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    analytic_flops: float
    hlo_flops_per_dev: float
    peak_bytes: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / analytic compiled FLOPs (remat/overhead waste)."""
        return self.model_flops / max(self.analytic_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """compute term / total — 1.0 means perfectly compute-bound."""
        return self.compute_s / max(self.total_s, 1e-30)


def roofline_for_cell(cfg: ArchConfig, shape: ShapeSpec, mesh_name: str,
                      record: dict) -> RooflineTerms:
    n_dev = 512 if mesh_name == "multi" else 256
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if mesh_name == "multi" else {"data": 16, "model": 16})
    flops = step_flops(cfg, shape)
    hbm = analytic_hbm_bytes(cfg, shape, n_dev)
    coll = analytic_collective_bytes(cfg, shape, mesh_shape)
    n_tok = shape.tokens if shape.kind != "decode" else shape.global_batch
    factor = 6 if shape.kind == "train" else 2
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_devices=n_dev,
        compute_s=flops / (n_dev * PEAK_FLOPS),
        memory_s=hbm / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=factor * cfg.active_param_count() * n_tok,
        analytic_flops=flops,
        hlo_flops_per_dev=record.get("cost", {}).get(
            "flops_per_device", 0.0),
        peak_bytes=record.get("memory", {}).get("peak_bytes", 0),
    )
