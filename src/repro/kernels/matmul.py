"""Tiled Pallas TPU matmul with run-time-selectable BlockSpec tiling.

This is the compute object ADSALA tunes: the (bm, bk, bn) tile triple is
one axis of the tuner's worker configuration (DESIGN.md §Hardware
adaptation — the TPU analogue of the paper's cache-blocking interaction
with thread count).  The kernel accumulates in fp32 VMEM scratch over a
sequential K grid dimension; M and N grid dimensions are parallel.

Layout notes (TPU):
  * block shapes should be multiples of (8, 128) for f32 / (16, 128) for
    bf16; DEFAULT_TILES in core.costmodel respects this,
  * the fp32 accumulator lives in VMEM scratch and is flushed to the
    output block on the last K step,
  * dimension_semantics marks K "arbitrary" so Mosaic keeps revisits of
    the same (i, j) output block in order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret",
                                    "out_dtype"))
def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                  bk: int = 128, bn: int = 128, interpret: bool = False,
                  out_dtype: jnp.dtype | None = None) -> jax.Array:
    """C[m, n] = A[m, k] @ B[k, n] with explicit VMEM tiling.

    Operands with dimensions not divisible by the tile are zero-padded to
    the tile grid and the result sliced back — zero rows/columns do not
    perturb the product.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {b.shape}")
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype

    gm, gk, gn = pl.cdiv(m, bm), pl.cdiv(k, bk), pl.cdiv(n, bn)
    a = _pad_to(a, gm * bm, gk * bk)
    b = _pad_to(b, gk * bk, gn * bn)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
