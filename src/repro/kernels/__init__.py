"""Pallas TPU kernels (+ jnp oracles) for the perf-critical GEMM paths.

matmul          — tiled MXU matmul, tile = ADSALA worker-config axis
grouped_matmul  — expert-batched MoE GEMM over capacity buckets
flash_attention — online-softmax blocked attention (causal / windowed)
recorder        — DispatchRecorder: observe (routine, m, k, n, config,
                  cache_hit) per dispatch on the current thread
"""

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.ops import (
    dispatch_hint,
    flash_attention,
    grouped_dispatch_hint,
    grouped_matmul,
    matmul,
    observe,
    resolve_backend,
    supported_routine,
    syrk,
    trsm,
)
from repro.kernels.recorder import DispatchEvent, DispatchRecorder
from repro.kernels.ref import (
    flash_attention_ref,
    grouped_matmul_ref,
    matmul_ref,
    syrk_ref,
    trsm_ref,
)

__all__ = [
    "matmul_pallas", "grouped_matmul_pallas", "flash_attention_pallas",
    "matmul", "syrk", "trsm", "grouped_matmul", "flash_attention",
    "dispatch_hint", "grouped_dispatch_hint", "observe",
    "resolve_backend", "supported_routine",
    "DispatchEvent", "DispatchRecorder",
    "matmul_ref", "syrk_ref", "trsm_ref", "grouped_matmul_ref",
    "flash_attention_ref",
]
