"""Blocked (flash) attention Pallas kernel with online softmax.

Used by the prefill path of every attention architecture (32k-token
shapes make materialising the (S, S) score matrix impossible: 32768² x
4 B = 4 GB per head).  Supports causal masking and an optional sliding
window (mixtral SWA, recurrentgemma local attention).

TPU adaptation: the KV sequence axis is a *sequential* grid dimension
with running (max, denominator, accumulator) carried in VMEM scratch —
the memory-hierarchy translation of the GPU warp-level online-softmax.
Out-of-window KV blocks are skipped with ``pl.when`` (no MXU work), the
Pallas equivalent of block-sparse skipping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  n_kv: int, bq: int, bkv: int, causal: bool,
                  window: int | None, sm_scale: float):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    kv_start = ikv * bkv

    def _not_skipped() -> None:
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bkv)

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
        if causal:
            mask &= kv_ids <= q_ids
        if window is not None:
            mask &= kv_ids > q_ids - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_ref[...] = corr * l_ref[...] + jnp.sum(
            p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    if causal or window is not None:
        visible = jnp.bool_(True)
        if causal:
            visible &= kv_start <= q_start + bq - 1
        if window is not None:
            visible &= kv_start + bkv - 1 > q_start - window
        pl.when(visible)(_not_skipped)
    else:
        _not_skipped()

    @pl.when(ikv == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "window",
                                    "sm_scale", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int = 512, bkv: int = 512,
                           causal: bool = True, window: int | None = None,
                           sm_scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """softmax(q kᵀ / sqrt(D), causal/windowed) v  over (BH, S, D) inputs.

    q: (BH, Sq, D), k/v: (BH, Skv, D) — callers fold batch x heads into
    the leading dim (and broadcast KV heads for GQA).  Sq/Skv are padded
    to the block grid; padded KV columns are masked out via the window /
    causal logic plus an explicit length mask when padding occurred.
    """
    if q.ndim != 3 or k.shape != v.shape or q.shape[0] != k.shape[0] \
            or q.shape[2] != k.shape[2]:
        raise ValueError(f"bad attention shapes {q.shape} {k.shape}")
    bh, sq, d = q.shape
    skv = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else float(d) ** -0.5

    bq_ = min(bq, max(8, sq))
    bkv_ = min(bkv, max(8, skv))
    gq, gkv = pl.cdiv(sq, bq_), pl.cdiv(skv, bkv_)
    qp = jnp.pad(q, ((0, 0), (0, gq * bq_ - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, gkv * bkv_ - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, gkv * bkv_ - skv), (0, 0)))
    # mask padded KV tail by folding it into the causal/window logic:
    # padded kv ids are >= skv > any real q id when causal; for the
    # non-causal case add a -inf bias via k rows of zeros — harmless
    # only if masked, so force causal semantics for padded non-causal.
    if gkv * bkv_ != skv and not causal:
        raise ValueError("non-causal attention requires Skv divisible by "
                         f"bkv (got {skv} vs block {bkv_})")

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=gkv, bq=bq_, bkv=bkv_,
                          causal=causal, window=window, sm_scale=sm_scale),
        grid=(bh, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, gq * bq_, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]
