"""Blocked (flash) attention Pallas kernels with online softmax.

Used by the prefill path of every attention architecture (32k-token
shapes make materialising the (S, S) score matrix impossible: 32768² x
4 B = 4 GB per head).  Supports causal masking and an optional sliding
window (mixtral SWA, recurrentgemma local attention).

Two KV-grid variants, selected by ``grid=`` (the tuner's
``GemmConfig.flash_grid`` knob — see :mod:`repro.core.costmodel`):

* ``dense`` — grid ``(BH, gq, gkv)``.  Fully-masked tiles are skipped
  with ``pl.when`` (no MXU work), but every grid step still *launches*
  and every K/V block is still streamed HBM->VMEM — neither memory
  traffic nor step count reflects the causal triangle.
* ``tri`` — block-sparse triangular grid.  A host-built tile map
  (:func:`flash_tile_map`, fed through scalar prefetch) bounds the
  sequential KV axis per Q block row (and per window band), so
  above-diagonal tiles are never launched and their K/V blocks never
  copied — roughly halving both launches and K/V HBM traffic on causal
  prefill.  Bit-compatible with the dense grid (identical block
  arithmetic in the same order; only the skipped all-masked tiles —
  which contribute exactly nothing — differ).

TPU adaptation: the KV sequence axis is a *sequential* grid dimension
with running (max, denominator, accumulator) carried in fp32 VMEM
scratch — the memory-hierarchy translation of the GPU warp-level
online-softmax.  The sequential-axis Pallas pipeline double-buffers the
K/V block fetches automatically; the triangular map keeps tiles in
row-major order so each Q row's K/V stream stays contiguous for that
pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["flash_attention_pallas", "flash_tile_map", "flash_grid_counts"]

_NEG_INF = -1e30

FLASH_GRID_KINDS = ("dense", "tri")


def _clamp_blocks(sq: int, skv: int, bq: int, bkv: int) -> tuple[int, int]:
    """The effective (bq, bkv) the kernels run: never larger than the
    (sublane-padded) sequence extents."""
    return min(bq, max(8, sq)), min(bkv, max(8, skv))


def flash_tile_map(sq: int, skv: int, bq: int, bkv: int, *,
                   causal: bool = True, window: int | None = None
                   ) -> tuple[np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
    """Block-sparse tile list for the triangular/banded flash grid.

    Returns ``(qt, kvt, first, last)`` int32 arrays, one entry per
    launched tile, in row-major (Q row outer, KV ascending) order:
    ``qt[t]``/``kvt[t]`` are the block indices the sequential grid step
    ``t`` loads, ``first[t]``/``last[t]`` flag the row's scratch init /
    output write.  Per Q row ``i`` (blocks over the *padded* Sq so every
    output row is written):

    * causal bounds the KV axis above at the diagonal,
      ``hi = min(gkv-1, (i*bq + bq - 1) // bkv)`` — tiles past it are
      fully masked and never emitted;
    * a sliding window bounds it below at the band edge,
      ``lo = max(0, (i*bq - window + 1) // bkv)``;
    * the KV-length bound caps ``hi`` at the last block holding a real
      (< skv) key, so fully-padded KV tiles are never emitted either.

    A row whose band is empty (window entirely in the future relative
    to every key) degenerates to one flagged-first-and-last tile whose
    body the kernel masks out entirely — init + finish still run, so
    the row's output is written (as zeros, matching the dense grid).
    """
    gq = -(-sq // bq)
    gkv = -(-skv // bkv)
    kv_hi = (skv - 1) // bkv          # last block with a real key
    qt, kvt, first, last = [], [], [], []
    for i in range(gq):
        hi = kv_hi
        if causal:
            hi = min(hi, (i * bq + bq - 1) // bkv)
        lo = 0
        if window is not None:
            lo = max(0, (i * bq - window + 1) // bkv)
        if lo > hi:                   # fully-masked row: degenerate tile
            lo = hi = min(lo, gkv - 1)
        for j in range(lo, hi + 1):
            qt.append(i)
            kvt.append(j)
            first.append(1 if j == lo else 0)
            last.append(1 if j == hi else 0)
    return (np.asarray(qt, np.int32), np.asarray(kvt, np.int32),
            np.asarray(first, np.int32), np.asarray(last, np.int32))


def flash_grid_counts(sq: int, skv: int, bq: int, bkv: int, *,
                      causal: bool = True, window: int | None = None
                      ) -> tuple[int, int]:
    """(triangular grid steps, dense grid steps) per batch-head, after
    the same block clamping :func:`flash_attention_pallas` applies —
    the launch saving the cost model prices and bench_flash measures."""
    bq_, bkv_ = _clamp_blocks(sq, skv, bq, bkv)
    gq, gkv = -(-sq // bq_), -(-skv // bkv_)
    qt, _, _, _ = flash_tile_map(sq, skv, bq_, bkv_,
                                 causal=causal, window=window)
    return len(qt), gq * gkv


def _block_update(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  q_start, kv_start, bq: int, bkv: int, skv: int,
                  causal: bool, window: int | None,
                  sm_scale: float) -> None:
    """One online-softmax block step, shared by both grid variants."""
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale       # (bq, bkv)

    q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    # the KV-length mask is unconditional: padded key columns hold zero
    # vectors whose score (0 * sm_scale = 0) would otherwise leak into
    # the denominator whenever causality alone doesn't hide them (any
    # q id >= skv, i.e. every causal sq > skv call)
    mask = kv_ids < skv
    if causal:
        mask &= kv_ids <= q_ids
    if window is not None:
        mask &= kv_ids > q_ids - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]                                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                   # (bq, bkv)
    corr = jnp.exp(m_prev - m_new)                           # (bq, 1)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)


def _visible(q_start, kv_start, *, bq: int, bkv: int, skv: int,
             padded: bool, causal: bool, window: int | None):
    """Does this tile intersect the mask at all?  Invisible tiles are
    skipped whole: no MXU work on the dense grid, and — crucially — no
    uniform-p garbage from an all-``_NEG_INF`` score block (exp(0)=1)
    before a row's running max is seeded."""
    visible = jnp.bool_(True)
    if padded:
        visible &= kv_start < skv
    if causal:
        visible &= kv_start <= q_start + bq - 1
    if window is not None:
        visible &= kv_start + bkv - 1 > q_start - window
    return visible


def _flash_dense_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                        *, n_kv: int, bq: int, bkv: int, skv: int,
                        causal: bool, window: int | None, sm_scale: float):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    kv_start = ikv * bkv
    body = functools.partial(
        _block_update, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
        q_start=q_start, kv_start=kv_start, bq=bq, bkv=bkv, skv=skv,
        causal=causal, window=window, sm_scale=sm_scale)

    padded = n_kv * bkv != skv
    if causal or window is not None or padded:
        pl.when(_visible(q_start, kv_start, bq=bq, bkv=bkv, skv=skv,
                         padded=padded, causal=causal,
                         window=window))(body)
    else:
        body()

    @pl.when(ikv == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def _flash_tri_kernel(qt_ref, kvt_ref, firstf_ref, lastf_ref,
                      q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                      *, bq: int, bkv: int, skv: int, causal: bool,
                      window: int | None, sm_scale: float):
    """Block-sparse variant: grid (BH, T) over the prefetched tile map.
    The scalar-prefetch refs also drive the BlockSpec index maps, so a
    tile absent from the map is neither launched nor DMA'd."""
    t = pl.program_id(1)
    iq = qt_ref[t]
    ikv = kvt_ref[t]

    @pl.when(firstf_ref[t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    kv_start = ikv * bkv
    body = functools.partial(
        _block_update, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
        q_start=q_start, kv_start=kv_start, bq=bq, bkv=bkv, skv=skv,
        causal=causal, window=window, sm_scale=sm_scale)
    # emitted tiles are visible by construction except a fully-masked
    # row's degenerate placeholder (and padded-KV straddle columns are
    # handled by the in-block mask) — the guard keeps those exact
    pl.when(_visible(q_start, kv_start, bq=bq, bkv=bkv, skv=skv,
                     padded=True, causal=causal, window=window))(body)

    @pl.when(lastf_ref[t] == 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "window",
                                    "sm_scale", "interpret", "grid"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int = 512, bkv: int = 512,
                           causal: bool = True, window: int | None = None,
                           sm_scale: float | None = None,
                           interpret: bool = False,
                           grid: str = "dense") -> jax.Array:
    """softmax(q kᵀ / sqrt(D), causal/windowed) v  over (BH, S, D) inputs.

    q: (BH, Sq, D), k/v: (BH, Skv, D) — callers fold batch x heads into
    the leading dim (and broadcast KV heads for GQA).  Sq/Skv are padded
    to the block grid; padded KV columns are masked out explicitly (the
    KV-length mask), so ragged causal *and* non-causal shapes are exact.

    ``grid`` picks the KV grid (see module docstring): ``"dense"`` or
    ``"tri"`` (block-sparse triangular/banded — identical output, fewer
    launched tiles whenever causality or a window masks whole blocks).
    """
    if q.ndim != 3 or k.shape != v.shape or q.shape[0] != k.shape[0] \
            or q.shape[2] != k.shape[2]:
        raise ValueError(f"bad attention shapes {q.shape} {k.shape}")
    if grid not in FLASH_GRID_KINDS:
        raise ValueError(f"unknown flash grid {grid!r}; "
                         f"expected one of {FLASH_GRID_KINDS}")
    bh, sq, d = q.shape
    skv = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else float(d) ** -0.5

    bq_, bkv_ = _clamp_blocks(sq, skv, bq, bkv)
    gq, gkv = pl.cdiv(sq, bq_), pl.cdiv(skv, bkv_)
    qp = jnp.pad(q, ((0, 0), (0, gq * bq_ - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, gkv * bkv_ - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, gkv * bkv_ - skv), (0, 0)))
    scratch = [
        pltpu.VMEM((bq_, d), jnp.float32),
        pltpu.VMEM((bq_, 128), jnp.float32),
        pltpu.VMEM((bq_, 128), jnp.float32),
    ]

    if grid == "tri":
        qt, kvt, first, last = flash_tile_map(
            sq, skv, bq_, bkv_, causal=causal, window=window)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(bh, len(qt)),
            in_specs=[
                pl.BlockSpec((1, bq_, d),
                             lambda b, t, qt, kvt, ff, lf: (b, qt[t], 0)),
                pl.BlockSpec((1, bkv_, d),
                             lambda b, t, qt, kvt, ff, lf: (b, kvt[t], 0)),
                pl.BlockSpec((1, bkv_, d),
                             lambda b, t, qt, kvt, ff, lf: (b, kvt[t], 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, bq_, d), lambda b, t, qt, kvt, ff, lf: (b, qt[t], 0)),
            scratch_shapes=scratch,
        )
        out = pl.pallas_call(
            functools.partial(_flash_tri_kernel, bq=bq_, bkv=bkv_,
                              skv=skv, causal=causal, window=window,
                              sm_scale=sm_scale),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((bh, gq * bq_, d), q.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(jnp.asarray(qt), jnp.asarray(kvt), jnp.asarray(first),
          jnp.asarray(last), qp, kp, vp)
        return out[:, :sq, :]

    out = pl.pallas_call(
        functools.partial(_flash_dense_kernel, n_kv=gkv, bq=bq_, bkv=bkv_,
                          skv=skv, causal=causal, window=window,
                          sm_scale=sm_scale),
        grid=(bh, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, gq * bq_, d), q.dtype),
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]
