"""Dispatch observability: which BLAS-3 routine did each contraction use?

``DispatchRecorder`` is a context manager backed by a thread-local
registry.  While one is active, every routine-aware call site —
:func:`repro.kernels.ops.matmul` / ``syrk`` / ``trsm`` /
``grouped_matmul`` and the ``dispatch_hint`` family — reports a
:class:`DispatchEvent` carrying ``(routine, m, k, n, chosen_config,
cache_hit, site)``.  The reporting path is compiled into the ops
permanently: when no recorder is active, :func:`record` is a two-lookup
no-op, cheap enough to leave on the serving hot path.

Semantics worth knowing:

* **Trace-time recording.**  Under ``jit`` / ``lax.scan`` / ``vmap`` the
  call sites run once at trace time, so a recorder sees one event per
  call site per compilation — the dispatch *decision* (which is made on
  static shapes anyway), not the per-step execution count.  Eager calls
  record once per call; a scanned layer stack records once per unit
  layer.
* **Nesting.**  Recorders stack: an event reaches every recorder active
  on the current thread, so an outer recorder can aggregate a whole run
  while an inner one isolates a single step.
* **Thread isolation.**  The registry is ``threading.local`` — a
  recorder never observes another thread's dispatches.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterable

from repro.core.costmodel import ROUTINES
from repro.core.features import ROUTINE_FLOP_SCALE

__all__ = ["DispatchEvent", "DispatchRecorder", "record", "active",
           "active_event_count", "record_backward"]


@dataclasses.dataclass(frozen=True)
class DispatchEvent:
    """One observed contraction dispatch.

    ``config`` is the tuner-chosen worker configuration (``None`` when
    the call ran untuned) and ``cache_hit`` says whether the tuner
    served it from its memo cache without a model evaluation.
    """

    routine: str
    m: int
    k: int
    n: int
    config: Any = None
    cache_hit: bool = False
    site: str = ""
    #: dispatch multiplicity: a vmapped call site traces once but
    #: stands for ``count`` identical contractions (e.g. the per-head
    #: attention score product records count = B*H), so flops and
    #: event-weighted mixes don't under-count batched sites
    count: int = 1

    @property
    def flops(self) -> float:
        """Routine-adjusted flop volume (count * 2mkn per ROUTINES)."""
        scale = ROUTINE_FLOP_SCALE[ROUTINES.index(self.routine)]
        return 2.0 * self.count * self.m * self.k * self.n * scale


_tls = threading.local()


def _stack() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def active() -> bool:
    """True when at least one recorder is active on this thread."""
    return bool(getattr(_tls, "stack", None))


def record(routine: str, m: int, k: int, n: int, *,
           config: Any = None, cache_hit: bool = False,
           site: str = "", count: int = 1) -> None:
    """Report one dispatch to every active recorder (no-op when none)."""
    stack = getattr(_tls, "stack", None)
    if not stack:
        return
    event = DispatchEvent(routine, int(m), int(k), int(n), config,
                          bool(cache_hit), site, int(count))
    for rec in stack:
        rec.events.append(event)


def active_event_count() -> int:
    """Events seen so far by the innermost active recorder (0 if none).

    Pair with :func:`record_backward` to bracket a forward pass.
    """
    stack = getattr(_tls, "stack", None)
    return len(stack[-1].events) if stack else 0


def record_backward(since: int = 0, tuner: Any = None) -> None:
    """Tag the backward-pass contractions of a just-traced forward pass.

    For every forward event the innermost recorder collected from index
    ``since`` on, records the two AD-transposed contractions — dX
    ``(m, n, k)`` and dW ``(k, m, n)`` — as ``gemm`` events (the
    adjoint of a triangular product is a general contraction).  When a
    ``tuner`` is given the backward shapes are resolved through it so
    the events carry worker configurations like their forward twins.
    """
    stack = getattr(_tls, "stack", None)
    if not stack:
        return
    forward = [e for e in stack[-1].events[since:]
               if not e.site.startswith("bwd")]
    for e in forward:
        for (m, k, n), which in (((e.m, e.n, e.k), "dx"),
                                 ((e.k, e.m, e.n), "dw")):
            cfg, hit = None, False
            if tuner is not None:
                hit = tuner.peek(m, k, n, "gemm")
                cfg = tuner.select(m, k, n, "gemm")
            record("gemm", m, k, n, config=cfg, cache_hit=hit,
                   site=f"bwd.{which}[{e.site or e.routine}]",
                   count=e.count)


class DispatchRecorder:
    """Collects :class:`DispatchEvent`s on this thread while active.

    >>> with DispatchRecorder() as rec:
    ...     model.prefill(params, tokens, ctx)
    >>> rec.routine_mix()
    {'gemm': 0.72, 'syrk': 0.28}
    """

    def __init__(self) -> None:
        self.events: list[DispatchEvent] = []

    # -- context management -------------------------------------------
    def __enter__(self) -> "DispatchRecorder":
        _stack().append(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        else:                       # out-of-order exit: still detach
            stack.remove(self)

    def clear(self) -> None:
        self.events.clear()

    # -- aggregation ---------------------------------------------------
    def summary(self) -> dict[str, dict[str, float]]:
        """Per-routine totals: traced events, dispatches (count-
        weighted), flops, tuned calls, cache hits."""
        out: dict[str, dict[str, float]] = {}
        for e in self.events:
            row = out.setdefault(e.routine, {
                "events": 0, "dispatches": 0, "flops": 0.0, "tuned": 0,
                "cache_hits": 0})
            row["events"] += 1
            row["dispatches"] += e.count
            row["flops"] += e.flops
            row["tuned"] += e.config is not None
            row["cache_hits"] += e.cache_hit
        return out

    def routine_mix(self, by: str = "flops") -> dict[str, float]:
        """Fraction of dispatch volume per routine (sums to 1).

        ``by="flops"`` weights by routine-adjusted flop volume (the
        default — what the roofline cares about); ``by="events"``
        weights every dispatch equally (count-weighted, so a vmapped
        site traced once still contributes its batch multiplicity).
        """
        if by not in ("flops", "events"):
            raise ValueError(f"by={by!r}; expected 'flops' or 'events'")
        totals: dict[str, float] = {}
        for e in self.events:
            w = e.flops if by == "flops" else float(e.count)
            totals[e.routine] = totals.get(e.routine, 0.0) + w
        denom = sum(totals.values())
        if denom <= 0:
            return {}
        return {r: v / denom for r, v in sorted(totals.items())}

    def assert_only(self, routines: Iterable[str]) -> None:
        """Raise AssertionError if any event used a routine outside
        ``routines`` (the legacy-artifact fallback check)."""
        allowed = set(routines)
        bad = [e for e in self.events if e.routine not in allowed]
        if bad:
            seen = sorted({e.routine for e in bad})
            sites = sorted({e.site for e in bad})[:5]
            raise AssertionError(
                f"recorded routines {seen} outside allowed "
                f"{sorted(allowed)} ({len(bad)} events, e.g. at sites "
                f"{sites})")

    def sites(self, prefix: str = "") -> list[DispatchEvent]:
        """Events whose call-site label starts with ``prefix``."""
        return [e for e in self.events if e.site.startswith(prefix)]

    def shape_table(self) -> list[dict]:
        """Aggregated totals per distinct ``(routine, m, k, n)``, sorted
        by descending flop volume.

        This is the shape-level view a
        :class:`~repro.core.workload.WorkloadProfile` is built from
        (``dispatches`` carries the count-weighted multiplicity), and
        what ``repro.launch.dryrun`` persists per cell so install grids
        can be weighted by recorded workloads offline.
        """
        agg: dict[tuple, dict] = {}
        for e in self.events:
            key = (e.routine, e.m, e.k, e.n)
            row = agg.setdefault(key, {
                "routine": e.routine, "m": e.m, "k": e.k, "n": e.n,
                "events": 0, "dispatches": 0, "flops": 0.0})
            row["events"] += 1
            row["dispatches"] += e.count
            row["flops"] += e.flops
        return sorted(agg.values(), key=lambda r: -r["flops"])
