"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["matmul_ref", "syrk_ref", "trsm_ref", "grouped_matmul_ref",
           "flash_attention_ref"]


def matmul_ref(a: jax.Array, b: jax.Array,
               out_dtype: jnp.dtype | None = None) -> jax.Array:
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


def syrk_ref(a: jax.Array, b: jax.Array | None = None, *,
             lower: bool = True,
             out_dtype: jnp.dtype | None = None) -> jax.Array:
    """Symmetric rank-k update: the ``lower`` (or upper) triangle of
    A @ Aᵀ; the untouched triangle is zero, as BLAS leaves it to C.

    With ``b`` (same shape as A) this is the SYRK-*shaped* product
    tril/triu(A @ Bᵀ) — only one triangle of the square output is
    produced, which is what a causal self-attention score matrix
    consumes."""
    b = a if b is None else b
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)
    c = jnp.tril(c) if lower else jnp.triu(c)
    return c.astype(out_dtype or a.dtype)


def trsm_ref(a: jax.Array, b: jax.Array, *, lower: bool = True,
             unit_diag: bool = False,
             out_dtype: jnp.dtype | None = None) -> jax.Array:
    """Triangular solve A X = B for X, via jax.lax.linalg."""
    x = jax.lax.linalg.triangular_solve(
        a.astype(jnp.float32), b.astype(jnp.float32),
        left_side=True, lower=lower, unit_diagonal=unit_diag)
    return x.astype(out_dtype or b.dtype)


def grouped_matmul_ref(x: jax.Array, w: jax.Array,
                       out_dtype: jnp.dtype | None = None) -> jax.Array:
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        sm_scale: float | None = None) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else float(d) ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    q_ids = jnp.arange(sq)[:, None]
    kv_ids = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kv_ids <= q_ids
    if window is not None:
        mask &= kv_ids > q_ids - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
