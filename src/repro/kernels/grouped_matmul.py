"""Grouped (expert-batched) Pallas matmul for MoE layers.

Computes Y[e] = X[e] @ W[e] for every expert e over fixed-capacity
token buckets — the TPU-idiomatic MoE formulation (dense dispatch into
(E, capacity, d) buckets; no dynamic shapes).  The per-expert GEMMs are
exactly the paper's "small and irregular" regime (capacity is usually a
few hundred rows), which is where ADSALA's tuner gives the largest wins;
the tile triple here is tuned with the same worker-configuration model
as the plain matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["grouped_matmul_pallas"]


def _grouped_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad3(x: jax.Array, d1: int, d2: int) -> jax.Array:
    p1, p2 = d1 - x.shape[1], d2 - x.shape[2]
    if p1 or p2:
        x = jnp.pad(x, ((0, 0), (0, p1), (0, p2)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret",
                                    "out_dtype"))
def grouped_matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128,
                          bk: int = 128, bn: int = 128,
                          interpret: bool = False,
                          out_dtype: jnp.dtype | None = None) -> jax.Array:
    """Y[e, c, f] = X[e, c, d] @ W[e, d, f] for all experts e."""
    if x.ndim != 3 or w.ndim != 3 or x.shape[0] != w.shape[0] \
            or x.shape[2] != w.shape[1]:
        raise ValueError(f"bad grouped shapes {x.shape} x {w.shape}")
    e, c, d = x.shape
    _, _, f = w.shape
    out_dtype = out_dtype or x.dtype

    gm, gk, gn = pl.cdiv(c, bm), pl.cdiv(d, bk), pl.cdiv(f, bn)
    x = _pad3(x, gm * bm, gk * bk)
    w = _pad3(w, gk * bk, gn * bn)

    out = pl.pallas_call(
        functools.partial(_grouped_kernel, n_k=gk),
        grid=(e, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, kk: (g, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, kk: (g, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, kk: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, gm * bm, gn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]
