"""Public jit'd kernel wrappers + ADSALA tuner integration.

``matmul`` / ``grouped_matmul`` / ``flash_attention`` are the entry
points the model layers call.  Backend selection:

  * ``pallas``  — the Pallas TPU kernels (interpret=True off-TPU, used by
    the correctness tests);
  * ``xla``     — jnp reference implementations.  The default on CPU
    hosts and inside the multi-pod dry-run, where XLA's SPMD partitioner
    handles the sharded einsums and Mosaic kernels cannot lower.

When an :class:`~repro.core.tuner.AdsalaTuner` is supplied, the GEMM's
(m, k, n) is looked up per call (memoised inside the tuner) and the
chosen worker configuration supplies the kernel tile; the chosen chip
count / partition axis is exposed via :func:`dispatch_hint` for the
distribution layer to turn into sharding constraints.
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.costmodel import DEFAULT_TILES, GemmConfig
from repro.core.tuner import AdsalaTuner
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.matmul import matmul_pallas

__all__ = ["matmul", "grouped_matmul", "flash_attention", "dispatch_hint",
           "resolve_backend"]

Backend = Literal["auto", "pallas", "xla"]


def resolve_backend(backend: Backend = "auto") -> str:
    if backend != "auto":
        return backend
    if os.environ.get("ADSALA_FORCE_PALLAS"):
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _tile_for(m: int, k: int, n: int,
              tuner: AdsalaTuner | None,
              tile: tuple[int, int, int] | None) -> tuple[int, int, int]:
    if tile is not None:
        return tile
    if tuner is not None:
        return tuner.select(m, k, n).tile
    return DEFAULT_TILES[3]  # (256, 256, 256)


def dispatch_hint(m: int, k: int, n: int,
                  tuner: AdsalaTuner | None) -> GemmConfig | None:
    """Worker configuration the tuner recommends for this GEMM (or None)."""
    return tuner.select(m, k, n) if tuner is not None else None


def matmul(a: jax.Array, b: jax.Array, *,
           tuner: AdsalaTuner | None = None,
           tile: tuple[int, int, int] | None = None,
           backend: Backend = "auto",
           interpret: bool | None = None) -> jax.Array:
    be = resolve_backend(backend)
    if be == "xla":
        return ref.matmul_ref(a, b)
    bm, bk, bn = _tile_for(a.shape[0], a.shape[1], b.shape[1], tuner, tile)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return matmul_pallas(a, b, bm=bm, bk=bk, bn=bn, interpret=interp)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   tuner: AdsalaTuner | None = None,
                   tile: tuple[int, int, int] | None = None,
                   backend: Backend = "auto",
                   interpret: bool | None = None) -> jax.Array:
    be = resolve_backend(backend)
    if be == "xla":
        return ref.grouped_matmul_ref(x, w)
    bm, bk, bn = _tile_for(x.shape[1], x.shape[2], w.shape[2], tuner, tile)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return grouped_matmul_pallas(x, w, bm=bm, bk=bk, bn=bn, interpret=interp)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    sm_scale: float | None = None,
                    bq: int = 512, bkv: int = 512,
                    backend: Backend = "auto",
                    interpret: bool | None = None) -> jax.Array:
    be = resolve_backend(backend)
    if be == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, sm_scale=sm_scale)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  sm_scale=sm_scale, bq=bq, bkv=bkv,
                                  interpret=interp)
