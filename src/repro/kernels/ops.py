"""Public jit'd kernel wrappers + ADSALA tuner integration.

``matmul`` / ``grouped_matmul`` / ``flash_attention`` are the entry
points the model layers call.  Backend selection:

  * ``pallas``  — the Pallas TPU kernels (interpret=True off-TPU, used by
    the correctness tests);
  * ``xla``     — jnp reference implementations.  The default on CPU
    hosts and inside the multi-pod dry-run, where XLA's SPMD partitioner
    handles the sharded einsums and Mosaic kernels cannot lower.

When an :class:`~repro.core.tuner.AdsalaTuner` is supplied, the call's
(routine, m, k, n) is looked up per call (memoised inside the tuner) and
the chosen worker configuration supplies the kernel tile; the chosen
chip count / partition axis is exposed via :func:`dispatch_hint` for the
distribution layer to turn into sharding constraints.

Every routine-aware entry point also reports its dispatch — the
*resolved* routine, shape, chosen config and whether the tuner served
it from cache — to any active
:class:`~repro.kernels.recorder.DispatchRecorder`.  Routine names are
validated here at the ops boundary (unknown strings fail loudly), and a
routine the tuner's artifact carries no training signal for degrades to
the explicit :data:`~repro.core.costmodel.DEFAULT_ROUTINE` gemm
fallback instead of raising — a v1 gemm-only artifact keeps serving
models whose call sites are routine-tagged.
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (
    DEFAULT_ROUTINE,
    DEFAULT_TILES,
    ROUTINES,
    GemmConfig,
)
from repro.core.tuner import AdsalaTuner
from repro.kernels import recorder, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.matmul import matmul_pallas

__all__ = ["matmul", "syrk", "trsm", "grouped_matmul", "flash_attention",
           "dispatch_hint", "grouped_dispatch_hint", "observe",
           "resolve_backend", "supported_routine"]

Backend = Literal["auto", "pallas", "xla"]

_BACKENDS = ("auto", "pallas", "xla")


def resolve_backend(backend: Backend = "auto") -> str:
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get("ADSALA_BACKEND")
    if env:
        if env not in ("pallas", "xla"):
            raise ValueError(
                f"ADSALA_BACKEND={env!r}; expected 'pallas' or 'xla'")
        return env
    if os.environ.get("ADSALA_FORCE_PALLAS"):
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def supported_routine(routine: str, tuner: AdsalaTuner | None) -> str:
    """The routine a call site can actually dispatch.

    Validates the name against :data:`ROUTINES` (unknown strings raise
    here, at the ops boundary, with the full expected set), then falls
    back to the explicit gemm :data:`DEFAULT_ROUTINE` when the tuner's
    artifact was installed without ``routine`` — legacy/v1 artifacts
    and subset installs keep serving instead of raising from deep
    inside a model layer.
    """
    if routine not in ROUTINES:
        raise ValueError(
            f"unknown routine {routine!r}; expected one of {ROUTINES}")
    if tuner is not None and routine not in tuner.routines:
        return DEFAULT_ROUTINE
    return routine


def _select(m: int, k: int, n: int, routine: str,
            tuner: AdsalaTuner | None, *, need_config: bool
            ) -> tuple[str, GemmConfig | None, bool]:
    """(resolved routine, tuner config | None, cache_hit) for one call.

    The tuner is consulted when the kernel needs a tile
    (``need_config``) or a recorder wants the chosen config on the
    event; otherwise (xla path, nobody watching) the lookup is skipped
    so untuned dispatch stays free.
    """
    routine = supported_routine(routine, tuner)
    if tuner is None or not (need_config or recorder.active()):
        return routine, None, False
    hit = tuner.peek(m, k, n, routine)
    return routine, tuner.select(m, k, n, routine), hit


def dispatch_hint(m: int, k: int, n: int,
                  tuner: AdsalaTuner | None,
                  routine: str = DEFAULT_ROUTINE,
                  site: str = "", count: int = 1) -> GemmConfig | None:
    """Worker configuration the tuner recommends for this call (or None).

    Doubles as the observability point for contractions that don't go
    through an ops kernel (einsum call sites in the model layers): the
    resolved routine identity is reported to any active
    DispatchRecorder, with the gemm fallback applied when the artifact
    has no signal for ``routine``.
    """
    routine = supported_routine(routine, tuner)
    cfg, hit = None, False
    if tuner is not None:
        hit = tuner.peek(m, k, n, routine)
        cfg = tuner.select(m, k, n, routine)
    recorder.record(routine, m, k, n, config=cfg, cache_hit=hit,
                    site=site, count=count)
    return cfg


def observe(m: int, k: int, n: int,
            tuner: AdsalaTuner | None,
            routine: str = DEFAULT_ROUTINE,
            site: str = "", count: int = 1) -> None:
    """Observability-only twin of :func:`dispatch_hint`.

    The model-layer einsum call sites discard the hint — they only
    exist so a recorder can see the contraction's routine identity.
    Unlike ``dispatch_hint`` (whose contract is to *return* the tuner's
    recommendation), this consults the tuner only while a recorder is
    active, so eager untuned/unwatched dispatch pays nothing beyond the
    routine-name validation and the tuner's LRU never fills with fused
    hint shapes that are not real kernel dispatches.
    """
    if not recorder.active():
        supported_routine(routine, tuner)   # still fail loudly on typos
        return
    dispatch_hint(m, k, n, tuner, routine, site, count)


def grouped_dispatch_hint(shapes: list[tuple[int, int, int]],
                          tuner: AdsalaTuner | None, *,
                          n_experts: int | None = None,
                          routine: str = DEFAULT_ROUTINE,
                          site: str = "grouped"
                          ) -> list[GemmConfig] | None:
    """Per-expert worker configurations for a grouped (MoE) dispatch.

    All expert GEMMs go through ONE batched tuner lookup
    (:meth:`AdsalaTuner.select_many`) instead of per-expert scalar calls.
    ``n_experts`` (when known) guards against a shape list covering only
    a prefix of the experts — a silent truncation would hand later
    experts no hint at all.  One event per expert shape is reported to
    any active recorder.
    """
    shapes = list(shapes)
    if n_experts is not None and len(shapes) != n_experts:
        raise ValueError(
            f"grouped dispatch got {len(shapes)} GEMM shapes for "
            f"{n_experts} experts; every expert needs a shape")
    routine = supported_routine(routine, tuner)
    cfgs = None
    if tuner is not None:
        hits = [tuner.peek(m, k, n, routine) for m, k, n in shapes]
        cfgs = tuner.select_many(shapes, routines=routine)
    else:
        hits = [False] * len(shapes)
    if recorder.active():
        for (m, k, n), hit, cfg in zip(
                shapes, hits, cfgs or [None] * len(shapes)):
            recorder.record(routine, m, k, n, config=cfg, cache_hit=hit,
                            site=site)
    return cfgs


def matmul(a: jax.Array, b: jax.Array, *,
           tuner: AdsalaTuner | None = None,
           tile: tuple[int, int, int] | None = None,
           backend: Backend = "auto",
           interpret: bool | None = None,
           site: str = "", count: int = 1) -> jax.Array:
    be = resolve_backend(backend)
    m, k, n = int(a.shape[0]), int(a.shape[1]), int(b.shape[1])
    # an explicit tile overrides the tuner entirely: don't consult it,
    # and don't label the event with a config that was never dispatched
    rt, cfg, hit = _select(m, k, n, DEFAULT_ROUTINE,
                           tuner if tile is None else None,
                           need_config=be != "xla")
    recorder.record(rt, m, k, n, config=cfg, cache_hit=hit, site=site,
                    count=count)
    if be == "xla":
        return ref.matmul_ref(a, b)
    bm, bk, bn = (tile if tile is not None
                  else cfg.tile if cfg is not None else DEFAULT_TILES[3])
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return matmul_pallas(a, b, bm=bm, bk=bk, bn=bn, interpret=interp)


def syrk(a: jax.Array, b: jax.Array | None = None, *,
         tuner: AdsalaTuner | None = None,
         tile: tuple[int, int, int] | None = None,
         lower: bool = True,
         backend: Backend = "auto",
         interpret: bool | None = None,
         site: str = "", count: int = 1) -> jax.Array:
    """Symmetric rank-k update C = tril/triu(A @ Aᵀ), A of shape (m, k).

    With ``b`` (same shape as A) this is the SYRK-*shaped* product
    C = tril/triu(A @ Bᵀ): only one triangle of the square output is
    produced, so it prices — and dispatches — as SYRK even though the
    operands differ.  Causal self-attention scores (QKᵀ consumed under
    a triangular mask) are the serving-path instance.

    The Pallas path reuses the tuned matmul kernel and masks the output
    to the written triangle (the kernel computes both halves; the
    analytic cost model charges only the triangular fraction, which is
    what a production SYRK kernel would execute).  Tuner lookups use
    routine="syrk" on the (m, k, m) shape, degrading to gemm on
    artifacts without syrk signal.
    """
    if a.ndim != 2:
        raise ValueError(f"bad SYRK operand shape {a.shape}")
    if b is not None and b.shape != a.shape:
        raise ValueError(
            f"bad SYRK-shaped operands {a.shape} x {b.shape}; B must "
            "match A (square output, shared k)")
    m, k = int(a.shape[0]), int(a.shape[1])
    be = resolve_backend(backend)
    rt, cfg, hit = _select(m, k, m, "syrk",
                           tuner if tile is None else None,
                           need_config=be != "xla")
    recorder.record(rt, m, k, m, config=cfg, cache_hit=hit, site=site,
                    count=count)
    if be == "xla":
        return ref.syrk_ref(a, b, lower=lower)
    bm, bk, bn = (tile if tile is not None
                  else cfg.tile if cfg is not None else DEFAULT_TILES[3])
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    c = matmul_pallas(a, (a if b is None else b).T, bm=bm, bk=bk, bn=bn,
                      interpret=interp, out_dtype=jnp.float32)
    c = jnp.tril(c) if lower else jnp.triu(c)
    return c.astype(a.dtype)


def trsm(a: jax.Array, b: jax.Array, *,
         tuner: AdsalaTuner | None = None,
         tile: tuple[int, int, int] | None = None,
         lower: bool = True,
         unit_diag: bool = False,
         backend: Backend = "auto",
         interpret: bool | None = None,
         site: str = "", count: int = 1) -> jax.Array:
    """Triangular solve A X = B (A (m, m) triangular, B (m, n)).

    The Pallas path is a blocked substitution: row panels of ``bm``
    (from the tuned tile) retire in order — each one subtracts the
    already-solved prefix via the tuned matmul kernel, then solves its
    diagonal block against the jax.lax reference.  This mirrors the cost
    model's sequential-dependency term (one dependent launch per M
    panel).  Tuner lookups use routine="trsm" on the (m, m, n) shape,
    degrading to gemm on artifacts without trsm signal.
    """
    if a.ndim != 2 or a.shape[0] != a.shape[1] or b.ndim != 2 \
            or b.shape[0] != a.shape[0]:
        raise ValueError(f"bad TRSM shapes {a.shape} x {b.shape}")
    m = int(a.shape[0])
    n = int(b.shape[1])
    be = resolve_backend(backend)
    rt, cfg, hit = _select(m, m, n, "trsm",
                           tuner if tile is None else None,
                           need_config=be != "xla")
    recorder.record(rt, m, m, n, config=cfg, cache_hit=hit, site=site,
                    count=count)
    if be == "xla":
        return ref.trsm_ref(a, b, lower=lower, unit_diag=unit_diag)
    bm, bk, bn = (tile if tile is not None
                  else cfg.tile if cfg is not None else DEFAULT_TILES[3])
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    starts = list(range(0, m, bm))
    if not lower:                 # backward substitution: bottom-up
        starts = starts[::-1]
    blocks: dict[int, jax.Array] = {}
    for i0 in starts:
        i1 = min(i0 + bm, m)
        rhs = b32[i0:i1]
        # subtract the already-solved panels' contribution in one tuned
        # matmul over the concatenated prefix (suffix for upper)
        done = [j0 for j0 in blocks if (j0 < i0 if lower else j0 > i0)]
        if done:
            done.sort()
            cols = jnp.concatenate(
                [a32[i0:i1, j0:min(j0 + bm, m)] for j0 in done], axis=1)
            solved = jnp.concatenate([blocks[j0] for j0 in done], axis=0)
            rhs = rhs - matmul_pallas(cols, solved, bm=bm, bk=bk, bn=bn,
                                      interpret=interp)
        blocks[i0] = jax.lax.linalg.triangular_solve(
            a32[i0:i1, i0:i1], rhs, left_side=True, lower=lower,
            unit_diagonal=unit_diag)
    x = jnp.concatenate([blocks[i0] for i0 in sorted(blocks)], axis=0)
    return x.astype(b.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   tuner: AdsalaTuner | None = None,
                   tile: tuple[int, int, int] | None = None,
                   group_sizes: list[int] | None = None,
                   routine: str = DEFAULT_ROUTINE,
                   site: str = "grouped",
                   backend: Backend = "auto",
                   interpret: bool | None = None) -> jax.Array:
    """Y[e] = X[e] @ W[e] with tuner-selected tiling.

    ``group_sizes`` (actual tokens routed per expert, <= capacity) refines
    the per-expert GEMM shapes the tuner sees; with or without it, all E
    experts resolve through a single batched ``select_many`` lookup.
    Each per-expert shape is reported to any active recorder as its own
    event (the MoE dispatch volume is per-expert, not per-kernel).
    """
    be = resolve_backend(backend)
    e, c, d = x.shape
    f = w.shape[2]
    if group_sizes is not None:
        group_sizes = [int(g) for g in group_sizes]
        if len(group_sizes) != e:
            raise ValueError(
                f"group_sizes has {len(group_sizes)} entries for {e} "
                "experts; a prefix is not allowed — pass one size per "
                "expert (0 for an idle expert)")
        if any(g < 0 or g > c for g in group_sizes):
            raise ValueError(
                f"group_sizes {group_sizes} outside [0, capacity={c}]")
    # an expert with zero routed tokens still runs its capacity bucket;
    # query the tuner with at least one row so the shape stays sensible
    shapes = ([(max(int(g), 1), int(d), int(f)) for g in group_sizes]
              if group_sizes is not None
              else [(int(c), int(d), int(f))] * int(e))
    consult = tuner if tile is None else None
    rt = supported_routine(routine, consult)
    cfgs = None
    want_events = recorder.active()
    if consult is not None and (be != "xla" or want_events):
        hits = [consult.peek(m_, k_, n_, rt) for m_, k_, n_ in shapes]
        cfgs = consult.select_many(shapes, routines=rt)
    else:
        hits = [False] * len(shapes)
    if want_events:
        for (m_, k_, n_), hit, cfg in zip(
                shapes, hits, cfgs or [None] * len(shapes)):
            recorder.record(rt, m_, k_, n_, config=cfg, cache_hit=hit,
                            site=site)
    if be == "xla":
        return ref.grouped_matmul_ref(x, w)
    if tile is not None:
        bm, bk, bn = tile
    elif cfgs is not None:
        # one kernel tile serves every expert; use the config chosen for
        # the cost-dominant per-expert GEMM (largest m*k*n, not just m —
        # hint shapes may be heterogeneous in every dim)
        big = max(range(len(shapes)),
                  key=lambda i: shapes[i][0] * shapes[i][1] * shapes[i][2])
        bm, bk, bn = cfgs[big].tile
    else:
        bm, bk, bn = DEFAULT_TILES[3]  # (256, 256, 256)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return grouped_matmul_pallas(x, w, bm=bm, bk=bk, bn=bn, interpret=interp)


#: untuned-XLA fallback: the longest causal self-attention whose scores
#: the SYRK materialisation path serves when no tuner is available to
#: price the choice.  This retires the models.layers.SYRK_SCORES_MAX_SEQ
#: hardcode — a tuner with attn + syrk signal replaces the threshold
#: with a predicted-time comparison per shape.
SYRK_FALLBACK_MAX_SEQ = 512

#: hard memory guard on the SYRK score path (tuned or not): the full
#: fp32 (Sq, Sq) score triangle must fit this budget per head — the
#: chunked / flash paths keep only O(block x Skv) scores live, so past
#: this point materialisation is inadmissible at any predicted speed.
SYRK_SCORES_BYTES_MAX = 64 * 1024 * 1024


def _syrk_scores_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           sm_scale: float | None, *,
                           tuner: AdsalaTuner | None,
                           site: str, count: int) -> jax.Array:
    """Causal self-attention with materialised SYRK-shaped scores.

    With causal masking only the lower triangle of QK^T is ever
    consumed — exactly SYRK's output shape — so the score product
    dispatches (and is recorded, per head with its batch multiplicity)
    as routine="syrk" on the (Sq, Dh, Sq) triple.  q/k/v: (BH, Sq, Dh);
    computed in fp32 like the chunked path.
    """
    bh, sq, d = q.shape
    scale = sm_scale if sm_scale is not None else float(d) ** -0.5
    scores = jax.vmap(
        lambda qi, ki: syrk(qi, ki, tuner=tuner, site=site, count=count,
                            backend="xla"))(
        q.astype(jnp.float32), k.astype(jnp.float32))
    ids = jnp.arange(sq)
    mask = ids[None, :] <= ids[:, None]
    scores = jnp.where(mask[None], scores * scale, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def _chunked_attention_flat(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool, window: int | None,
                            sm_scale: float | None,
                            chunk: int = 512) -> jax.Array:
    """Online XLA attention scanned over query chunks, (BH, S, D) in/out.

    Never materialises the full (Sq, Skv) score matrix: per scan step
    the live block is (BH, chunk, Skv) — the long-sequence XLA path.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else float(d) ** -0.5
    nc = -(-sq // chunk)
    pad = nc * chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
    qc = qp.reshape(bh, nc, chunk, d).transpose(1, 0, 2, 3)
    kv_ids = jnp.arange(skv)

    def step(_, qi_ci):
        qi, ci = qi_ci
        s = jnp.einsum("bqd,bkd->bqk", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        q_ids = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, skv), dtype=bool)
        if causal:
            mask &= kv_ids[None, :] <= q_ids[:, None]
        if window is not None:
            mask &= kv_ids[None, :] > q_ids[:, None] - window
        s = jnp.where(mask[None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(step, None, (qc, jnp.arange(nc)))
    return outs.transpose(1, 0, 2, 3).reshape(bh, nc * chunk, d)[:, :sq]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    sm_scale: float | None = None,
                    bq: int | None = None, bkv: int | None = None,
                    grid: str | None = None,
                    tuner: AdsalaTuner | None = None,
                    backend: Backend = "auto",
                    interpret: bool | None = None,
                    site: str = "attn.core",
                    count: int | None = None) -> jax.Array:
    """Tuned attention: softmax(q kᵀ, causal/windowed) v on (BH, S, D).

    Masked (causal or windowed) attention dispatches as routine="attn"
    on the per-head (Sq, Dh, Skv) triple with ``count`` (default BH)
    multiplicity; non-causal unwindowed attention keeps the gemm
    identity.  The tuner's chosen :class:`GemmConfig` supplies the
    flash blocks (``flash_block``) and the KV-grid kind
    (``flash_grid``: dense vs block-sparse triangular), and on the XLA
    backend whether the SYRK score-materialisation path wins instead —
    a predicted-time comparison per shape, replacing the retired
    ``SYRK_SCORES_MAX_SEQ`` hardcode (untuned XLA callers fall back to
    that threshold, :data:`SYRK_FALLBACK_MAX_SEQ`, under the
    :data:`SYRK_SCORES_BYTES_MAX` memory guard).  Explicit
    ``bq``/``bkv``/``grid`` overrides skip the tuner entirely, like
    ``matmul``'s explicit ``tile``.  Every path reports its dispatch —
    the SYRK path through :func:`syrk` itself (no double event), the
    flash/chunked paths as one attn/gemm event carrying the resolved
    config — to any active DispatchRecorder.
    """
    be = resolve_backend(backend)
    if q.ndim != 3 or k.shape != v.shape or q.shape[0] != k.shape[0] \
            or q.shape[2] != k.shape[2]:
        raise ValueError(f"bad attention shapes {q.shape} {k.shape}")
    bh, sq, d = (int(s) for s in q.shape)
    skv = int(k.shape[1])
    count = bh if count is None else count
    masked = causal or window is not None
    explicit = bq is not None or bkv is not None or grid is not None
    rt = supported_routine("attn" if masked else DEFAULT_ROUTINE,
                           None if explicit else tuner)
    cfg, hit = None, False
    if tuner is not None and not explicit:
        hit = tuner.peek(sq, d, skv, rt)
        cfg = tuner.select(sq, d, skv, rt)
    if cfg is not None and rt == "attn":
        fbq, fbkv = cfg.flash_block
        fgrid = cfg.flash_grid
    else:
        # untuned defaults: under a causal/window mask the block-sparse
        # grid is a pure win (it only drops all-masked tiles); without
        # a mask the two grids are the same tile list anyway
        fbq, fbkv, fgrid = 512, 512, ("tri" if masked else "dense")
    bq = bq if bq is not None else fbq
    bkv = bkv if bkv is not None else fbkv
    grid = grid if grid is not None else fgrid

    if be == "xla":
        if causal and window is None and sq == skv \
                and sq * sq * 4 <= SYRK_SCORES_BYTES_MAX:
            if cfg is not None and rt == "attn" \
                    and "syrk" in tuner.routines:
                _, t_attn = tuner.select_with_times(sq, d, skv, "attn")
                _, t_syrk = tuner.select_with_times(sq, d, sq, "syrk")
                use_syrk = float(np.min(t_syrk)) < float(np.min(t_attn))
            else:
                use_syrk = (tuner is None or rt != "attn") \
                    and sq <= SYRK_FALLBACK_MAX_SEQ
            if use_syrk:
                return _syrk_scores_attention(q, k, v, sm_scale,
                                              tuner=tuner, site=site,
                                              count=count)
        recorder.record(rt, sq, d, skv, config=cfg, cache_hit=hit,
                        site=site, count=count)
        return _chunked_attention_flat(q, k, v, causal=causal,
                                       window=window, sm_scale=sm_scale,
                                       chunk=min(512, max(1, sq)))
    recorder.record(rt, sq, d, skv, config=cfg, cache_hit=hit,
                    site=site, count=count)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  sm_scale=sm_scale, bq=bq, bkv=bkv,
                                  interpret=interp, grid=grid)
