"""Public jit'd kernel wrappers + ADSALA tuner integration.

``matmul`` / ``grouped_matmul`` / ``flash_attention`` are the entry
points the model layers call.  Backend selection:

  * ``pallas``  — the Pallas TPU kernels (interpret=True off-TPU, used by
    the correctness tests);
  * ``xla``     — jnp reference implementations.  The default on CPU
    hosts and inside the multi-pod dry-run, where XLA's SPMD partitioner
    handles the sharded einsums and Mosaic kernels cannot lower.

When an :class:`~repro.core.tuner.AdsalaTuner` is supplied, the GEMM's
(m, k, n) is looked up per call (memoised inside the tuner) and the
chosen worker configuration supplies the kernel tile; the chosen chip
count / partition axis is exposed via :func:`dispatch_hint` for the
distribution layer to turn into sharding constraints.
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.costmodel import DEFAULT_TILES, GemmConfig
from repro.core.tuner import AdsalaTuner
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.matmul import matmul_pallas

__all__ = ["matmul", "syrk", "trsm", "grouped_matmul", "flash_attention",
           "dispatch_hint", "grouped_dispatch_hint", "resolve_backend"]

Backend = Literal["auto", "pallas", "xla"]

_BACKENDS = ("auto", "pallas", "xla")


def resolve_backend(backend: Backend = "auto") -> str:
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get("ADSALA_BACKEND")
    if env:
        if env not in ("pallas", "xla"):
            raise ValueError(
                f"ADSALA_BACKEND={env!r}; expected 'pallas' or 'xla'")
        return env
    if os.environ.get("ADSALA_FORCE_PALLAS"):
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _tile_for(m: int, k: int, n: int,
              tuner: AdsalaTuner | None,
              tile: tuple[int, int, int] | None,
              routine: str = "gemm") -> tuple[int, int, int]:
    if tile is not None:
        return tile
    if tuner is not None:
        return tuner.select(m, k, n, routine).tile
    return DEFAULT_TILES[3]  # (256, 256, 256)


def dispatch_hint(m: int, k: int, n: int,
                  tuner: AdsalaTuner | None,
                  routine: str = "gemm") -> GemmConfig | None:
    """Worker configuration the tuner recommends for this call (or None)."""
    return tuner.select(m, k, n, routine) if tuner is not None else None


def grouped_dispatch_hint(shapes: list[tuple[int, int, int]],
                          tuner: AdsalaTuner | None, *,
                          n_experts: int | None = None
                          ) -> list[GemmConfig] | None:
    """Per-expert worker configurations for a grouped (MoE) dispatch.

    All expert GEMMs go through ONE batched tuner lookup
    (:meth:`AdsalaTuner.select_many`) instead of per-expert scalar calls.
    ``n_experts`` (when known) guards against a shape list covering only
    a prefix of the experts — a silent truncation would hand later
    experts no hint at all.
    """
    shapes = list(shapes)
    if n_experts is not None and len(shapes) != n_experts:
        raise ValueError(
            f"grouped dispatch got {len(shapes)} GEMM shapes for "
            f"{n_experts} experts; every expert needs a shape")
    return tuner.select_many(shapes) if tuner is not None else None


def _grouped_tile_for(shapes: list[tuple[int, int, int]],
                      tuner: AdsalaTuner | None,
                      tile: tuple[int, int, int] | None
                      ) -> tuple[int, int, int]:
    if tile is not None:
        return tile
    if not shapes:
        raise ValueError("grouped dispatch needs at least one GEMM shape")
    if tuner is not None:
        cfgs = tuner.select_many(shapes)
        # one kernel tile serves every expert; use the config chosen for
        # the cost-dominant per-expert GEMM (largest m*k*n, not just m —
        # hint shapes may be heterogeneous in every dim)
        big = max(range(len(shapes)),
                  key=lambda i: shapes[i][0] * shapes[i][1] * shapes[i][2])
        return cfgs[big].tile
    return DEFAULT_TILES[3]  # (256, 256, 256)


def matmul(a: jax.Array, b: jax.Array, *,
           tuner: AdsalaTuner | None = None,
           tile: tuple[int, int, int] | None = None,
           backend: Backend = "auto",
           interpret: bool | None = None) -> jax.Array:
    be = resolve_backend(backend)
    if be == "xla":
        return ref.matmul_ref(a, b)
    bm, bk, bn = _tile_for(a.shape[0], a.shape[1], b.shape[1], tuner, tile)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return matmul_pallas(a, b, bm=bm, bk=bk, bn=bn, interpret=interp)


def syrk(a: jax.Array, *,
         tuner: AdsalaTuner | None = None,
         tile: tuple[int, int, int] | None = None,
         lower: bool = True,
         backend: Backend = "auto",
         interpret: bool | None = None) -> jax.Array:
    """Symmetric rank-k update C = tril/triu(A @ Aᵀ), A of shape (m, k).

    The Pallas path reuses the tuned matmul kernel and masks the output
    to the written triangle (the kernel computes both halves; the
    analytic cost model charges only the triangular fraction, which is
    what a production SYRK kernel would execute).  Tuner lookups use
    routine="syrk" on the (m, k, m) shape.
    """
    if a.ndim != 2:
        raise ValueError(f"bad SYRK operand shape {a.shape}")
    m, k = a.shape
    be = resolve_backend(backend)
    if be == "xla":
        return ref.syrk_ref(a, lower=lower)
    bm, bk, bn = _tile_for(m, k, m, tuner, tile, routine="syrk")
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    c = matmul_pallas(a, a.T, bm=bm, bk=bk, bn=bn, interpret=interp,
                      out_dtype=jnp.float32)
    c = jnp.tril(c) if lower else jnp.triu(c)
    return c.astype(a.dtype)


def trsm(a: jax.Array, b: jax.Array, *,
         tuner: AdsalaTuner | None = None,
         tile: tuple[int, int, int] | None = None,
         lower: bool = True,
         unit_diag: bool = False,
         backend: Backend = "auto",
         interpret: bool | None = None) -> jax.Array:
    """Triangular solve A X = B (A (m, m) triangular, B (m, n)).

    The Pallas path is a blocked substitution: row panels of ``bm``
    (from the tuned tile) retire in order — each one subtracts the
    already-solved prefix via the tuned matmul kernel, then solves its
    diagonal block against the jax.lax reference.  This mirrors the cost
    model's sequential-dependency term (one dependent launch per M
    panel).  Tuner lookups use routine="trsm" on the (m, m, n) shape.
    """
    if a.ndim != 2 or a.shape[0] != a.shape[1] or b.ndim != 2 \
            or b.shape[0] != a.shape[0]:
        raise ValueError(f"bad TRSM shapes {a.shape} x {b.shape}")
    m = a.shape[0]
    n = b.shape[1]
    be = resolve_backend(backend)
    if be == "xla":
        return ref.trsm_ref(a, b, lower=lower, unit_diag=unit_diag)
    bm, bk, bn = _tile_for(m, m, n, tuner, tile, routine="trsm")
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    starts = list(range(0, m, bm))
    if not lower:                 # backward substitution: bottom-up
        starts = starts[::-1]
    blocks: dict[int, jax.Array] = {}
    for i0 in starts:
        i1 = min(i0 + bm, m)
        rhs = b32[i0:i1]
        # subtract the already-solved panels' contribution in one tuned
        # matmul over the concatenated prefix (suffix for upper)
        done = [j0 for j0 in blocks if (j0 < i0 if lower else j0 > i0)]
        if done:
            done.sort()
            cols = jnp.concatenate(
                [a32[i0:i1, j0:min(j0 + bm, m)] for j0 in done], axis=1)
            solved = jnp.concatenate([blocks[j0] for j0 in done], axis=0)
            rhs = rhs - matmul_pallas(cols, solved, bm=bm, bk=bk, bn=bn,
                                      interpret=interp)
        blocks[i0] = jax.lax.linalg.triangular_solve(
            a32[i0:i1, i0:i1], rhs, left_side=True, lower=lower,
            unit_diagonal=unit_diag)
    x = jnp.concatenate([blocks[i0] for i0 in sorted(blocks)], axis=0)
    return x.astype(b.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   tuner: AdsalaTuner | None = None,
                   tile: tuple[int, int, int] | None = None,
                   group_sizes: list[int] | None = None,
                   backend: Backend = "auto",
                   interpret: bool | None = None) -> jax.Array:
    """Y[e] = X[e] @ W[e] with tuner-selected tiling.

    ``group_sizes`` (actual tokens routed per expert, <= capacity) refines
    the per-expert GEMM shapes the tuner sees; with or without it, all E
    experts resolve through a single batched ``select_many`` lookup.
    """
    be = resolve_backend(backend)
    e, c, d = x.shape
    f = w.shape[2]
    if group_sizes is not None:
        group_sizes = [int(g) for g in group_sizes]
        if len(group_sizes) != e:
            raise ValueError(
                f"group_sizes has {len(group_sizes)} entries for {e} "
                "experts; a prefix is not allowed — pass one size per "
                "expert (0 for an idle expert)")
        if any(g < 0 or g > c for g in group_sizes):
            raise ValueError(
                f"group_sizes {group_sizes} outside [0, capacity={c}]")
    if be == "xla":
        return ref.grouped_matmul_ref(x, w)
    # an expert with zero routed tokens still runs its capacity bucket;
    # query the tuner with at least one row so the shape stays sensible
    shapes = ([(max(int(g), 1), d, f) for g in group_sizes]
              if group_sizes is not None else [(c, d, f)] * e)
    bm, bk, bn = _grouped_tile_for(shapes, tuner, tile)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return grouped_matmul_pallas(x, w, bm=bm, bk=bk, bn=bn, interpret=interp)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    sm_scale: float | None = None,
                    bq: int = 512, bkv: int = 512,
                    backend: Backend = "auto",
                    interpret: bool | None = None) -> jax.Array:
    be = resolve_backend(backend)
    if be == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, sm_scale=sm_scale)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  sm_scale=sm_scale, bq=bq, bkv=bkv,
                                  interpret=interp)
