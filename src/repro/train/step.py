"""Train-step builder: loss -> grad -> AdamW, mesh-aware.

``build_train_step`` returns (step_fn, state_specs, batch_specs) so the
launcher/dry-run can jit with explicit in/out shardings and lower against
ShapeDtypeStructs without allocating anything.
"""

from __future__ import annotations

import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_specs, partition_params, state_specs
from repro.kernels import recorder
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.transformer import Ctx
from repro.train.optim import (
    STATE_MOMENTS,
    AdamWConfig,
    adamw_update,
    init_state,
)

__all__ = ["build_train_step", "make_ctx", "abstract_state",
           "train_batch_sds"]


def make_ctx(mesh, mode: str, *, cache_len: int = 0,
             remat: bool = True, tuner=None) -> Ctx:
    # §Perf knob: ADSALA_KV_INT8=1 switches serving caches to int8
    kv_q = (os.environ.get("ADSALA_KV_INT8") == "1"
            and mode in ("prefill", "decode"))
    if mesh is None:
        return Ctx(mode=mode, cache_len=cache_len, remat=remat,
                   kv_quantized=kv_q, tuner=tuner)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return Ctx(mode=mode, mesh=mesh, dp_axes=dp, tp_axis="model",
               cache_len=cache_len, remat=remat, kv_quantized=kv_q,
               tuner=tuner)


def abstract_state(model, cfg: ArchConfig, opt_cfg: AdamWConfig,
                   dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct train state (no allocation) for .lower()."""
    from repro.models.params import abstract_params
    p = abstract_params(model.defs, dtype)
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    state = {"params": p}
    for key in STATE_MOMENTS:
        state[key] = f32
    state["step"] = jax.ShapeDtypeStruct((), jnp.int32)
    if opt_cfg.compress:
        state["ef"] = f32
    return state


def train_batch_sds(cfg: ArchConfig, shape: ShapeSpec,
                    dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        sds["audio_emb"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), dtype)
    return sds


def build_train_step(model, cfg: ArchConfig, shape: ShapeSpec, mesh,
                     opt_cfg: AdamWConfig | None = None, tuner=None):
    """Returns (train_step, state_spec_tree, batch_spec_tree).

    ``tuner`` is threaded to every routine-aware call site via the Ctx;
    the step also tags the backward-pass contractions: for each forward
    event the recorder collected while the loss traced, the two
    AD-transposed gemm shapes (dX, dW) are recorded, so a recorded
    train step shows forward *and* backward dispatch volume.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    ctx = make_ctx(mesh, "train", tuner=tuner)

    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)

    def train_step(state, batch):
        n0 = recorder.active_event_count()
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        recorder.record_backward(since=n0, tuner=tuner)
        new_state, metrics = adamw_update(state, grads, opt_cfg)
        metrics["loss"] = loss
        return new_state, metrics

    if mesh is None:
        return train_step, None, None
    p_specs = partition_params(model, cfg, mesh)
    s_specs = state_specs(p_specs, compress=opt_cfg.compress)
    b_specs = batch_specs(cfg, shape, mesh)
    return train_step, s_specs, b_specs


def init_train_state(model, cfg: ArchConfig, opt_cfg: AdamWConfig,
                     rng, dtype=jnp.float32) -> dict:
    return init_state(model.init(rng, dtype), opt_cfg)
