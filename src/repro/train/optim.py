"""Optimizer: AdamW with global-norm clipping, cosine schedule, and
optional int8 gradient compression with error feedback.

Pure-pytree implementation (no optax in container).  The state layout
{"params", "m", "v", "step"} mirrors the parameter tree so the sharding
specs derive mechanically (dist.sharding.state_specs).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "STATE_MOMENTS", "init_state", "adamw_update",
           "cosine_lr", "clip_by_global_norm", "compress_int8",
           "decompress_int8", "compressed_grads"]

#: moment keys of the AdamW state dict.  The sharding layer
#: (repro.dist.sharding.state_specs) and the abstract-state builder
#: (repro.train.step.abstract_state) mirror the param tree onto exactly
#: these keys, so a layout change here propagates mechanically.
STATE_MOMENTS = ("m", "v")


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    #: int8 + error-feedback gradient compression (cross-replica traffic
    #: reduction; the residual stays in the optimizer state)
    compress: bool = False


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_state(params: Any, cfg: AdamWConfig | None = None) -> dict:
    def zeros() -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    state: dict = {"params": params}
    for key in STATE_MOMENTS:
        state[key] = zeros()
    state["step"] = jnp.zeros((), jnp.int32)
    if cfg is not None and cfg.compress:
        state["ef"] = zeros()
    return state


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantisation; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grads(grads: Any, ef: Any) -> tuple[Any, Any]:
    """Quantise grads with error feedback: g' = Q(g + ef); ef' = g+ef-g'.

    In a multi-host deployment the int8 payload is what crosses the DCN
    boundary; in-XLA the quantise/dequantise pair also bounds the bf16
    all-reduce error accumulation.
    """
    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, s = compress_int8(tot)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), tot - deq

    flat = jax.tree.map(one, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_ef


def adamw_update(state: dict, grads: Any, cfg: AdamWConfig
                 ) -> tuple[dict, dict]:
    """One AdamW step; returns (new_state, metrics)."""
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    metrics = {"grad_norm": gnorm}
    if cfg.compress and "ef" in state:
        grads, new_ef = compressed_grads(grads, state["ef"])
    else:
        new_ef = state.get("ef")
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p - (lr * delta).astype(p.dtype)), m2, v2

    out = jax.tree.map(upd, state["params"], grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"params": new_params, "m": new_m, "v": new_v,
                 "step": step}
    if new_ef is not None:
        new_state["ef"] = new_ef
    metrics["lr"] = lr
    return new_state, metrics
