"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "build_model"]

_MODULES = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "granite-8b": "repro.configs.granite_8b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).smoke_config()


def build_model(cfg: ArchConfig):
    """Family dispatch: decoder-only LM vs encoder-decoder."""
    if cfg.family == "audio":
        from repro.models.encdec import build_encdec
        return build_encdec(cfg)
    from repro.models.transformer import build_lm
    return build_lm(cfg)
