"""starcoder2-3b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49_152,
    attn_kind="gqa",
    mlp_kind="gelu",
    norm_kind="layernorm",
    subquadratic=False,
    source="arXiv:2402.19173; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256)
