"""xlstm-125m [ssm] — alternating mLSTM / sLSTM blocks (d_ff=0: the
blocks carry their own projections). [arXiv:2405.04517; unverified]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    attn_kind="none",
    mlp_kind="none",
    norm_kind="layernorm",
    pattern=("mlstm", "slstm"),
    subquadratic=True,       # recurrent state only
    source="arXiv:2405.04517; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, vocab=256)
