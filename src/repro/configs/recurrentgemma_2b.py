"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 pattern.
[arXiv:2402.19427; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,             # (rglru, rglru, local) x 8 + 2 rglru
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,            # MQA in the local-attention layers
    d_ff=7680,
    vocab=256_000,
    attn_kind="gqa",
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    pattern=("rglru", "rglru", "local"),
    lru_width=2560,
    local_window=2048,
    conv_width=4,
    subquadratic=True,       # recurrent state + windowed attention
    source="arXiv:2402.19427; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=256, lru_width=64, local_window=32)
