"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,              # dense layer-0 FFN width
    d_ff_dense=12288,
    vocab=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,            # qk_nope + qk_rope
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1536,
    first_dense_layers=1,
    subquadratic=False,      # MLA is full attention -> long_500k skipped
    source="arXiv:2405.04434; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, d_ff_dense=128, vocab=256, kv_lora_rank=32,
        q_lora_rank=48, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
        head_dim=24, n_experts=8, top_k=2, n_shared_experts=1,
        d_ff_expert=32, first_dense_layers=1)
