"""chatglm3-6b [dense] — RoPE 2d (half-rotary), GQA. [arXiv:2406.12793; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65_024,
    attn_kind="gqa",
    rope_fraction=0.5,       # 2d/partial rotary
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    subquadratic=False,
    source="arXiv:2406.12793; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256)
