"""whisper-tiny [audio] — enc-dec, conv frontend (STUB: precomputed frame
embeddings). [arXiv:2212.04356; unverified]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    n_encoder_layers=4,
    encoder_len=1500,        # 30 s of audio at 50 Hz after the conv stub
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51_865,
    attn_kind="gqa",
    rope_fraction=0.0,       # learned positional embeddings
    mlp_kind="gelu",
    norm_kind="layernorm",
    subquadratic=False,
    source="arXiv:2212.04356; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, encoder_len=16,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256)
