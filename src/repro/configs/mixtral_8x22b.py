"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32_768,
    attn_kind="gqa",
    window=4096,             # SWA per the assignment line
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    n_experts=8,
    top_k=2,
    d_ff_expert=16384,
    subquadratic=True,       # SWA bounds the KV cache -> long_500k runs
    source="arXiv:2401.04088; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, d_ff_expert=128, vocab=256, n_experts=4, top_k=2,
        window=32)
