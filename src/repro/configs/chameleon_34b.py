"""chameleon-34b [vlm] — early-fusion, VQ image tokens (frontend STUB:
image tokens arrive pre-quantised in the vocab). [arXiv:2405.09818;
unverified]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65_536,
    attn_kind="gqa",
    qk_norm=True,            # chameleon's QK-norm for stability
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    subquadratic=False,
    source="arXiv:2405.09818; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256)
