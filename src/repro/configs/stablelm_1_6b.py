"""stablelm-1.6b [dense] — MHA, partial RoPE.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,           # full MHA per the assignment (kv=32)
    d_ff=5632,
    vocab=100_352,
    attn_kind="gqa",
    rope_fraction=0.25,      # stablelm-2 partial rotary
    mlp_kind="swiglu",
    norm_kind="layernorm",
    subquadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256)
