"""granite-8b [dense] — llama-arch, code. [arXiv:2405.04324; hf]"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49_152,
    attn_kind="gqa",
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    subquadratic=False,
    source="arXiv:2405.04324; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256)
