"""Fault tolerance: checkpoint/restart driver, stragglers, preemption.

Import :class:`~repro.ft.driver.TrainDriver` from ``repro.ft.driver``
(it pulls in jax via the checkpointer); the heartbeat helpers here are
jax-free and shared with the serving re-install manager.
"""

from repro.ft.heartbeat import read_heartbeat, write_heartbeat

__all__ = ["write_heartbeat", "read_heartbeat"]
