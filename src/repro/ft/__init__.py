"""Fault tolerance: checkpoint/restart driver, stragglers, preemption."""
