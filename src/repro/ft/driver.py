"""Fault-tolerant training driver.

Responsibilities at fleet scale (and their single-host test analogues):

* checkpoint/restart  — periodic async checkpoints; on start, resume
  from the newest committed step (crash-in-the-middle leaves only a
  .tmp dir, which restore ignores).
* preemption handling — SIGTERM triggers a synchronous final checkpoint
  before exit (TPU preemption notice path).
* straggler watch     — per-step wall time vs. running median; steps
  slower than ``straggler_factor`` x median are counted and surfaced
  (the fleet-level actor would re-schedule the slow host; here we
  expose the signal + hook).
* heartbeat           — a per-host heartbeat file updated each step; a
  coordinator watching mtimes detects dead hosts and triggers the
  elastic-restore path (restore onto the surviving mesh).
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Iterator

import numpy as np

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
)
from repro.ft.heartbeat import write_heartbeat

__all__ = ["DriverConfig", "TrainDriver"]


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    straggler_factor: float = 3.0
    heartbeat_path: str | None = None
    on_straggler: Callable[[int, float], None] | None = None


class TrainDriver:
    def __init__(self, cfg: DriverConfig, step_fn: Callable,
                 state: Any, data: Iterator, *,
                 state_template: Any = None, mesh=None, specs: Any = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.mesh = mesh
        self.specs = specs
        self.state_template = state_template if state_template is not None \
            else state
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.step = 0
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self.metrics_history: list[dict] = []
        self._preempted = False

    # -- lifecycle ----------------------------------------------------------
    def maybe_resume(self) -> int:
        last = latest_step(self.cfg.ckpt_dir)
        if last is not None:
            self.state = restore_checkpoint(
                self.cfg.ckpt_dir, last, self.state_template,
                mesh=self.mesh, specs=self.specs)
            self.step = last
        return self.step

    def _handle_preempt(self, signum, frame) -> None:  # pragma: no cover
        self._preempted = True

    def _heartbeat(self) -> None:
        if self.cfg.heartbeat_path:
            write_heartbeat(self.cfg.heartbeat_path, self.step)

    # -- main loop ------------------------------------------------------------
    def run(self) -> dict:
        old = signal.signal(signal.SIGTERM, self._handle_preempt)
        try:
            for batch in self.data:
                if self.step >= self.cfg.max_steps or self._preempted:
                    break
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                dt = time.perf_counter() - t0
                self.step += 1
                self.step_times.append(dt)
                self.metrics_history.append(
                    {k: float(v) for k, v in metrics.items()})
                self._heartbeat()
                # straggler detection on the step-time stream
                if len(self.step_times) >= 5:
                    med = float(np.median(self.step_times[-50:]))
                    if dt > self.cfg.straggler_factor * med:
                        self.straggler_steps.append(self.step)
                        if self.cfg.on_straggler:
                            self.cfg.on_straggler(self.step, dt)
                if self.step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(self.step, self.state)
        finally:
            signal.signal(signal.SIGTERM, old)
        # final (synchronous) checkpoint — preemption or normal exit
        self.ckpt.wait()
        from repro.ckpt.checkpoint import save_checkpoint
        save_checkpoint(self.cfg.ckpt_dir, self.step, self.state)
        return {
            "step": self.step,
            "preempted": self._preempted,
            "stragglers": list(self.straggler_steps),
            "last_metrics": (self.metrics_history[-1]
                             if self.metrics_history else {}),
        }
