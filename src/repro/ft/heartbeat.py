"""Liveness beacons, shared by the train driver and the serve loop.

jax-free on purpose: the serving re-install manager imports this and
must stay importable anywhere the installer runs (repro.launch.profile
has the same constraint).
"""

from __future__ import annotations

import time
from typing import Any

__all__ = ["write_heartbeat", "read_heartbeat"]


def write_heartbeat(path: str, tag: Any) -> None:
    """Overwrite ``path`` with ``"<tag> <unix time>"``.

    A coordinator watching mtimes (or reading the tag) detects dead or
    wedged workers.  The train driver stamps its step number per step;
    the serving re-install manager stamps its install phase, so a
    background install that dies mid-gather is distinguishable from one
    that never fired.
    """
    with open(path, "w") as f:
        f.write(f"{tag} {time.time()}")


def read_heartbeat(path: str) -> tuple[str, float]:
    """``(tag, unix_time)`` of the last beat."""
    with open(path) as f:
        tag, _, ts = f.read().strip().rpartition(" ")
    return tag, float(ts)
