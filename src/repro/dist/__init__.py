"""Distribution layer: logical-axis sharding rules + spec derivation."""

from repro.dist.sharding import (
    DATA_AXIS_RULES,
    MODEL_AXIS_RULES,
    TP_AXIS,
    abstract_mesh,
    auto_spec,
    batch_specs,
    data_axes,
    divisible_axes,
    is_partition_spec,
    logical_axis_dims,
    named_shardings,
    param_rules,
    partition_params,
    state_specs,
)

__all__ = [
    "DATA_AXIS_RULES", "MODEL_AXIS_RULES", "TP_AXIS", "abstract_mesh",
    "auto_spec", "batch_specs", "data_axes", "divisible_axes",
    "is_partition_spec", "logical_axis_dims", "named_shardings",
    "param_rules", "partition_params", "state_specs",
]
