"""Sharding subsystem: logical-axis rule tables + spec derivation.

The models declare parameters with *logical* axes ("vocab", "ff",
"heads", …, see :mod:`repro.models.params`); this module decides which
*physical* mesh axes carry each of them for a given (arch x mesh) cell —
the same decision the paper's tuner makes per GEMM (which chips, which
partition axis), lifted to whole parameter/activation trees.

Rule-table design
-----------------
Logical axes fall into three groups:

* ``MODEL_AXIS_RULES`` — weight dims that tensor-parallelism splits
  (vocab, ff, heads, kv_heads, expert_ff).  Candidate: the ``"model"``
  mesh axis.
* ``DATA_AXIS_RULES`` — dims carried by the data-parallel axes
  (``experts``: expert parallelism over ("pod", "data")).
* everything else (``embed``, ``layers``, ``lora``, unnamed) — always
  replicated.  ``embed`` is the contracted dim of every projection and
  ``lora`` ranks are small; replicating them keeps every PartitionSpec
  free of duplicate mesh axes by construction.

Every candidate is *divisibility-checked* against all dims that carry
the logical axis in the arch's actual ParamDef tree: a non-dividing
assignment is demoted (outermost axis dropped first, e.g.
``("pod", "data")`` -> ``("data",)``) or dropped to ``None`` entirely —
the GSPMD invariant that every sharded dim divides its mesh-axis
product.  mixtral's 8 experts on a 16-way data axis demote to ``None``
(its experts are split over the FF dim instead — ``expert_ff``), and
whisper's odd 51865-token vocab stays replicated.

Meshes are only read through ``.shape`` / ``.axis_names``, so a real
``jax.sharding.Mesh``, an ``AbstractMesh`` (see :func:`abstract_mesh`),
or any shape-shaped stand-in works — spec derivation never needs
devices.
"""

from __future__ import annotations

import math
from typing import Any, Iterable, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.params import ParamDef, param_specs

__all__ = [
    "TP_AXIS", "MODEL_AXIS_RULES", "DATA_AXIS_RULES",
    "abstract_mesh", "auto_spec", "batch_specs", "data_axes",
    "divisible_axes", "is_partition_spec", "logical_axis_dims",
    "named_shardings", "paged_spec", "param_rules", "partition_params",
    "state_specs",
]

#: the tensor-parallel mesh axis name (repro.launch.mesh convention)
TP_AXIS = "model"

#: logical axes whose dims tensor-parallelism splits
MODEL_AXIS_RULES = ("vocab", "ff", "heads", "kv_heads", "expert_ff")

#: logical axes carried by the data-parallel axes (expert parallelism)
DATA_AXIS_RULES = ("experts",)


def is_partition_spec(x: Any) -> bool:
    """Proper leaf test for PartitionSpec trees (no stringly class-name
    matching) — shared with :mod:`repro.ckpt.checkpoint`."""
    return isinstance(x, PartitionSpec)


def abstract_mesh(shape: dict[str, int]):
    """Device-free mesh stand-in from an ``{axis: size}`` dict — lets
    tests/benchmarks derive specs for 256/512-chip production meshes on
    a laptop."""
    from jax.sharding import AbstractMesh
    return AbstractMesh(tuple(shape.items()))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != TP_AXIS)


def _axes_size(axes: Sequence[str], mesh) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def divisible_axes(dims: int | Iterable[int], axes: Sequence[str], mesh
                   ) -> str | tuple[str, ...] | None:
    """Largest demotion of ``axes`` whose size divides every dim.

    Drops axes outermost-first (``("pod", "data")`` -> ``("data",)``)
    until the remaining product divides all of ``dims``; returns a bare
    axis name for a single survivor, a tuple for several, or ``None``
    when nothing divides — i.e. an entry ready to drop into a
    PartitionSpec.
    """
    if isinstance(dims, int):
        dims = (dims,)
    dims = tuple(dims)
    axes = tuple(axes)
    while axes and any(d % _axes_size(axes, mesh) for d in dims):
        axes = axes[1:]
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def logical_axis_dims(defs: Any) -> dict[str, set[int]]:
    """Map each logical axis name to every dim size it tags in ``defs``."""
    out: dict[str, set[int]] = {}
    for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
        for dim, axis in zip(d.shape, d.axes):
            if axis is not None:
                out.setdefault(axis, set()).add(dim)
    return out


def param_rules(cfg, mesh, defs: Any = None) -> dict[str, Any]:
    """Logical-axis -> mesh-axis rule table for one (arch x mesh) cell.

    Divisibility-aware: every assignment is checked against all dims the
    axis tags in the arch's ParamDef tree and demoted/dropped so the
    resulting specs satisfy the GSPMD invariant on any mesh shape.
    ``defs`` may be supplied (e.g. ``model.defs``) to skip rebuilding
    the model.
    """
    if defs is None:
        from repro.configs import build_model
        defs = build_model(cfg).defs
    dims = logical_axis_dims(defs)
    dp = data_axes(mesh)
    rules: dict[str, Any] = {}
    for name, sizes in dims.items():
        if name in MODEL_AXIS_RULES and TP_AXIS in mesh.axis_names:
            rules[name] = divisible_axes(sizes, (TP_AXIS,), mesh)
        elif name in DATA_AXIS_RULES:
            rules[name] = divisible_axes(sizes, dp, mesh)
        else:
            rules[name] = None
    return rules


def partition_params(model, cfg, mesh) -> Any:
    """PartitionSpec tree for a model's parameters on ``mesh``."""
    return param_specs(model.defs, param_rules(cfg, mesh, model.defs))


def auto_spec(shape: Sequence[int], mesh, batch_dim: int = 0
              ) -> PartitionSpec:
    """Heuristic spec for an activation/cache array.

    The batch dim goes to the data-parallel axes (demoted until they
    divide, ``None`` if nothing does); the largest remaining dim
    divisible by the 'model' axis carries tensor parallelism; everything
    else is replicated.
    """
    entries: list[Any] = [None] * len(shape)
    entries[batch_dim] = divisible_axes(shape[batch_dim], data_axes(mesh),
                                        mesh)
    if TP_AXIS in mesh.axis_names:
        tp = mesh.shape[TP_AXIS]
        best = -1
        for i, d in enumerate(shape):
            if i == batch_dim or tp < 2 or d % tp:
                continue
            if best < 0 or d > shape[best]:
                best = i
        if best >= 0:
            entries[best] = TP_AXIS
    return PartitionSpec(*entries)


def paged_spec(shape: Sequence[int], mesh, page_dim: int = 0
               ) -> PartitionSpec:
    """Spec for a paged KV pool — 2D (data x model) on one array.

    Page pools (:mod:`repro.serve.kv_cache`) carry no batch dim: the
    *page* dim is the parallel one, so it takes the data axes (demoted
    until they divide).  Tensor parallelism goes to the largest
    remaining dim divisible by 'model' — excluding the page-offset dim
    at ``page_dim + 1``: token slots within a page must stay whole on
    every shard or the page-table gather/scatter stops being local.
    Scan-stacked pools pass ``page_dim=1`` (dim 0 is the repeat dim,
    replicated like the 'layers' logical axis).
    """
    entries: list[Any] = [None] * len(shape)
    entries[page_dim] = divisible_axes(shape[page_dim], data_axes(mesh),
                                       mesh)
    if TP_AXIS in mesh.axis_names:
        tp = mesh.shape[TP_AXIS]
        best = -1
        for i, d in enumerate(shape):
            if i in (page_dim, page_dim + 1) or tp < 2 or d % tp:
                continue
            if best < 0 or d > shape[best]:
                best = i
        if best >= 0:
            entries[best] = TP_AXIS
    return PartitionSpec(*entries)


def batch_specs(cfg, shape, mesh) -> dict[str, PartitionSpec]:
    """Specs for one global batch (mirrors ``train_batch_sds`` /
    ``prefill_batch_sds`` key-for-key): batch over data axes, audio
    frame embeddings additionally over 'model' where divisible."""
    batch_entry = divisible_axes(shape.global_batch, data_axes(mesh), mesh)
    tok = PartitionSpec(batch_entry, None)
    specs = {"tokens": tok}
    if shape.kind == "train":
        specs["labels"] = tok
    if cfg.family == "audio":
        specs["audio_emb"] = auto_spec(
            (shape.global_batch, cfg.encoder_len, cfg.d_model), mesh,
            batch_dim=0)
    return specs


def state_specs(p_specs: Any, *, compress: bool = False) -> dict[str, Any]:
    """AdamW state specs derived mechanically from the param specs: the
    moments (and the error-feedback residual when gradient compression
    is on) mirror the parameter tree leaf-for-leaf, the step counter is
    replicated.  Layout keys come from :mod:`repro.train.optim` so the
    two can never drift."""
    from repro.train.optim import STATE_MOMENTS
    specs: dict[str, Any] = {"params": p_specs}
    for key in STATE_MOMENTS:
        specs[key] = p_specs
    specs["step"] = PartitionSpec()
    if compress:
        specs["ef"] = p_specs
    return specs


def named_shardings(mesh, specs: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree (``None`` passes through,
    for unconstrained outputs)."""
    if specs is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=is_partition_spec)
