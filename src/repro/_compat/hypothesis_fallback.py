"""Deterministic stand-in for the tiny slice of `hypothesis` the tests use.

The real property-based testing library is declared in pyproject.toml's
test extra, but the hermetic CI container cannot install it.  This module
implements just enough of its API — ``given``, ``settings``, ``assume``
and the ``integers`` / ``floats`` / ``sampled_from`` / ``lists``
strategies — to run the same property tests as fixed-seed example sweeps:

* every ``@given`` test executes ``max_examples`` times with inputs drawn
  from a per-test RNG seeded by a CRC of the test name (stable across
  processes and runs, unlike ``hash()``);
* the first two examples pin each strategy to its bounds, so boundary
  values are always exercised;
* ``sampled_from`` cycles its elements, guaranteeing full coverage.

When the real package is importable, tests/conftest.py leaves it alone —
this fallback only ever fills a missing import.
"""

from __future__ import annotations

import sys
import types
import zlib
from typing import Any, Callable, Sequence

import numpy as np

__all__ = ["given", "settings", "assume", "strategies", "install"]

_DEFAULT_MAX_EXAMPLES = 20


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to discard the current example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption
    return True


class SearchStrategy:
    """Base strategy: ``example(rng, i)`` draws the i-th example."""

    def example(self, rng: np.random.Generator, i: int) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int) -> None:
        self.min_value, self.max_value = int(min_value), int(max_value)

    def example(self, rng: np.random.Generator, i: int) -> int:
        if i == 0:
            return self.min_value
        if i == 1:
            return self.max_value
        return int(rng.integers(self.min_value, self.max_value + 1))


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float,
                 **_: Any) -> None:
        self.min_value, self.max_value = float(min_value), float(max_value)

    def example(self, rng: np.random.Generator, i: int) -> float:
        if i == 0:
            return self.min_value
        if i == 1:
            return self.max_value
        return float(rng.uniform(self.min_value, self.max_value))


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]) -> None:
        self.elements = list(elements)

    def example(self, rng: np.random.Generator, i: int) -> Any:
        return self.elements[i % len(self.elements)]


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, *, min_size: int = 0,
                 max_size: int | None = None, **_: Any) -> None:
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng: np.random.Generator, i: int) -> list:
        size = (self.min_size if i == 0
                else int(rng.integers(self.min_size, self.max_size + 1)))
        return [self.elements.example(rng, i) for _ in range(size)]


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **kw: Any) -> SearchStrategy:
    return _Floats(min_value, max_value, **kw)


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    return _SampledFrom(elements)


def lists(elements: SearchStrategy, **kw: Any) -> SearchStrategy:
    return _Lists(elements, **kw)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kw: SearchStrategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        def runner() -> None:
            n = getattr(runner, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode("utf-8")))
            for i in range(n):
                kw = {name: s.example(rng, i)
                      for name, s in strategy_kw.items()}
                try:
                    fn(**kw)
                except UnsatisfiedAssumption:
                    continue

        # plain zero-arg callable: pytest must not mistake the strategy
        # parameters for fixtures, so no functools.wraps/__wrapped__
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis_fallback = True  # type: ignore[attr-defined]
        return runner
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` in :data:`sys.modules`.

    Call only when the real package failed to import.
    """
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    st_mod.SearchStrategy = SearchStrategy
    mod.strategies = st_mod

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
