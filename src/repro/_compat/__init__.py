"""Compatibility shims for optional third-party packages.

The container this repo targets is hermetic: anything not already baked
into the image cannot be installed.  Modules here provide minimal,
deterministic stand-ins that keep the test suite and tooling runnable
when an optional dependency is absent.
"""
