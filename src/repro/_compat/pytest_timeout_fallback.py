"""SIGALRM-based stand-in for the ``pytest-timeout`` plugin.

``pytest-timeout`` is declared in pyproject.toml's test extra and CI
installs the real thing; the hermetic container cannot.  Without *some*
enforcement, the deflake budgets on the subprocess-spawning suites
(``tests/test_distributed.py``, the re-install fault-injection tests)
are decoration — a wedged child process hangs the whole lane instead of
failing one test.  This module implements the slice of the plugin the
suite relies on:

* ``--timeout=<seconds>`` / ``--timeout-method`` command-line options
  (the method is accepted for CLI compatibility; only the signal
  implementation exists here);
* the ``@pytest.mark.timeout(N)`` marker, nearest-to-the-test wins,
  ``timeout(0)`` disables;
* per-test wall-clock enforcement via ``signal.setitimer`` — the test
  fails with a ``Timeout >Ns`` error instead of hanging the run.

Enforcement is skipped (budgets become inert annotations, as on
Windows) when SIGALRM is unavailable or the run is not on the main
thread — exactly the platforms the real plugin falls back to its
thread method on.  tests/conftest.py registers this module as a plugin
ONLY when the real ``pytest_timeout`` fails to import, so an
environment with the package installed sees no behavior change.
"""

from __future__ import annotations

import signal
import threading
from typing import Any

import pytest

__all__ = ["addoption"]


def addoption(parser: Any) -> None:
    """Split out of pytest_addoption so tests/conftest.py can delegate
    (plugins registered from pytest_configure are too late for their
    own addoption hook to run)."""
    group = parser.getgroup("timeout-fallback")
    group.addoption(
        "--timeout", type=float, default=None,
        help="default per-test timeout in seconds "
             "(pytest-timeout fallback; 0 = disabled)")
    group.addoption(
        "--timeout-method", default="signal",
        choices=("signal", "thread"),
        help="accepted for pytest-timeout CLI compatibility; the "
             "fallback only implements signal")


def pytest_configure(config: Any) -> None:
    config.addinivalue_line(
        "markers",
        "timeout(seconds): fail the test if it runs longer than the "
        "wall-clock budget (pytest-timeout, or its signal-based "
        "fallback when the plugin is not installed)")


def _budget(item: Any) -> float | None:
    marker = item.get_closest_marker("timeout")
    if marker is not None and marker.args:
        return float(marker.args[0])
    if marker is not None and "seconds" in marker.kwargs:
        return float(marker.kwargs["seconds"])
    opt = item.config.getoption("--timeout", default=None)
    return float(opt) if opt else None


def _can_enforce() -> bool:
    return (hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread())


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item: Any):
    seconds = _budget(item)
    if not seconds or seconds <= 0 or not _can_enforce():
        yield
        return

    def on_alarm(signum: int, frame: Any) -> None:
        pytest.fail(f"Timeout >{seconds:g}s (pytest-timeout fallback)",
                    pytrace=False)

    prev = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, prev)
