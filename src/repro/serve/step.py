"""Serving-step builders: prefill and single-token decode, mesh-aware.

``serve_step`` for the decode shapes lowers decode (one new token against
a seq_len KV cache), NOT train, per the assignment.  Cache sharding uses
dist.sharding.auto_spec (batch over data axes, largest divisible dim —
the cache sequence/width dim — over 'model').
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    auto_spec,
    batch_specs,
    data_axes,
    divisible_axes,
    paged_spec,
    partition_params,
)
from repro.models.config import ArchConfig, ShapeSpec
from repro.train.step import make_ctx

__all__ = ["build_prefill", "build_decode", "build_decode_paged",
           "prefill_batch_sds", "decode_inputs_sds", "cache_specs",
           "cache_sds", "paged_cache_sds"]


def prefill_batch_sds(cfg: ArchConfig, shape: ShapeSpec,
                      dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        sds["audio_emb"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), dtype)
    return sds


def cache_sds(model, cfg: ArchConfig, shape: ShapeSpec,
              dtype=jnp.bfloat16) -> Any:
    """Abstract decode cache via eval_shape (no allocation)."""
    ctx = make_ctx(None, "decode", cache_len=shape.seq_len)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, ctx, dtype))


def paged_cache_sds(model, n_pages: int, page_size: int,
                    dtype=jnp.bfloat16) -> Any:
    """Abstract paged-pool tree via eval_shape (no allocation)."""
    ctx = make_ctx(None, "decode", cache_len=0)
    return jax.eval_shape(
        lambda: model.init_paged_cache(n_pages, page_size, ctx, dtype))


def _is_pool(x: Any) -> bool:
    from repro.serve.kv_cache import PagedKV, PagedLatent
    return isinstance(x, (PagedKV, PagedLatent))


def _segment_specs(tree: Any, mesh, *, batch_dim: int,
                   page_dim: int) -> Any:
    """Leaf specs for one cache segment: contiguous caches batch-shard
    via auto_spec, paged pools (batch-dim-free) page-shard via
    paged_spec — both 2D (data x model) on the same array."""
    def node_spec(node):
        if _is_pool(node):
            return jax.tree.map(
                lambda l: paged_spec(l.shape, mesh, page_dim=page_dim),
                node)
        return jax.tree.map(
            lambda l: auto_spec(l.shape, mesh, batch_dim=batch_dim),
            node)
    return jax.tree.map(node_spec, tree, is_leaf=_is_pool)


def cache_specs(cache_abstract: Any, mesh) -> Any:
    """Spec tree for decode caches (contiguous or paged).

    Scan-segment caches are stacked (R, B, ...) — batch (or the page
    dim, for paged pools) is dim 1; prefix/suffix (and whisper's plain
    list) caches have it at dim 0.
    """
    if isinstance(cache_abstract, dict) and "scan" in cache_abstract:
        return {
            "prefix": _segment_specs(cache_abstract["prefix"], mesh,
                                     batch_dim=0, page_dim=0),
            "scan": _segment_specs(cache_abstract["scan"], mesh,
                                   batch_dim=1, page_dim=1),
            "suffix": _segment_specs(cache_abstract["suffix"], mesh,
                                     batch_dim=0, page_dim=0),
        }
    return _segment_specs(cache_abstract, mesh, batch_dim=0, page_dim=0)


def decode_inputs_sds(model, cfg: ArchConfig, shape: ShapeSpec,
                      dtype=jnp.bfloat16) -> tuple:
    """(token, cache, pos) stand-ins for the decode serve_step."""
    b = shape.global_batch
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache = cache_sds(model, cfg, shape, dtype)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, cache, pos


def build_prefill(model, cfg: ArchConfig, shape: ShapeSpec, mesh,
                  tuner=None):
    """Returns (prefill_fn, param_specs, batch_specs, out description).

    ``tuner`` reaches every routine-aware call site through the Ctx, so
    a DispatchRecorder around the built function (or its jit trace)
    observes the prefill's routine mix — causal self-attention scores
    dispatch as SYRK, projections/MoE as GEMM.
    """
    ctx = make_ctx(mesh, "prefill", cache_len=shape.seq_len, remat=False,
                   tuner=tuner)

    if cfg.family == "audio":
        def prefill(params, batch):
            return model.prefill(params, batch, ctx)
    else:
        def prefill(params, batch):
            return model.prefill(params, batch["tokens"], ctx)

    if mesh is None:
        return prefill, None, None
    return (prefill, partition_params(model, cfg, mesh),
            batch_specs(cfg, shape, mesh))


def build_decode(model, cfg: ArchConfig, shape: ShapeSpec, mesh,
                 tuner=None):
    """Returns (decode_fn, param_specs, (token, cache, pos) specs).

    ``tuner`` reaches the decode call sites through the Ctx; the
    per-layer KV/latent cache updates dispatch as TRSM-adjacent events
    (sequential along the cache axis), observable by a recorder.
    """
    ctx = make_ctx(mesh, "decode", cache_len=shape.seq_len, tuner=tuner)

    def decode(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, ctx)

    if mesh is None:
        return decode, None, None
    p_specs = partition_params(model, cfg, mesh)
    cache_abs = cache_sds(model, cfg, shape)
    c_specs = cache_specs(cache_abs, mesh)
    t_spec = P(divisible_axes(shape.global_batch, data_axes(mesh), mesh),
               None)
    return decode, p_specs, (t_spec, c_specs, P())


def build_decode_paged(model, cfg: ArchConfig, *, slots: int,
                       n_pages: int, page_size: int, table_pages: int,
                       mesh, tuner=None):
    """Paged twin of :func:`build_decode` for the continuous-batching
    scheduler's step shapes.

    Returns (decode_fn, param_specs, (token, pool, pos, table) specs).
    ``pos`` is (slots,) per-sequence positions and ``table``
    (slots, table_pages) the page table — both batch-sharded over the
    data axes; the pool is page-sharded 2D via cache_specs/paged_spec.
    """
    cache_len = table_pages * page_size
    ctx = make_ctx(mesh, "decode", cache_len=cache_len, tuner=tuner)

    def decode(params, token, pool, pos, table):
        return model.decode_step(params, token, pool, pos, ctx, table)

    if mesh is None:
        return decode, None, None
    p_specs = partition_params(model, cfg, mesh)
    pool_abs = paged_cache_sds(model, n_pages, page_size)
    pool_specs = cache_specs(pool_abs, mesh)
    slot_entry = divisible_axes(slots, data_axes(mesh), mesh)
    return decode, p_specs, (P(slot_entry, None), pool_specs,
                             P(slot_entry), P(slot_entry, None))
