"""Continuous-batching decode loop over the paged KV cache.

Fixed-batch serving admits one batch and steps it until the *slowest*
sequence finishes: every early-finishing slot idles, so goodput on
ragged-length traffic collapses toward the longest request.  The
scheduler here keeps a fixed number of decode **slots** and a shared
page pool (:mod:`repro.serve.kv_cache`); per step it

1. admits queued requests into free slots — the prompt is prefilled at
   its exact length and its cache rows are seeded into freshly
   allocated pages,
2. decodes one token for *every* active slot with a single jitted
   paged ``decode_step`` (fixed shapes: the jit never retraces as
   sequences come and go),
3. retires finished sequences immediately — their pages re-enter the
   free list and the freed slot can admit the next request on the same
   step.

Admission reserves the worst case up front
(``pages_for(prompt + max_new - 1)``), so a running sequence can never
deadlock mid-decode waiting for pages; requests are admitted strictly
FIFO (a request that does not fit blocks the queue head — no
starvation of long prompts by short ones).

Dispatch observability: prefill traces record into the ``"prefill"``
recorder, decode traces into ``"decode"`` — the same per-traffic-class
split :mod:`repro.launch.serve` feeds the
:class:`~repro.serve.reinstall.ReinstallManager`, so the live ragged
mix drives online re-installs unchanged.  Recording is trace-time: a
new prompt length is a new prefill trace, so the recorded mix tracks
the shape diversity actually admitted.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import (
    HOLE,
    PageAllocator,
    pages_for,
    seed_pages,
)

__all__ = ["Request", "FinishedSeq", "ContinuousBatchingScheduler"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request (ragged prompt/output lengths)."""

    rid: int
    prompt: tuple[int, ...]
    max_new: int


@dataclasses.dataclass(frozen=True)
class FinishedSeq:
    """A retired sequence: the generated ids plus scheduling metadata."""

    rid: int
    prompt: tuple[int, ...]
    tokens: tuple[int, ...]
    admitted_step: int
    finished_step: int


def _seed_segment(pool_seg: Any, cache_seg: Any, page_ids: jax.Array,
                  stacked: bool) -> Any:
    """Write a prefill cache segment into the matching pool segment.

    The two trees differ in node type (``KVCache`` vs ``PagedKV``,
    ``MLACache`` vs ``PagedLatent``) but align leaf-for-leaf — k with
    k, v with v, c_kv with c_kv — so the zip below is the whole
    mapping.  ``stacked`` handles the scan segment's extra leading
    repeat dim ((R, 1, cap, ...) rows into (R, P, page, ...) pools).
    """
    leaves, treedef = jax.tree.flatten(pool_seg)
    vals = jax.tree.leaves(cache_seg)
    if len(leaves) != len(vals):
        raise ValueError(
            f"pool/prefill cache leaf mismatch ({len(leaves)} vs "
            f"{len(vals)}) — unsupported cache variant for paging")
    out = []
    for pl, vl in zip(leaves, vals):
        if stacked:
            out.append(jax.vmap(
                lambda pool, rows: seed_pages(pool, page_ids, rows)
            )(pl, vl[:, 0]))
        else:
            out.append(seed_pages(pl, page_ids, vl[0]))
    return jax.tree.unflatten(treedef, out)


class ContinuousBatchingScheduler:
    """Admit/retire-per-step decode loop over a shared page pool.

    Parameters
    ----------
    model, cfg, params : the LM triple (``repro.configs.build_model``).
    slots : decode batch width — the fixed shape of the jitted step.
    n_pages, page_size : the shared pool (total token slots in flight
        = ``n_pages * page_size``, the real memory ceiling).
    max_seq_len : per-sequence cap (prompt + generated); sets the page
        table width, and with it the gathered attention span.
    tuner : optional ADSALA tuner / ReinstallManager facade, threaded
        into every routine-aware call site of prefill and decode.
    recorders : ``{"prefill": DispatchRecorder, "decode": ...}`` — the
        per-traffic-class recorders; created when omitted.
    eos_id : optional early-stop token id (None = run to ``max_new``).

    Thread safety: ``submit`` may be called from any thread while one
    consumer thread runs ``step``/``run_until_drained``.
    """

    def __init__(self, model, cfg, params, *, slots: int, n_pages: int,
                 page_size: int, max_seq_len: int, tuner=None,
                 recorders: dict | None = None, dtype=jnp.float32,
                 eos_id: int | None = None) -> None:
        if not hasattr(model, "init_paged_cache"):
            raise NotImplementedError(
                "continuous batching needs a decoder-only LM with a "
                "paged cache (encoder-decoder serving is fixed-batch)")
        if slots < 1:
            raise ValueError(f"slots={slots} < 1")
        from repro.kernels.recorder import DispatchRecorder
        from repro.train.step import make_ctx

        self.model, self.cfg, self.params = model, cfg, params
        self.slots = int(slots)
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.table_pages = pages_for(max_seq_len, page_size)
        #: gathered attention span per sequence (token slots)
        self.cap = self.table_pages * self.page_size
        self.tuner = tuner
        self.eos_id = eos_id
        self.recorders = recorders if recorders is not None else {
            "prefill": DispatchRecorder(), "decode": DispatchRecorder()}
        self.alloc = PageAllocator(n_pages, page_size)

        self._dtype = dtype
        self._make_ctx = make_ctx
        self._dctx = make_ctx(None, "decode", cache_len=self.cap,
                              tuner=tuner)
        self.pool = model.init_paged_cache(n_pages, page_size,
                                           self._dctx, dtype)

        # host-side slot state
        self._table = np.full((slots, self.table_pages), HOLE, np.int32)
        self._pos = np.full((slots,), -1, np.int32)
        self._tok = np.zeros((slots,), np.int32)
        self._req: list[Request | None] = [None] * slots
        self._gen: list[list[int]] = [[] for _ in range(slots)]
        self._admit_step = [0] * slots

        self._lock = threading.Lock()
        self._queue: deque[Request] = deque()
        self._rids: set[int] = set()
        self._next_rid = 0
        self.finished: dict[int, FinishedSeq] = {}
        self.steps = 0
        self.admitted = 0

        self._decode = jax.jit(
            lambda p, pool, tok, pos, table: model.decode_step(
                p, tok, pool, pos, self._dctx, table),
            donate_argnums=(1,))
        self._prefills: dict[int, Callable] = {}

    # -- request intake -------------------------------------------------
    def submit(self, prompt, max_new: int, rid: int | None = None) -> int:
        """Queue one request; returns its rid.  Raises when the request
        could *never* run (exceeds the per-sequence cap or the whole
        pool) — deferral is for transient exhaustion only."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new={max_new} < 1")
        # the last generated token is returned, never written back, so
        # the sequence stores prompt + max_new - 1 token slots
        total = len(prompt) + max_new - 1
        if total > self.cap:
            raise ValueError(
                f"request needs {total} token slots > per-sequence cap "
                f"{self.cap} (max_seq_len)")
        if pages_for(total, self.page_size) > self.n_pages:
            raise ValueError(
                f"request needs {pages_for(total, self.page_size)} pages "
                f"> pool size {self.n_pages}: can never be admitted")
        with self._lock:
            if rid is None:
                while self._next_rid in self._rids:
                    self._next_rid += 1
                rid = self._next_rid
            if rid in self._rids:
                raise ValueError(f"duplicate rid {rid}")
            self._rids.add(rid)
            self._queue.append(Request(rid, prompt, max_new))
        return rid

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self._req)

    # -- admission ------------------------------------------------------
    def _prefill_fn(self, prompt_len: int) -> Callable:
        fn = self._prefills.get(prompt_len)
        if fn is None:
            # cache_len = whole pages, so the seeded rows reshape
            # cleanly into pages; prompt runs at its exact length so
            # logits_last sees the real last token, not padding
            cache_len = pages_for(prompt_len, self.page_size) \
                * self.page_size
            pctx = self._make_ctx(None, "prefill", cache_len=cache_len,
                                  remat=False, tuner=self.tuner)
            fn = jax.jit(
                lambda p, toks: self.model.prefill(p, toks, pctx))
            self._prefills[prompt_len] = fn
        return fn

    def _admit(self) -> None:
        while True:
            slot = next((i for i in range(self.slots)
                         if self._req[i] is None), None)
            if slot is None:
                return
            with self._lock:
                if not self._queue:
                    return
                req = self._queue[0]
                pages = self.alloc.admit(
                    req.rid, len(req.prompt) + req.max_new - 1)
                if pages is None:        # transient exhaustion: defer
                    return
                self._queue.popleft()
            self._start(slot, req, pages)

    def _start(self, slot: int, req: Request, pages: list[int]) -> None:
        n_prompt_pages = pages_for(len(req.prompt), self.page_size)
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        with self.recorders["prefill"]:
            logits, cache = self._prefill_fn(len(req.prompt))(
                self.params, toks)
        page_ids = jnp.asarray(
            np.asarray(pages[:n_prompt_pages], np.int32))
        pool = self.pool
        self.pool = {
            "prefix": _seed_segment(pool["prefix"], cache["prefix"],
                                    page_ids, stacked=False),
            "scan": (_seed_segment(pool["scan"], cache["scan"],
                                   page_ids, stacked=True)
                     if self.model.repeats else pool["scan"]),
            "suffix": _seed_segment(pool["suffix"], cache["suffix"],
                                    page_ids, stacked=False),
        }
        first = int(jnp.argmax(logits[0]))
        row = np.full((self.table_pages,), HOLE, np.int32)
        row[: len(pages)] = pages
        self._table[slot] = row
        self._pos[slot] = len(req.prompt)   # next decode writes here
        self._tok[slot] = first
        self._req[slot] = req
        self._gen[slot] = [first]
        self._admit_step[slot] = self.steps
        self.admitted += 1
        if req.max_new == 1 or first == self.eos_id:
            self._retire(slot)              # finished at prefill

    # -- the decode step ------------------------------------------------
    def step(self) -> bool:
        """Admit, decode one token for every active slot, retire.

        Returns False when there was nothing to do (no active slots
        after admission)."""
        self._admit()
        if self.active == 0:
            return False
        with self.recorders["decode"]:
            logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(self._tok[:, None]),
                jnp.asarray(self._pos), jnp.asarray(self._table))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.steps += 1
        for i in range(self.slots):
            req = self._req[i]
            if req is None:
                continue
            tok = int(nxt[i])
            self._gen[i].append(tok)
            self._pos[i] += 1
            self._tok[i] = tok
            if len(self._gen[i]) >= req.max_new or tok == self.eos_id:
                self._retire(i)
        self._admit()    # freed pages/slots serve the queue same-step
        return True

    def _retire(self, slot: int) -> None:
        req = self._req[slot]
        with self._lock:
            freed = self.alloc.retire(req.rid)
            assert freed == pages_for(
                len(req.prompt) + req.max_new - 1, self.page_size)
            self.finished[req.rid] = FinishedSeq(
                req.rid, req.prompt, tuple(self._gen[slot]),
                self._admit_step[slot], self.steps)
        self._table[slot] = HOLE
        self._pos[slot] = -1
        self._tok[slot] = 0
        self._req[slot] = None
        self._gen[slot] = []

    def run_until_drained(self, on_step: Callable | None = None,
                          max_steps: int = 1_000_000
                          ) -> dict[int, FinishedSeq]:
        """Step until queue and slots are empty; returns finished map.

        ``on_step(self)`` fires after every decode step — the hook the
        serve launcher uses for ReinstallManager drift checks.
        """
        idle_checks = 0
        while True:
            did = self.step()
            if did:
                idle_checks = 0
                if on_step is not None:
                    on_step(self)
            else:
                if self.pending == 0 and self.active == 0:
                    return dict(self.finished)
                idle_checks += 1
                if idle_checks > self.slots + 1:
                    raise RuntimeError(
                        "scheduler wedged: queued requests but nothing "
                        "admitted — pool/slot accounting broken")
            if self.steps > max_steps:
                raise RuntimeError(f"exceeded max_steps={max_steps}")

    # -- reporting ------------------------------------------------------
    @property
    def generated_tokens(self) -> int:
        return sum(len(f.tokens) for f in self.finished.values())

    def goodput(self) -> float:
        """Generated tokens per slot-step — 1.0 means every decode slot
        produced a kept token every step (the continuous-batching
        headline number; fixed-batch serving pays idle slots here)."""
        if self.steps == 0:
            return 0.0
        return self.generated_tokens / (self.steps * self.slots)
