"""Paged KV cache: host-side page allocator + device-side page pools.

The fixed-batch serving cache allocates ``B x cache_len`` token slots up
front, so per-chip cache memory caps the batch at
``B_max = mem / (cache_len * bytes_per_token)`` even when most requests
are far shorter than ``cache_len``.  Paging (vLLM, arXiv 2309.06180)
breaks the cache into fixed-size **pages** of ``page_size`` token slots
handed out from a free list; each sequence holds exactly the pages its
actual length needs, and a **page table** maps its logical token
positions to physical pages.  The ceiling becomes total *tokens in
flight*, not batch size — the property the continuous-batching
scheduler (:mod:`repro.serve.scheduler`) is built on.

Split of responsibilities:

* :class:`PageAllocator` — pure-Python free-list bookkeeping (admit /
  grow / retire), no jax.  Its invariants (no double-allocation,
  free + live conservation, clean failure on exhaustion) are the
  property-tested contract (tests/test_kv_cache_property.py).
* :class:`PagedKV` / :class:`PagedLatent` — registered pytrees holding
  one attention layer's page pool: ``(n_pages, page_size, ...)``
  arrays, the direct paged analogue of
  :class:`~repro.models.layers.KVCache` and
  :class:`~repro.models.mla.MLACache`.
* :func:`gather_pages` / :func:`append_token` / :func:`seed_pages` —
  the jittable fixed-shape device primitives the paged decode read
  path (``attention_decode_paged`` / ``mla_decode_paged``) is built
  from.  Holes in the page table are clamped on gather (the garbage
  rows land beyond every sequence's valid prefix, where the attention
  mask kills them) and routed out of bounds on scatter (dropped, never
  corrupting a live page).

Sharding: pools carry no batch dim — the page dim takes the
data-parallel axes and the head/width dim the model axis, both on the
same array (2D), via :func:`repro.dist.sharding.paged_spec`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "HOLE", "PageAllocator", "PagedKV", "PagedLatent",
    "gather_pages", "append_token", "seed_pages", "pages_for",
]

#: page-table entry marking an unallocated slot
HOLE = -1


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` token slots (ceil division)."""
    if n_tokens < 0:
        raise ValueError(f"n_tokens={n_tokens} < 0")
    return -(-n_tokens // page_size)


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Free-list page allocator with per-sequence page accounting.

    All-or-nothing: :meth:`admit` and :meth:`grow` either return the
    full list of newly allocated page ids or ``None`` with the
    allocator state untouched — a caller that cannot get its pages
    defers (re-queues the request), it never observes a half-allocated
    sequence.  :meth:`retire` frees exactly the sequence's pages.

    The invariants the property suite pins:

    * a page is never handed out twice while live;
    * ``free_pages + live_pages == n_pages`` after every operation;
    * retiring a sequence frees exactly the page count it held;
    * exhaustion returns ``None`` and changes nothing.
    """

    def __init__(self, n_pages: int, page_size: int) -> None:
        if n_pages <= 0:
            raise ValueError(f"n_pages={n_pages} <= 0")
        if page_size <= 0:
            raise ValueError(f"page_size={page_size} <= 0")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free stack: recently retired pages are re-used first,
        # keeping the hot pool compact
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._live: dict[int, list[int]] = {}

    # -- views ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return sum(len(p) for p in self._live.values())

    @property
    def live_seqs(self) -> tuple[int, ...]:
        return tuple(self._live)

    def pages_of(self, seq_id: int) -> list[int]:
        """The sequence's pages in logical order (copy)."""
        return list(self._live[seq_id])

    def can_admit(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page_size) <= len(self._free)

    # -- mutations ------------------------------------------------------
    def admit(self, seq_id: int, n_tokens: int) -> list[int] | None:
        """Allocate pages for a new sequence of ``n_tokens`` slots.

        Returns the page ids (logical order) or ``None`` when the pool
        cannot cover the request — admission deferred, nothing changed.
        """
        if seq_id in self._live:
            raise ValueError(f"seq {seq_id} already live")
        need = pages_for(n_tokens, self.page_size)
        if need == 0:
            raise ValueError(f"admit of empty sequence {seq_id}")
        if need > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._live[seq_id] = pages
        return pages

    def grow(self, seq_id: int, n_tokens_total: int) -> list[int] | None:
        """Extend a live sequence to ``n_tokens_total`` slots.

        Returns the *newly* allocated page ids ([] when already
        covered) or ``None`` when the pool is exhausted — the sequence
        keeps its current pages, nothing is partially allocated.
        """
        held = self._live[seq_id]
        need = pages_for(n_tokens_total, self.page_size) - len(held)
        if need <= 0:
            return []
        if need > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(need)]
        held.extend(fresh)
        return fresh

    def retire(self, seq_id: int) -> int:
        """Free a live sequence's pages; returns how many were freed."""
        pages = self._live.pop(seq_id)
        self._free.extend(pages)
        return len(pages)

    def check(self) -> None:
        """Raise AssertionError when any allocator invariant is broken."""
        live = [p for pages in self._live.values() for p in pages]
        assert len(set(live)) == len(live), "double-allocated live page"
        assert not set(live) & set(self._free), "live page on free list"
        assert len(live) + len(self._free) == self.n_pages, \
            "page conservation violated"
        assert all(0 <= p < self.n_pages for p in live + self._free)


# ---------------------------------------------------------------------------
# Device-side pools
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PagedKV:
    """One attention layer's page pool — the paged
    :class:`~repro.models.layers.KVCache`.  ``k``/``v``:
    ``(n_pages, page_size, n_kv_heads, head_dim)``."""

    k: jax.Array
    v: jax.Array

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]


@dataclasses.dataclass
class PagedLatent:
    """One MLA layer's page pool — the paged
    :class:`~repro.models.mla.MLACache`.  ``c_kv``:
    ``(n_pages, page_size, kv_lora_rank)``, ``k_rope``:
    ``(n_pages, page_size, qk_rope_dim)``."""

    c_kv: jax.Array
    k_rope: jax.Array

    @property
    def page_size(self) -> int:
        return self.c_kv.shape[-2]


jax.tree_util.register_dataclass(PagedKV, data_fields=["k", "v"],
                                 meta_fields=[])
jax.tree_util.register_dataclass(PagedLatent,
                                 data_fields=["c_kv", "k_rope"],
                                 meta_fields=[])


def init_paged_kv(n_pages: int, page_size: int, n_kv_heads: int,
                  head_dim: int, dtype: jnp.dtype) -> PagedKV:
    shape = (n_pages, page_size, n_kv_heads, head_dim)
    return PagedKV(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_paged_latent(n_pages: int, page_size: int, kv_lora_rank: int,
                      qk_rope_dim: int, dtype: jnp.dtype) -> PagedLatent:
    return PagedLatent(
        jnp.zeros((n_pages, page_size, kv_lora_rank), dtype),
        jnp.zeros((n_pages, page_size, qk_rope_dim), dtype))


# ---------------------------------------------------------------------------
# Jittable device primitives
# ---------------------------------------------------------------------------

def gather_pages(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Page-table gather: ``(P, page, ...)`` pool + ``(B, T)`` table ->
    a contiguous per-sequence ``(B, T*page, ...)`` view.

    Holes (:data:`HOLE`) clamp to page 0; whatever that page holds
    lands at token slots at/after the sequence's allocated prefix,
    where the downstream ``kv_ids <= pos`` attention mask zeroes it —
    the gathered view is bitwise-safe without a select."""
    b, t = table.shape
    page = pages.shape[1]
    gathered = jnp.take(pages, jnp.clip(table, 0, pages.shape[0] - 1),
                        axis=0)
    return gathered.reshape((b, t * page) + pages.shape[2:])


def append_token(pages: jax.Array, table: jax.Array, pos: jax.Array,
                 new: jax.Array, active: jax.Array) -> jax.Array:
    """Write one token per sequence: ``new[b]`` lands at physical slot
    ``(table[b, pos[b] // page], pos[b] % page)``.

    Inactive slots (and holes) are routed to page id ``n_pages`` —
    out of bounds, so the scatter drops them (``mode="drop"``) instead
    of corrupting page 0.  Live sequences own disjoint pages, so the
    per-``b`` scatter indices never collide.
    """
    n_pages, page = pages.shape[:2]
    cap = table.shape[1] * page
    idx = jnp.clip(pos, 0, cap - 1)
    page_ix = jnp.take_along_axis(table, (idx // page)[:, None],
                                  axis=1)[:, 0]
    ok = active & (page_ix >= 0)
    page_ix = jnp.where(ok, page_ix, n_pages)
    return pages.at[page_ix, idx % page].set(new, mode="drop")


def seed_pages(pages: jax.Array, page_ids: jax.Array,
               values: jax.Array) -> jax.Array:
    """Bulk-write a prompt's cache rows into freshly allocated pages.

    ``values``: ``(n * page, ...)`` contiguous token rows (pad to a
    page multiple first), scattered as ``n`` whole pages at
    ``page_ids``."""
    n = page_ids.shape[0]
    page = pages.shape[1]
    vals = values.reshape((n, page) + pages.shape[2:])
    return pages.at[page_ids].set(vals)
