"""Drift-triggered online re-install with atomic artifact hot-swap.

Closes the serving loop of the model-driven adaptive-libraries line of
work (arXiv 1806.07060): PR 5 taught serve to *measure* total-variation
drift between the live dispatch mix and the installed
:class:`~repro.core.workload.WorkloadProfile` but only warned above a
threshold.  The :class:`ReinstallManager` here makes the loop closed —

    live DispatchRecorder(s)
      -> WorkloadProfile (per traffic class, volume-weighted merge)
      -> drift vs the installed profile (routine mix AND shape cells)
      -> threshold crossing, debounced by hysteresis + cooldown
      -> mix-weighted, budget-capped install() in a BACKGROUND thread
      -> atomic artifact hot-swap under traffic

The swap is atomic at both layers, reusing the write-to-tmp +
commit-sentinel + rename idiom of the checkpoint/FT stack
(``repro.ckpt.checkpoint`` / ``repro.ft.driver``):

* **on disk** — the install writes into ``<artifact>.tmp/``, a
  ``COMMIT`` sentinel lands only after both artifact files are
  complete, and :func:`~repro.core.installer.commit_artifact` promotes
  it with two ``os.replace`` renames, retaining the displaced artifact
  at ``<artifact>.prev/`` for one-call :meth:`ReinstallManager.rollback`.
  A killed install leaves an uncommitted tmp that
  :func:`~repro.core.installer.resolve_artifact` ignores and sweeps at
  the next boot.
* **in memory** — :meth:`AdsalaTuner.swap_from_artifact
  <repro.core.tuner.AdsalaTuner.swap_from_artifact>` builds a fresh
  tuner (hot working set re-selected through the NEW model), and the
  manager publishes it with a single reference assignment.  Serving
  threads read that reference once per dispatch, so every select runs
  entirely against one tuner: no dropped or blocked dispatch, never a
  torn old/new mix, and the per-instance LRU means stale cache hits
  cannot cross a swap.

The manager quacks like an :class:`~repro.core.tuner.AdsalaTuner`
(``select`` / ``select_many`` / ``select_with_times`` / ``peek`` /
``routines`` / ``workload``), so it drops into ``Ctx.tuner`` and the
``repro.kernels.ops`` dispatch path unchanged.

jax-free on purpose, like ``repro.launch.profile``: drift checks and
installs run anywhere the simulated/measured timing backends do.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import time
import warnings
from typing import Any, Callable, Mapping

from repro.core.costmodel import ROUTINES
from repro.core.installer import (
    ARTIFACT_COMMIT,
    InstallConfig,
    artifact_tmp_dir,
    commit_artifact,
    gather_data,
    install,
    resolve_artifact,
    rollback_artifact,
)
from repro.core.registry import ArtifactRegistry, HardwareFingerprint
from repro.core.timing import SimulatedBackend, backend_from_dict
from repro.core.tuner import AdsalaTuner
from repro.core.workload import WorkloadProfile
from repro.ft.heartbeat import write_heartbeat

__all__ = ["DriftTrigger", "ReinstallConfig", "ReinstallManager"]

#: background-install phases, in order; the fault-injection tests kill
#: the install at each of these points and assert the old artifact
#: keeps serving (see tests/test_reinstall.py)
PHASES = ("profile", "gather", "fit", "write", "commit", "swap")


@dataclasses.dataclass
class DriftTrigger:
    """Threshold crossing with hysteresis + cooldown (no thrash).

    Pure state machine — :meth:`observe` takes the measured drift and a
    caller-supplied clock so the invariants are property-testable
    without threads or installs:

    * fires only while **armed** and ``drift > threshold``;
    * firing disarms; re-arming requires drift to first fall to
      ``threshold - hysteresis`` or below (an oscillating mix that
      hovers around the threshold fires once, not per crossing);
    * two fires are always ``>= cooldown_s`` apart, regardless of the
      drift trajectory in between.
    """

    threshold: float = 0.25
    hysteresis: float = 0.05
    cooldown_s: float = 300.0
    armed: bool = True
    last_fire: float | None = None

    def __post_init__(self) -> None:
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError(f"threshold={self.threshold} outside (0, 1]")
        if not 0.0 <= self.hysteresis <= self.threshold:
            raise ValueError(f"hysteresis={self.hysteresis} outside "
                             f"[0, threshold={self.threshold}]")
        if self.cooldown_s < 0.0:
            raise ValueError(f"cooldown_s={self.cooldown_s} < 0")

    def observe(self, drift: float, now: float) -> bool:
        """Feed one drift measurement; True = fire a re-install now."""
        if drift <= max(self.threshold - self.hysteresis, 0.0):
            self.armed = True
        if not self.armed or drift <= self.threshold:
            return False
        if (self.last_fire is not None
                and now - self.last_fire < self.cooldown_s):
            return False
        self.armed = False
        self.last_fire = now
        return True


def _default_install_template() -> InstallConfig:
    """Budget-capped background install: every routine keeps floor
    coverage (the manager must never narrow the tuner's routine set —
    the dispatch path consults ``tuner.routines`` and a narrowing swap
    could strand an in-flight routine check), one fast boosting model,
    beam-survivor timing instead of the dense grid."""
    return InstallConfig(
        n_samples=160, repeats=2, routines=tuple(ROUTINES),
        models=("lightgbm",), timing_budget=2000, beam_width=8,
        cv_splits=2)


@dataclasses.dataclass
class ReinstallConfig:
    """Policy knobs of the closed serving loop."""

    #: drift (total variation, [0, 1]) above which a re-install fires
    threshold: float = 0.25
    #: re-arm band: after a fire, drift must fall to
    #: ``threshold - hysteresis`` before another fire is possible
    hysteresis: float = 0.05
    #: minimum wall-clock seconds between fires
    cooldown_s: float = 300.0
    #: recorded events (across all traffic classes) below which the
    #: live mix is noise, not signal — no fire
    min_events: int = 64
    #: dispatch-volume weighting of the live profile; keep "flops" to
    #: match dryrun/profile-built install profiles
    by: str = "flops"
    #: install template for each fire; ``workload`` and ``seed`` are
    #: filled per fire (the live profile snapshot, template seed + fire
    #: count).  None = :func:`_default_install_template`.
    install: InstallConfig | None = None
    #: transplant the outgoing tuner's hot shape set into the new one
    #: (re-selected through the NEW model; see swap_from_artifact)
    carry_warm: bool = True
    #: liveness beacon stamped with the install phase (ft idiom); a
    #: coordinator watching mtimes can tell a dead install from an
    #: idle manager
    heartbeat_path: str | None = None


class ReinstallManager:
    """Watches live dispatch drift and hot-swaps the tuner artifact.

    Drop-in tuner: pass the manager wherever an
    :class:`~repro.core.tuner.AdsalaTuner` goes (``make_ctx(...,
    tuner=manager)``).  Every delegated call reads the current tuner
    reference exactly once, so a concurrent swap can never hand half a
    dispatch to each artifact.

    ``recorders`` is one live
    :class:`~repro.kernels.recorder.DispatchRecorder` or a mapping of
    traffic-class name (e.g. ``"prefill"`` / ``"decode"``) to recorder;
    per-class profiles are merged volume-weighted by recorded flops, so
    the install budget follows where serving volume actually is.

    :meth:`check` is the loop body: measure drift, debounce through the
    :class:`DriftTrigger`, and on a fire run the whole
    profile → gather → fit → write → commit → swap pipeline on a
    daemon thread while serving continues.  Injected faults / kills at
    any phase leave the live tuner serving the old artifact and at
    worst an uncommitted ``.tmp`` that the next boot sweeps.
    """

    def __init__(self, artifact_dir: str | None = None,
                 recorders: "Any | Mapping[str, Any]" = None, *,
                 backend: Any = None,
                 registry: "ArtifactRegistry | str | None" = None,
                 fingerprint: HardwareFingerprint | None = None,
                 cfg: ReinstallConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 phase_hook: Callable[[str], None] | None = None,
                 **tuner_kw: Any) -> None:
        #: re-installs target this machine's registry cell when a
        #: registry is given: the loop can never overwrite a
        #: neighbour's artifact with locally-corrected timings.
        self.registry = (ArtifactRegistry(registry)
                         if isinstance(registry, str) else registry)
        self.fingerprint = fingerprint
        if self.registry is not None:
            if self.fingerprint is None:
                # key-only collection: the cell address needs the stable
                # fields, not the ~10ms timed probe
                self.fingerprint = HardwareFingerprint.collect(
                    probe_sizes=())
            artifact_dir = self.registry.register(self.fingerprint)
        if artifact_dir is None:
            raise ValueError("pass artifact_dir= or registry=")
        self.artifact_dir = artifact_dir
        if resolve_artifact(artifact_dir) is None:
            raise FileNotFoundError(
                f"no servable artifact at {artifact_dir} (and no "
                ".prev to recover from)")
        self.cfg = cfg or ReinstallConfig()
        if self.cfg.by not in ("flops", "events"):
            raise ValueError(f"by={self.cfg.by!r}; expected 'flops' or "
                             "'events'")
        self._recorders: dict[str, Any] = (
            {} if recorders is None
            else dict(recorders) if isinstance(recorders, Mapping)
            else {"all": recorders})
        self.trigger = DriftTrigger(threshold=self.cfg.threshold,
                                    hysteresis=self.cfg.hysteresis,
                                    cooldown_s=self.cfg.cooldown_s)
        self._clock = clock
        self._phase_hook = phase_hook
        self._tuner = AdsalaTuner.from_artifact(
            artifact_dir, local_fingerprint=self.fingerprint, **tuner_kw)
        # Re-install with the same KIND of backend that built the loaded
        # artifact (its "backend" provenance block): a measured install
        # must not silently drift back to the simulator on re-install.
        # An explicit backend= always wins; legacy artifacts (no block)
        # or unreconstructable kinds fall back to the simulator.
        if backend is None and self._tuner.backend_info is not None:
            try:
                backend = backend_from_dict(self._tuner.backend_info)
            except (ValueError, KeyError, TypeError) as e:
                warnings.warn(
                    f"cannot rebuild the artifact's install backend "
                    f"({e}); re-installs will use the simulated "
                    "backend", stacklevel=2)
        self.backend = backend if backend is not None else \
            SimulatedBackend(seed=0)
        self._state_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._installing = False
        #: completed hot-swaps (in-memory tuner replacements)
        self.swaps = 0
        #: fires (background installs launched, successful or not)
        self.fires = 0
        self.last_drift: float | None = None
        self.last_error: BaseException | None = None
        self.last_report: Any = None

    # -- tuner facade ---------------------------------------------------
    # Each method binds self._tuner ONCE; the swap is a reference
    # assignment, so an in-flight call completes on the tuner it bound.
    @property
    def tuner(self) -> AdsalaTuner:
        return self._tuner

    @property
    def routines(self) -> tuple[str, ...]:
        return self._tuner.routines

    @property
    def workload(self) -> WorkloadProfile | None:
        return self._tuner.workload

    @property
    def space(self) -> Any:
        return self._tuner.space

    @property
    def candidates(self) -> list:
        return self._tuner.candidates

    @property
    def stats(self) -> dict:
        return self._tuner.stats

    def select(self, m: int, k: int, n: int, routine: str = "gemm",
               **kw: Any):
        return self._tuner.select(m, k, n, routine, **kw)

    def select_many(self, shapes, routines=None, **kw: Any):
        return self._tuner.select_many(shapes, routines=routines, **kw)

    def select_with_times(self, m: int, k: int, n: int,
                          routine: str = "gemm"):
        return self._tuner.select_with_times(m, k, n, routine)

    def peek(self, m: int, k: int, n: int,
             routine: str = "gemm") -> bool:
        return self._tuner.peek(m, k, n, routine)

    def predicted_times_many(self, shapes, routines=None, **kw: Any):
        return self._tuner.predicted_times_many(shapes,
                                                routines=routines, **kw)

    def workload_drift(self, observed) -> float | None:
        return self._tuner.workload_drift(observed)

    # -- drift watch ----------------------------------------------------
    def events_total(self) -> int:
        return sum(len(rec.events) for rec in self._recorders.values())

    def live_profile(self) -> WorkloadProfile | None:
        """The recorded serving mix as one profile: per-traffic-class
        profiles merged volume-weighted (a class that dispatched 10x
        the flops pulls the install budget 10x harder).  None until any
        class has recorded an event."""
        per_class = [
            WorkloadProfile.from_recorder(
                rec, by=self.cfg.by,
                source={"kind": "serve-live", "traffic_class": name})
            for name, rec in self._recorders.items() if rec.events]
        if not per_class:
            return None
        if len(per_class) == 1:
            return per_class[0]
        return WorkloadProfile.merge(
            per_class, source={"kind": "serve-live"})

    def drift(self) -> float | None:
        """Live drift vs the installed profile (None when either side
        is missing — an uniform-install artifact never fires)."""
        installed = self._tuner.workload
        live = self.live_profile()
        if installed is None or live is None:
            return None
        return installed.drift(live)

    @property
    def installing(self) -> bool:
        return self._installing

    def check(self) -> bool:
        """One loop iteration: measure drift, maybe fire a background
        re-install.  Returns True when an install was launched.  Cheap
        and non-blocking either way — call it from the serve loop."""
        live = self.live_profile()
        installed = self._tuner.workload
        if live is None or installed is None:
            return False
        drift = installed.drift(live)
        self.last_drift = drift
        with self._state_lock:
            if self._installing:
                # still, feed the trigger so re-arming tracks recovery
                self.trigger.observe(drift, self._clock())
                return False
            if self.events_total() < self.cfg.min_events:
                return False
            if not self.trigger.observe(drift, self._clock()):
                return False
            self._installing = True
            self.fires += 1
            fire_seq = self.fires
        self._thread = threading.Thread(
            target=self._install_once, args=(live, fire_seq),
            name=f"adsala-reinstall-{fire_seq}", daemon=True)
        self._thread.start()
        return True

    def wait(self, timeout: float | None = None) -> bool:
        """Join the background install (True when none is running)."""
        t = self._thread
        if t is not None:
            t.join(timeout)
        return not self._installing

    # -- the background install -----------------------------------------
    def _phase(self, name: str) -> None:
        if self.cfg.heartbeat_path:
            write_heartbeat(self.cfg.heartbeat_path, name)
        if self._phase_hook is not None:
            self._phase_hook(name)

    def _install_template(self) -> InstallConfig:
        return (self.cfg.install if self.cfg.install is not None
                else _default_install_template())

    def _install_once(self, profile: WorkloadProfile,
                      fire_seq: int) -> None:
        """Profile -> gather -> fit -> write -> commit -> swap.

        Any exception (including an injected fault) aborts the install:
        the live tuner keeps serving the old artifact and on-disk state
        is at worst an uncommitted ``.tmp`` (a killed install's debris,
        swept by resolve_artifact at the next boot or by the next fire).
        """
        tmp = artifact_tmp_dir(self.artifact_dir)
        try:
            self._phase("profile")
            template = self._install_template()
            icfg = dataclasses.replace(
                template, workload=profile,
                seed=template.seed + fire_seq,
                # keep the cell's provenance through re-installs: the
                # new artifact is for the same machine (this one)
                fingerprint=(self.fingerprint
                             if self.fingerprint is not None
                             else self._tuner.fingerprint))
            self._phase("gather")
            data = gather_data(self.backend, icfg)
            self._phase("fit")
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)          # stale debris of a crash
            report = install(self.backend, icfg, data=data,
                             artifact_dir=tmp)
            self._phase("write")
            # sentinel last, after both artifact files are complete —
            # the checkpoint COMMIT idiom; commit_artifact refuses
            # tmp dirs without it
            with open(os.path.join(tmp, ARTIFACT_COMMIT), "w") as f:
                f.write("ok")
            self._phase("commit")
            commit_artifact(tmp, self.artifact_dir)
            self._phase("swap")
            old = self._tuner
            new = old.swap_from_artifact(
                self.artifact_dir, carry_warm=self.cfg.carry_warm,
                search_width=old.search_width)
            self._tuner = new               # THE swap: one reference
            self.last_report = report
            self.last_error = None
            self.swaps += 1
            if self.cfg.heartbeat_path:
                write_heartbeat(self.cfg.heartbeat_path, "idle")
        except BaseException as e:          # noqa: BLE001 — must never
            self.last_error = e             # take the serve loop down
        finally:
            self._installing = False

    # -- manual lifecycle ------------------------------------------------
    def swap_now(self, artifact_dir: str | None = None) -> AdsalaTuner:
        """Synchronous in-memory swap from an on-disk artifact (the
        manager's own by default).  Used by rollback, ops tooling and
        the race tests; the drift-triggered path ends in the same
        single-reference assignment."""
        src = artifact_dir if artifact_dir is not None \
            else self.artifact_dir
        old = self._tuner
        new = old.swap_from_artifact(
            src, carry_warm=self.cfg.carry_warm,
            search_width=old.search_width)
        self._tuner = new
        self.swaps += 1
        return new

    def rollback(self) -> None:
        """Swap ``<artifact>.prev/`` back in, on disk and in memory.
        Pure renames on disk — the restored artifact is byte-for-byte
        what the last commit displaced."""
        self.wait()
        rollback_artifact(self.artifact_dir)
        self.swap_now()
