"""Serving substrate: prefill/decode builders, cache sharding, the
paged-KV continuous-batching scheduler, and the drift-triggered online
re-install loop.

``repro.serve.step`` / ``kv_cache`` / ``scheduler`` pull in jax; the
re-install manager below is jax-free on purpose (it runs against the
simulated/measured timing backends), so it is safe to re-export
eagerly — the jax-backed names resolve lazily.
"""

from repro.serve.reinstall import (
    DriftTrigger,
    ReinstallConfig,
    ReinstallManager,
)

__all__ = ["DriftTrigger", "ReinstallConfig", "ReinstallManager",
           "ContinuousBatchingScheduler", "Request", "FinishedSeq",
           "PageAllocator", "PagedKV", "PagedLatent"]

_LAZY = {
    "ContinuousBatchingScheduler": "repro.serve.scheduler",
    "Request": "repro.serve.scheduler",
    "FinishedSeq": "repro.serve.scheduler",
    "PageAllocator": "repro.serve.kv_cache",
    "PagedKV": "repro.serve.kv_cache",
    "PagedLatent": "repro.serve.kv_cache",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
