"""Serving substrate: prefill/decode builders, cache sharding."""
