"""Serving substrate: prefill/decode builders, cache sharding, and the
drift-triggered online re-install loop.

``repro.serve.step`` pulls in jax; the re-install manager below is
jax-free on purpose (it runs against the simulated/measured timing
backends), so it is safe to re-export eagerly.
"""

from repro.serve.reinstall import (
    DriftTrigger,
    ReinstallConfig,
    ReinstallManager,
)

__all__ = ["DriftTrigger", "ReinstallConfig", "ReinstallManager"]
