"""Parameter definition + initialisation + logical-axis sharding.

Every model declares its parameters as a pytree of :class:`ParamDef`
(shape, init, *logical* axes).  Logical axes ("vocab", "ff", "heads",
"experts", …) are mapped to physical mesh axes by the distribution
layer's rule table — the same pattern MaxText/T5X use, so sharding is a
config concern, not a model concern.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

__all__ = ["ParamDef", "init_params", "param_specs", "count_params"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # None -> 1/sqrt(fan_in)

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _init_one(d: ParamDef, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = d.scale if d.scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, d.shape, jnp.float32) * scale
            ).astype(dtype)


def init_params(defs: Any, rng: jax.Array,
                dtype: jnp.dtype = jnp.float32) -> Any:
    """Materialise a ParamDef pytree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    arrays = [_init_one(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs: Any, dtype: jnp.dtype = jnp.float32) -> Any:
    """ShapeDtypeStruct pytree (for .lower() without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def param_specs(defs: Any, rules: dict[str, Any]) -> Any:
    """Map logical axes -> PartitionSpec via the rule table.

    ``rules`` maps logical axis name -> mesh axis (str | tuple | None).
    Unlisted logical axes are replicated.
    """
    def one(d: ParamDef) -> PartitionSpec:
        return PartitionSpec(*(rules.get(a) if a else None for a in d.axes))
    return jax.tree.map(one, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    total = 0
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
