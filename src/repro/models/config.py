"""Architecture configuration schema + the assigned input-shape sets."""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_for"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (exact public configs)."""

    name: str
    family: str                   # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention flavour -------------------------------------------------
    attn_kind: str = "gqa"        # gqa | mla | none
    rope_fraction: float = 1.0    # partial rotary (chatglm 0.5, stablelm 0.25)
    window: int | None = None     # sliding-window attention (mixtral)
    qk_norm: bool = False         # chameleon
    head_dim: int | None = None   # override d_model // n_heads

    # --- MLP flavour --------------------------------------------------------
    mlp_kind: str = "swiglu"      # swiglu | gelu | geglu | none
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0   # deepseek: layer 0 is a dense MLP
    d_ff_dense: int = 0           # ff width of those dense layers

    # --- MLA (deepseek) -----------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- hybrid / recurrent -------------------------------------------------
    #: layer pattern, e.g. ("rglru", "rglru", "local") for recurrentgemma,
    #: ("mlstm", "slstm") for xlstm; empty = all "attn".
    pattern: tuple[str, ...] = ()
    lru_width: int = 0
    local_window: int = 0
    conv_width: int = 4

    # --- encoder-decoder (whisper) -------------------------------------------
    n_encoder_layers: int = 0
    encoder_len: int = 1500       # precomputed audio-frame embeddings (stub)

    # --- misc ----------------------------------------------------------------
    max_seq: int = 524_288
    tie_embeddings: bool = False
    subquadratic: bool = False    # eligible for long_500k
    notes: str = ""
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd = self.resolved_head_dim
        for i in range(self.n_layers):
            kind = (self.pattern[i % len(self.pattern)]
                    if self.pattern else "attn")
            if kind in ("attn", "local"):
                if self.attn_kind == "mla":
                    q = (d * self.q_lora_rank + self.q_lora_rank *
                         self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
                    kv = (d * (self.kv_lora_rank + self.qk_rope_dim)
                          + self.kv_lora_rank * self.n_heads *
                          (self.qk_nope_dim + self.v_head_dim))
                    o = self.n_heads * self.v_head_dim * d
                    total += q + kv + o
                else:
                    total += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                        + self.n_heads * hd * d
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + w * d + self.conv_width * w + 2 * w
            elif kind in ("mlstm", "slstm"):
                total += 4 * d * d + 2 * d * 2 * d
            # mlp
            if self.n_experts and i >= self.first_dense_layers:
                e_ff = self.d_ff_expert or self.d_ff
                n_e = self.n_experts + self.n_shared_experts
                total += n_e * 3 * d * e_ff + d * self.n_experts
            elif self.mlp_kind != "none":
                ff = (self.d_ff_dense if self.n_experts
                      and i < self.first_dense_layers else self.d_ff)
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                total += mult * d * ff
        # encoder (whisper)
        for _ in range(self.n_encoder_layers):
            total += 4 * d * d + 2 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        e_ff = self.d_ff_expert or self.d_ff
        moe_layers = self.n_layers - self.first_dense_layers
        all_exp = moe_layers * (self.n_experts + self.n_shared_experts) \
            * 3 * d * e_ff
        act_exp = moe_layers * (self.top_k + self.n_shared_experts) \
            * 3 * d * e_ff
        return full - all_exp + act_exp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSpec:
    return SHAPES[name]
