"""RecurrentGemma blocks: RG-LRU recurrence + temporal conv (Griffin,
arXiv:2402.19427).

The RG-LRU is a real-valued gated linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(Λ) * sigmoid(r_t))

It is linear in h, so training/prefill uses ``jax.lax.associative_scan``
(log-depth — the TPU translation of the paper's sequential CUDA scan),
and decode carries a single (B, W) state.  The recurrent block is
conv1d(4) -> RG-LRU in a gated (GeGLU-style) wrapper, as in Griffin.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import linear
from repro.models.params import ParamDef

__all__ = ["RGLRUSpec", "rglru_block_defs", "rglru_block_train",
           "rglru_block_decode", "RGLRUState", "init_rglru_state"]

_C = 8.0  # Griffin's fixed recurrence sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    width: int            # lru_width
    conv_width: int = 4


def rglru_block_defs(s: RGLRUSpec) -> dict:
    d, w = s.d_model, s.width
    return {
        "wx": ParamDef((d, w), ("embed", "ff")),        # recurrent branch
        "wy": ParamDef((d, w), ("embed", "ff")),        # gate branch
        "conv_w": ParamDef((s.conv_width, w), (None, "ff"), scale=0.5),
        "conv_b": ParamDef((w,), ("ff",), init="zeros"),
        "lam": ParamDef((w,), ("ff",), init="normal", scale=0.5),
        "w_input_gate": ParamDef((w, w), ("ff", None), scale=0.01),
        "b_input_gate": ParamDef((w,), (None,), init="zeros"),
        "w_rec_gate": ParamDef((w, w), ("ff", None), scale=0.01),
        "b_rec_gate": ParamDef((w,), (None,), init="zeros"),
        "wo": ParamDef((w, d), ("ff", "embed")),
    }


def _gates(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """log(a_t) and input gate i_t, both (..., W) in fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_rec_gate"].astype(jnp.float32)
                       + p["b_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_input_gate"].astype(jnp.float32)
                       + p["b_input_gate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    return log_a, i


def _conv1d(p: dict, x: jax.Array, state: jax.Array | None
            ) -> tuple[jax.Array, jax.Array]:
    """Causal depthwise conv along seq; x (B, S, W).

    Returns (out, new_state) where state holds the last (conv_width - 1)
    inputs for decode continuation.
    """
    cw = p["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    out = sum(xx[:, i:i + x.shape[1]] * p["conv_w"][i][None, None]
              for i in range(cw))
    return out + p["conv_b"], xx[:, -(cw - 1):]


def _rglru_scan(log_a: jax.Array, gx: jax.Array,
                h0: jax.Array | None) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (seq)."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gx
    if h0 is not None:
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_train(p: dict, x: jax.Array
                      ) -> tuple[jax.Array, "RGLRUState"]:
    """Full-sequence recurrent block: x (B, S, D) -> (out, final state)."""
    gate = jax.nn.gelu(linear(x, p["wy"]))
    u = linear(x, p["wx"])
    u, conv_state = _conv1d(p, u, None)
    log_a, i_gate = _gates(p, u)
    h = _rglru_scan(log_a, i_gate * u.astype(jnp.float32), None)
    out = linear((h.astype(x.dtype) * gate), p["wo"])
    return out, RGLRUState(h[:, -1], conv_state)


@dataclasses.dataclass
class RGLRUState:
    h: jax.Array          # (B, W) recurrence state, fp32
    conv: jax.Array       # (B, conv_width - 1, W)


jax.tree_util.register_dataclass(
    RGLRUState, data_fields=["h", "conv"], meta_fields=[])


def init_rglru_state(batch: int, s: RGLRUSpec,
                     dtype: jnp.dtype) -> RGLRUState:
    return RGLRUState(
        jnp.zeros((batch, s.width), jnp.float32),
        jnp.zeros((batch, s.conv_width - 1, s.width), dtype))


def rglru_block_decode(p: dict, x: jax.Array, state: RGLRUState
                       ) -> tuple[jax.Array, RGLRUState]:
    """One-token step: x (B, 1, D)."""
    gate = jax.nn.gelu(linear(x, p["wy"]))
    u = linear(x, p["wx"])
    u, conv_state = _conv1d(p, u, state.conv)
    log_a, i_gate = _gates(p, u[:, 0])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_gate * u[:, 0].astype(jnp.float32))
    h = a * state.h + b
    out = linear((h[:, None].astype(x.dtype) * gate), p["wo"])
    return out, RGLRUState(h, conv_state)
