"""Decoder-only LM assembly for all non-encoder-decoder families.

Layers are grouped into
  prefix   — unrolled leading layers (e.g. deepseek's dense layer 0),
  unit x R — the repeating pattern scanned with ``lax.scan`` (keeps the
             HLO small: one unit body regardless of depth),
  suffix   — unrolled remainder when n_layers is not a multiple of the
             pattern length (e.g. recurrentgemma's 26 = 3*8 + 2).

The same layer-apply code serves train, prefill (returns caches) and
decode (consumes caches), so there is exactly one implementation of each
block to test.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import recurrent as REC
from repro.models import xlstm as XL
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.params import ParamDef, init_params, param_specs

__all__ = ["LM", "build_lm", "chunked_cross_entropy"]


# ---------------------------------------------------------------------------
# Layer taxonomy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str      # attn | local | rglru | mlstm | slstm
    mlp: str       # mlp | moe | none
    d_ff: int = 0  # per-layer ff width (deepseek dense layer differs)


def _layer_plan(cfg: ArchConfig) -> list[LayerSpec]:
    plan = []
    pattern = cfg.pattern or ("attn",)
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if cfg.n_experts and i >= cfg.first_dense_layers:
            mlp = "moe"
            ff = cfg.d_ff_expert or cfg.d_ff
        elif cfg.mlp_kind == "none":
            mlp, ff = "none", 0
        else:
            mlp = "mlp"
            ff = (cfg.d_ff_dense
                  if cfg.n_experts and i < cfg.first_dense_layers
                  else cfg.d_ff)
        plan.append(LayerSpec(kind, mlp, ff))
    return plan


def _segments(plan: list[LayerSpec]
              ) -> tuple[list[LayerSpec], list[LayerSpec], int,
                         list[LayerSpec]]:
    """(prefix, unit, repeats, suffix) with unit = shortest cycle."""
    # prefix = leading layers that differ from the eventual cycle
    # find the cycle of the tail: try cycle lengths 1..4
    for start in range(0, min(4, len(plan))):
        tail = plan[start:]
        for clen in (1, 2, 3, 4):
            if clen > len(tail):
                break
            unit = tail[:clen]
            reps = len(tail) // clen
            if reps >= 1 and all(
                    tail[i] == unit[i % clen] for i in range(reps * clen)):
                suffix = tail[reps * clen:]
                return plan[:start], unit, reps, suffix
    return plan, [], 0, []          # fully unrolled fallback


# ---------------------------------------------------------------------------
# Per-layer defs / apply
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ArchConfig, kind: str) -> L.AttnSpec:
    return L.AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_fraction=cfg.rope_fraction,
        window=(cfg.local_window if kind == "local" else cfg.window),
        qk_norm=cfg.qk_norm)


def _mla_spec(cfg: ArchConfig) -> MLA.MLASpec:
    return MLA.MLASpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim)


def _moe_spec(cfg: ArchConfig) -> MOE.MoESpec:
    return MOE.MoESpec(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_ff=cfg.d_ff_expert or cfg.d_ff, n_shared=cfg.n_shared_experts)


def _rglru_spec(cfg: ArchConfig) -> REC.RGLRUSpec:
    return REC.RGLRUSpec(d_model=cfg.d_model,
                         width=cfg.lru_width or cfg.d_model,
                         conv_width=cfg.conv_width)


def _xlstm_spec(cfg: ArchConfig) -> XL.XLSTMSpec:
    return XL.XLSTMSpec(d_model=cfg.d_model, n_heads=cfg.n_heads)


def _layer_defs(cfg: ArchConfig, spec: LayerSpec) -> dict:
    d = cfg.d_model
    defs: dict = {"ln1": L.norm_defs(d, cfg.norm_kind)}
    if spec.kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            defs["mixer"] = MLA.mla_defs(_mla_spec(cfg))
        else:
            defs["mixer"] = L.attention_defs(_attn_spec(cfg, spec.kind))
    elif spec.kind == "rglru":
        defs["mixer"] = REC.rglru_block_defs(_rglru_spec(cfg))
    elif spec.kind == "mlstm":
        defs["mixer"] = XL.mlstm_defs(_xlstm_spec(cfg))
    elif spec.kind == "slstm":
        defs["mixer"] = XL.slstm_defs(_xlstm_spec(cfg))
    else:
        raise ValueError(spec.kind)
    if spec.mlp == "mlp":
        defs["ln2"] = L.norm_defs(d, cfg.norm_kind)
        defs["mlp"] = L.mlp_defs(d, spec.d_ff, cfg.mlp_kind)
    elif spec.mlp == "moe":
        defs["ln2"] = L.norm_defs(d, cfg.norm_kind)
        defs["moe"] = MOE.moe_defs(_moe_spec(cfg))
    return defs


# ---------------------------------------------------------------------------
# Runtime context: mode + mesh info
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ctx:
    mode: str                      # train | prefill | decode
    mesh: Any = None               # jax Mesh for the shard_map MoE path
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    cache_len: int = 0             # decode capacity
    remat: bool = True
    kv_quantized: bool = False     # int8 KV cache (§Perf, memory-bound
                                   # decode cells)
    tuner: Any = None              # AdsalaTuner threaded to every
                                   # routine-aware call site (None = the
                                   # sites still report dispatch events,
                                   # just untuned)


def _moe_apply(p: dict, x: jax.Array, cfg: ArchConfig, ctx: Ctx
               ) -> tuple[jax.Array, jax.Array]:
    """MoE dispatch-path selection.

    * no mesh / decode step  -> dense one-hot path (tiny workloads),
    * E divisible by tp size -> shard_map expert parallelism (deepseek),
    * otherwise              -> shard_map expert tensor parallelism
                                (mixtral: 8 experts on a 16-way axis).
    """
    spec = _moe_spec(cfg)
    if ctx.mesh is None or ctx.mode == "decode":
        return MOE.apply_moe(p, x, spec, tuner=ctx.tuner)
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    dp = ctx.dp_axes
    tp = ctx.tp_axis
    tp_size = ctx.mesh.shape[tp]
    ep_mode = (spec.n_experts % tp_size == 0
               and x.shape[1] % tp_size == 0)
    spec = dataclasses.replace(spec, ep_axis=tp)
    fn = MOE.apply_moe_ep if ep_mode else MOE.apply_moe_tp

    def wrapped(p_local, x_local):
        out, aux = fn(p_local, x_local, s=spec, tuner=ctx.tuner)
        return out, jax.lax.pmean(aux, (*dp, tp))

    if ep_mode:
        w_specs = {k: (P() if k.startswith(("router", "shared"))
                       else P(tp, None, None)) for k in p}
        x_spec = P(dp, tp, None)
    else:
        w_specs = {}
        for k in p:
            if k.startswith(("router", "shared")):
                w_specs[k] = P()
            elif k == "wo":
                w_specs[k] = P(None, tp, None)
            else:
                w_specs[k] = P(None, None, tp)
        x_spec = P(dp, None, None)
    return shard_map(
        wrapped, mesh=ctx.mesh,
        in_specs=(w_specs, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False)(p, x)


def _seed_cache(raw: Any, cfg: ArchConfig, spec: LayerSpec,
                ctx: Ctx) -> Any:
    """Convert a mixer's prefill by-product into decode cache format."""
    if spec.kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            c_kv, k_rope = raw
            return MLA.seed_mla_cache(c_kv, k_rope, ctx.cache_len)
        a = _attn_spec(cfg, spec.kind)
        windowed = a.window is not None
        cap = min(ctx.cache_len, a.window) if windowed else ctx.cache_len
        k, v = raw
        return L.seed_kv_cache(k, v, cap, windowed=windowed,
                               quantized=ctx.kv_quantized)
    return raw  # recurrent states are already decode-format


def _apply_layer_train(p: dict, x: jax.Array, cfg: ArchConfig,
                       spec: LayerSpec, ctx: Ctx
                       ) -> tuple[jax.Array, jax.Array, Any]:
    """Full-sequence layer application.

    Returns (x, aux_loss, cache) — cache is decode-format when
    ctx.mode == 'prefill', else None (so train carries no dead weight).
    """
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["ln1"], x, cfg.norm_kind)
    if spec.kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            mix, raw = MLA.mla_train(p["mixer"], h, _mla_spec(cfg),
                                     tuner=ctx.tuner)
        else:
            mix, raw = L.attention_train(p["mixer"], h,
                                         _attn_spec(cfg, spec.kind),
                                         tuner=ctx.tuner)
    elif spec.kind == "rglru":
        mix, raw = REC.rglru_block_train(p["mixer"], h)
    elif spec.kind == "mlstm":
        mix, raw = XL.mlstm_train(p["mixer"], h, _xlstm_spec(cfg))
    else:
        mix, raw = XL.slstm_train(p["mixer"], h, _xlstm_spec(cfg))
    cache = _seed_cache(raw, cfg, spec, ctx) if ctx.mode == "prefill" \
        else None
    x = x + mix
    if spec.mlp == "mlp":
        x = x + L.apply_mlp(p["mlp"],
                            L.apply_norm(p["ln2"], x, cfg.norm_kind),
                            cfg.mlp_kind, tuner=ctx.tuner)
    elif spec.mlp == "moe":
        out, aux = _moe_apply(p["moe"],
                              L.apply_norm(p["ln2"], x, cfg.norm_kind),
                              cfg, ctx)
        x = x + out
    return x, aux, cache


# --- caches ----------------------------------------------------------------

def _init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                      ctx: Ctx, dtype) -> Any:
    if spec.kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            return MLA.init_mla_cache(batch, ctx.cache_len, _mla_spec(cfg),
                                      dtype)
        a = _attn_spec(cfg, spec.kind)
        windowed = a.window is not None
        cap = min(ctx.cache_len, a.window) if windowed else ctx.cache_len
        return L.init_kv_cache(batch, cap, a.n_kv_heads, a.head_dim,
                               dtype, windowed=windowed,
                               quantized=ctx.kv_quantized)
    if spec.kind == "rglru":
        return REC.init_rglru_state(batch, _rglru_spec(cfg), dtype)
    if spec.kind == "mlstm":
        return XL.init_mlstm_state(batch, _xlstm_spec(cfg))
    return XL.init_slstm_state(batch, _xlstm_spec(cfg))


def _init_layer_paged(cfg: ArchConfig, spec: LayerSpec, n_pages: int,
                      page_size: int, ctx: Ctx, dtype) -> Any:
    """Paged twin of :func:`_init_layer_cache`: one page pool per
    attention layer.  Sliding-window (ring) and recurrent-state layers
    have no paged representation (the window bounds their memory
    already; recurrent states carry no sequence dim) — continuous
    batching supports the attention-cache families."""
    from repro.serve import kv_cache as KV

    if spec.kind not in ("attn", "local"):
        raise NotImplementedError(
            f"paged decode cache for layer kind {spec.kind!r} "
            "(recurrent states are not paged)")
    if ctx.kv_quantized:
        raise NotImplementedError("paged decode with int8 KV cache")
    if cfg.attn_kind == "mla":
        s = _mla_spec(cfg)
        return KV.init_paged_latent(n_pages, page_size, s.kv_lora_rank,
                                    s.qk_rope_dim, dtype)
    a = _attn_spec(cfg, spec.kind)
    if a.window is not None:
        raise NotImplementedError(
            "paged decode cache for sliding-window (ring) layers")
    return KV.init_paged_kv(n_pages, page_size, a.n_kv_heads,
                            a.head_dim, dtype)


def _apply_layer_decode(p: dict, x: jax.Array, cache: Any,
                        pos: jax.Array, cfg: ArchConfig, spec: LayerSpec,
                        ctx: Ctx, page_table: jax.Array | None = None
                        ) -> tuple[jax.Array, Any]:
    """``page_table`` switches the attention mixers onto the paged read
    path (cache leaves are PagedKV/PagedLatent pools, ``pos`` is (B,)
    per-sequence positions) — the continuous-batching decode."""
    h = L.apply_norm(p["ln1"], x, cfg.norm_kind)
    if spec.kind in ("attn", "local"):
        if page_table is not None:
            if cfg.attn_kind == "mla":
                mix, cache = MLA.mla_decode_paged(
                    p["mixer"], h, _mla_spec(cfg), cache, page_table,
                    pos, tuner=ctx.tuner)
            else:
                mix, cache = L.attention_decode_paged(
                    p["mixer"], h, _attn_spec(cfg, spec.kind), cache,
                    page_table, pos, tuner=ctx.tuner)
        elif cfg.attn_kind == "mla":
            mix, cache = MLA.mla_decode(p["mixer"], h, _mla_spec(cfg),
                                        cache, pos, tuner=ctx.tuner)
        else:
            mix, cache = L.attention_decode(
                p["mixer"], h, _attn_spec(cfg, spec.kind), cache, pos,
                tuner=ctx.tuner)
    elif spec.kind == "rglru":
        mix, cache = REC.rglru_block_decode(p["mixer"], h, cache)
    elif spec.kind == "mlstm":
        mix, cache = XL.mlstm_decode(p["mixer"], h, _xlstm_spec(cfg), cache)
    else:
        mix, cache = XL.slstm_decode(p["mixer"], h, _xlstm_spec(cfg), cache)
    x = x + mix
    if spec.mlp == "mlp":
        x = x + L.apply_mlp(p["mlp"],
                            L.apply_norm(p["ln2"], x, cfg.norm_kind),
                            cfg.mlp_kind, tuner=ctx.tuner)
    elif spec.mlp == "moe":
        out, _ = _moe_apply(p["moe"],
                            L.apply_norm(p["ln2"], x, cfg.norm_kind),
                            cfg, ctx)
        x = x + out
    return x, cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_cross_entropy(x: jax.Array, w_unemb: jax.Array,
                          labels: jax.Array, *, chunk: int = 512
                          ) -> jax.Array:
    """Mean CE over (B, S) without materialising (B, S, V) at once."""
    b, s, d = x.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = xp.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        xi, li = inp
        logits = jnp.einsum("bcd,dv->bcv", xi, w_unemb).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        ce = ((logz - gold) * valid).sum()
        return carry + jnp.stack([ce, valid.sum()]), None

    tot, _ = jax.lax.scan(step, jnp.zeros(2, jnp.float32), (xc, lc))
    return tot[0] / jnp.maximum(tot[1], 1.0)


# ---------------------------------------------------------------------------
# The model object
# ---------------------------------------------------------------------------

class LM:
    """Decoder-only LM with scan-over-pattern distribution-ready layout."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        plan = _layer_plan(cfg)
        self.prefix, self.unit, self.repeats, self.suffix = _segments(plan)
        self.defs = self._build_defs()

    # -- parameter definitions ---------------------------------------------
    def _build_defs(self) -> dict:
        cfg = self.cfg
        defs: dict = {
            "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              scale=1.0),
            "ln_f": L.norm_defs(cfg.d_model, cfg.norm_kind),
        }
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((cfg.d_model, cfg.vocab),
                                       ("embed", "vocab"))
        defs["prefix"] = [
            _layer_defs(cfg, s) for s in self.prefix]
        if self.repeats:
            unit_defs = [_layer_defs(cfg, s) for s in self.unit]
            defs["scan"] = jax.tree.map(
                lambda d: ParamDef((self.repeats,) + d.shape,
                                   ("layers",) + d.axes, init=d.init,
                                   scale=d.scale),
                unit_defs, is_leaf=lambda v: isinstance(v, ParamDef))
        defs["suffix"] = [
            _layer_defs(cfg, s) for s in self.suffix]
        return defs

    def init(self, rng: jax.Array, dtype=jnp.float32) -> dict:
        return init_params(self.defs, rng, dtype)

    def param_partition_specs(self, rules: dict) -> dict:
        return param_specs(self.defs, rules)

    # -- forward --------------------------------------------------------------
    def _embed(self, params: dict, tokens: jax.Array) -> jax.Array:
        return params["embed"][tokens]

    def _unembed_weight(self, params: dict) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    def forward(self, params: dict, tokens: jax.Array, ctx: Ctx
                ) -> tuple[jax.Array, jax.Array, dict | None]:
        """(B, S) tokens -> (hidden (B, S, D), total aux, caches|None)."""
        cfg = self.cfg
        want_cache = ctx.mode == "prefill"
        x = self._embed(params, tokens)
        aux_total = jnp.zeros((), jnp.float32)
        caches: dict = {"prefix": [], "scan": [], "suffix": []}

        for p, s in zip(params["prefix"], self.prefix):
            x, aux, c = _apply_layer_train(p, x, cfg, s, ctx)
            aux_total += aux
            caches["prefix"].append(c)

        if self.repeats:
            unit = self.unit

            def body(carry, layer_params):
                h, aux_in = carry
                aux_here = jnp.zeros((), jnp.float32)
                cs = []
                for i, s in enumerate(unit):
                    h, a, c = _apply_layer_train(layer_params[i], h, cfg,
                                                 s, ctx)
                    aux_here += a
                    cs.append(c)
                ys = cs if want_cache else None
                return (h, aux_in + aux_here), ys

            if ctx.remat:
                # ADSALA_REMAT_POLICY=dots saves matmul outputs so the
                # backward pass recomputes only elementwise ops (§Perf:
                # trades activation memory for ~fwd-worth of FLOPs).
                if os.environ.get("ADSALA_REMAT_POLICY") == "dots":
                    body = jax.checkpoint(
                        body, policy=jax.checkpoint_policies
                        .dots_with_no_batch_dims_saveable)
                else:
                    body = jax.checkpoint(body)
            # ADSALA_SCAN_UNROLL=full unrolls the layer loop so XLA cost
            # analysis counts every layer (dry-run accounting mode; the
            # default scan keeps HLO small for fast compiles).
            unroll = (self.repeats
                      if os.environ.get("ADSALA_SCAN_UNROLL") == "full"
                      else 1)
            (x, aux_total), scan_caches = jax.lax.scan(
                body, (x, aux_total), params["scan"], unroll=unroll)
            caches["scan"] = scan_caches if want_cache else []

        for p, s in zip(params["suffix"], self.suffix):
            x, aux, c = _apply_layer_train(p, x, cfg, s, ctx)
            aux_total += aux
            caches["suffix"].append(c)

        x = L.apply_norm(params["ln_f"], x, cfg.norm_kind)
        return x, aux_total, caches if want_cache else None

    # -- public entry points ---------------------------------------------------
    def loss(self, params: dict, batch: dict, ctx: Ctx | None = None
             ) -> jax.Array:
        ctx = ctx or Ctx(mode="train")
        x, aux, _ = self.forward(params, batch["tokens"], ctx)
        ce = chunked_cross_entropy(x, self._unembed_weight(params),
                                   batch["labels"])
        return ce + 0.01 * aux

    def logits_last(self, params: dict, x: jax.Array) -> jax.Array:
        return jnp.einsum("bd,dv->bv", x[:, -1],
                          self._unembed_weight(params))

    def init_cache(self, batch: int, ctx: Ctx, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        cache: dict = {
            "prefix": [_init_layer_cache(cfg, s, batch, ctx, dtype)
                       for s in self.prefix],
            "suffix": [_init_layer_cache(cfg, s, batch, ctx, dtype)
                       for s in self.suffix],
        }
        if self.repeats:
            unit_cache = [_init_layer_cache(cfg, s, batch, ctx, dtype)
                          for s in self.unit]
            cache["scan"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.repeats,) + a.shape).copy(), unit_cache)
        else:
            cache["scan"] = []
        return cache

    def init_paged_cache(self, n_pages: int, page_size: int, ctx: Ctx,
                         dtype=jnp.float32) -> dict:
        """Page-pool tree mirroring :meth:`init_cache` structure-for-
        structure — PagedKV / PagedLatent pools instead of per-batch
        contiguous caches.  All layers share one page table (they see
        the same token positions), so the scheduler allocates once and
        every layer's pool is indexed by the same physical page ids."""
        cfg = self.cfg
        cache: dict = {
            "prefix": [_init_layer_paged(cfg, s, n_pages, page_size,
                                         ctx, dtype)
                       for s in self.prefix],
            "suffix": [_init_layer_paged(cfg, s, n_pages, page_size,
                                         ctx, dtype)
                       for s in self.suffix],
        }
        if self.repeats:
            unit_cache = [_init_layer_paged(cfg, s, n_pages, page_size,
                                            ctx, dtype)
                          for s in self.unit]
            cache["scan"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.repeats,) + a.shape).copy(), unit_cache)
        else:
            cache["scan"] = []
        return cache

    def prefill(self, params: dict, tokens: jax.Array, ctx: Ctx
                ) -> tuple[jax.Array, dict]:
        """Run the full prompt; return (last-token logits, decode caches)."""
        x, _, caches = self.forward(params, tokens, ctx)
        return self.logits_last(params, x), caches

    def decode_step(self, params: dict, token: jax.Array, cache: dict,
                    pos: jax.Array, ctx: Ctx,
                    page_table: jax.Array | None = None
                    ) -> tuple[jax.Array, dict]:
        """token (B, 1) int32 -> (logits (B, V), new cache).

        With ``page_table`` (B, P) the cache tree holds page pools and
        ``pos`` is (B,) per-sequence positions (-1 = inactive slot) —
        the continuous-batching paged decode (repro.serve.scheduler).
        """
        cfg = self.cfg
        x = self._embed(params, token)

        new_prefix = []
        for p, s, c in zip(params["prefix"], self.prefix, cache["prefix"]):
            x, c2 = _apply_layer_decode(p, x, c, pos, cfg, s, ctx,
                                        page_table)
            new_prefix.append(c2)

        new_scan = cache["scan"]
        if self.repeats:
            unit = self.unit

            def body(h, pc):
                layer_params, layer_cache = pc
                new_caches = []
                for i, s in enumerate(unit):
                    h, c2 = _apply_layer_decode(
                        layer_params[i], h, layer_cache[i], pos, cfg, s,
                        ctx, page_table)
                    new_caches.append(c2)
                return h, new_caches

            x, new_scan = jax.lax.scan(
                body, x, (params["scan"], cache["scan"]))

        new_suffix = []
        for p, s, c in zip(params["suffix"], self.suffix, cache["suffix"]):
            x, c2 = _apply_layer_decode(p, x, c, pos, cfg, s, ctx,
                                        page_table)
            new_suffix.append(c2)

        x = L.apply_norm(params["ln_f"], x, cfg.norm_kind)
        logits = self.logits_last(params, x)
        return logits, {"prefix": new_prefix, "scan": new_scan,
                        "suffix": new_suffix}


def build_lm(cfg: ArchConfig) -> LM:
    return LM(cfg)
