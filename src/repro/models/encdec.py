"""Encoder-decoder LM (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, encoder_len, D).  The
transformer backbone is faithful: non-causal encoder self-attention,
causal decoder self-attention + cross-attention, learned positional
embeddings, LayerNorm + GELU MLPs.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.models.params import ParamDef, init_params, param_specs
from repro.models.transformer import Ctx, chunked_cross_entropy

__all__ = ["EncDecLM", "build_encdec"]


def _attn_spec(cfg: ArchConfig, causal: bool) -> L.AttnSpec:
    return L.AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.resolved_head_dim,
                      rope_fraction=0.0, causal=causal)


def _enc_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {"ln1": L.norm_defs(d, cfg.norm_kind),
            "attn": L.attention_defs(_attn_spec(cfg, causal=False)),
            "ln2": L.norm_defs(d, cfg.norm_kind),
            "mlp": L.mlp_defs(d, cfg.d_ff, cfg.mlp_kind)}


def _dec_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {"ln1": L.norm_defs(d, cfg.norm_kind),
            "self_attn": L.attention_defs(_attn_spec(cfg, causal=True)),
            "ln_x": L.norm_defs(d, cfg.norm_kind),
            "cross_attn": L.attention_defs(_attn_spec(cfg, causal=False)),
            "ln2": L.norm_defs(d, cfg.norm_kind),
            "mlp": L.mlp_defs(d, cfg.d_ff, cfg.mlp_kind)}


def _cross_attention(p: dict, x: jax.Array, enc_k: jax.Array,
                     enc_v: jax.Array, s: L.AttnSpec,
                     tuner=None) -> jax.Array:
    """Query from x, K/V precomputed from encoder output."""
    b, sq, _ = x.shape
    # cross-attention scores are rectangular (decoder x encoder): a
    # plain GEMM, never SYRK-eligible — tagged so the recorded mix
    # distinguishes it from causal self-attention
    ops.observe(sq, s.head_dim, enc_k.shape[1], tuner,
                site="attn.cross_qk", count=b * s.n_heads)
    q = L.linear(x, p["wq"]).reshape(b, sq, s.n_heads, s.head_dim)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        enc_k.astype(jnp.float32)) * (s.head_dim ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs,
                     enc_v.astype(jnp.float32)).astype(x.dtype)
    return L.linear(out.reshape(b, sq, s.n_heads * s.head_dim), p["wo"])


def _project_enc_kv(p: dict, enc: jax.Array, s: L.AttnSpec
                    ) -> tuple[jax.Array, jax.Array]:
    b, sk, _ = enc.shape
    k = L.linear(enc, p["wk"]).reshape(b, sk, s.n_kv_heads, s.head_dim)
    v = L.linear(enc, p["wv"]).reshape(b, sk, s.n_kv_heads, s.head_dim)
    return (L._repeat_kv(k, s.n_heads), L._repeat_kv(v, s.n_heads))


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        d = cfg.d_model
        self.defs = {
            "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=1.0),
            "pos_dec": ParamDef((32_768, d), (None, "embed"), scale=0.02),
            "pos_enc": ParamDef((cfg.encoder_len, d), (None, "embed"),
                                scale=0.02),
            "encoder": [_enc_layer_defs(cfg)
                        for _ in range(cfg.n_encoder_layers)],
            "ln_enc": L.norm_defs(d, cfg.norm_kind),
            "decoder": [_dec_layer_defs(cfg) for _ in range(cfg.n_layers)],
            "ln_f": L.norm_defs(d, cfg.norm_kind),
        }

    def init(self, rng: jax.Array, dtype=jnp.float32) -> dict:
        return init_params(self.defs, rng, dtype)

    def param_partition_specs(self, rules: dict) -> dict:
        return param_specs(self.defs, rules)

    # -- encoder -----------------------------------------------------------
    def encode(self, params: dict, audio_emb: jax.Array,
               tuner=None) -> jax.Array:
        cfg = self.cfg
        x = audio_emb + params["pos_enc"][None, : audio_emb.shape[1]]
        spec = _attn_spec(cfg, causal=False)
        for p in params["encoder"]:
            h, _ = L.attention_train(
                p["attn"], L.apply_norm(p["ln1"], x, cfg.norm_kind), spec,
                tuner=tuner)
            x = x + h
            x = x + L.apply_mlp(
                p["mlp"], L.apply_norm(p["ln2"], x, cfg.norm_kind),
                cfg.mlp_kind, tuner=tuner)
        return L.apply_norm(params["ln_enc"], x, cfg.norm_kind)

    # -- decoder full-sequence ----------------------------------------------
    def _decode_seq(self, params: dict, tokens: jax.Array, enc: jax.Array,
                    ctx: Ctx) -> tuple[jax.Array, list]:
        cfg = self.cfg
        want_cache = ctx.mode == "prefill"
        x = params["embed"][tokens] + params["pos_dec"][None,
                                                        : tokens.shape[1]]
        sa = _attn_spec(cfg, causal=True)
        ca = _attn_spec(cfg, causal=False)
        caches = []
        for p in params["decoder"]:
            h, kv = L.attention_train(
                p["self_attn"], L.apply_norm(p["ln1"], x, cfg.norm_kind),
                sa, tuner=ctx.tuner)
            x = x + h
            ek, ev = _project_enc_kv(p["cross_attn"], enc, ca)
            x = x + _cross_attention(
                p["cross_attn"], L.apply_norm(p["ln_x"], x, cfg.norm_kind),
                ek, ev, ca, tuner=ctx.tuner)
            x = x + L.apply_mlp(
                p["mlp"], L.apply_norm(p["ln2"], x, cfg.norm_kind),
                cfg.mlp_kind, tuner=ctx.tuner)
            if want_cache:
                caches.append({
                    "self": L.seed_kv_cache(kv[0], kv[1], ctx.cache_len,
                                            windowed=False),
                    "cross_k": ek, "cross_v": ev})
        return L.apply_norm(params["ln_f"], x, cfg.norm_kind), caches

    # -- public API -----------------------------------------------------------
    def loss(self, params: dict, batch: dict, ctx: Ctx | None = None
             ) -> jax.Array:
        ctx = ctx or Ctx(mode="train")
        enc = self.encode(params, batch["audio_emb"], tuner=ctx.tuner)
        x, _ = self._decode_seq(params, batch["tokens"], enc, ctx)
        return chunked_cross_entropy(x, params["embed"].T, batch["labels"])

    def prefill(self, params: dict, batch: dict, ctx: Ctx
                ) -> tuple[jax.Array, list]:
        enc = self.encode(params, batch["audio_emb"], tuner=ctx.tuner)
        x, caches = self._decode_seq(params, batch["tokens"], enc, ctx)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["embed"].T)
        return logits, caches

    def init_cache(self, batch: int, ctx: Ctx, dtype=jnp.float32) -> list:
        cfg = self.cfg
        sa = _attn_spec(cfg, causal=True)
        return [{
            "self": L.init_kv_cache(batch, ctx.cache_len, sa.n_kv_heads,
                                    sa.head_dim, dtype),
            "cross_k": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads,
                                  sa.head_dim), dtype),
            "cross_v": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads,
                                  sa.head_dim), dtype),
        } for _ in range(cfg.n_layers)]

    def decode_step(self, params: dict, token: jax.Array, cache: list,
                    pos: jax.Array, ctx: Ctx) -> tuple[jax.Array, list]:
        cfg = self.cfg
        x = params["embed"][token] + jax.lax.dynamic_slice_in_dim(
            params["pos_dec"], pos, 1, axis=0)[None]
        sa = _attn_spec(cfg, causal=True)
        ca = _attn_spec(cfg, causal=False)
        new_cache = []
        for p, c in zip(params["decoder"], cache):
            h, self_c = L.attention_decode(
                p["self_attn"], L.apply_norm(p["ln1"], x, cfg.norm_kind),
                sa, c["self"], pos, tuner=ctx.tuner)
            x = x + h
            x = x + _cross_attention(
                p["cross_attn"], L.apply_norm(p["ln_x"], x, cfg.norm_kind),
                c["cross_k"], c["cross_v"], ca, tuner=ctx.tuner)
            x = x + L.apply_mlp(
                p["mlp"], L.apply_norm(p["ln2"], x, cfg.norm_kind),
                cfg.mlp_kind, tuner=ctx.tuner)
            new_cache.append({"self": self_c, "cross_k": c["cross_k"],
                              "cross_v": c["cross_v"]})
        x = L.apply_norm(params["ln_f"], x, cfg.norm_kind)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["embed"].T)
        return logits, new_cache


def build_encdec(cfg: ArchConfig) -> EncDecLM:
    return EncDecLM(cfg)
