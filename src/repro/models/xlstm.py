"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM
(scalar memory with exponential gating).

TPU adaptation (DESIGN.md): the paper's CUDA kernels become
  * mLSTM — chunked linear-attention form: inter-chunk state (B, H, Dh,
    Dh) carried by ``lax.scan`` over sequence chunks, intra-chunk work
    fully parallel on the MXU.  O(S·Dh²) like the recurrent form but
    matmul-shaped.
  * sLSTM — plain ``lax.scan`` over time (the recurrence is
    non-associative because of the max-stabiliser state), vector ops
    only.

Both carry exact recurrent state for decode, which is what makes
xlstm-125m eligible for the long_500k shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import linear, rmsnorm
from repro.models.params import ParamDef

__all__ = [
    "XLSTMSpec", "mlstm_defs", "mlstm_train", "mlstm_decode",
    "slstm_defs", "slstm_train", "slstm_decode",
    "MLSTMState", "SLSTMState", "init_mlstm_state", "init_slstm_state",
]


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0      # mLSTM up-projection
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_defs(s: XLSTMSpec) -> dict:
    d, di = s.d_model, s.d_inner
    return {
        "w_up": ParamDef((d, 2 * di), ("embed", "ff")),
        "wq": ParamDef((di, di), ("ff", None)),
        "wk": ParamDef((di, di), ("ff", None)),
        "wv": ParamDef((di, di), ("ff", None)),
        "w_igate": ParamDef((di, s.n_heads), ("ff", None), scale=0.01),
        "b_igate": ParamDef((s.n_heads,), (None,), init="zeros"),
        "w_fgate": ParamDef((di, s.n_heads), ("ff", None), scale=0.01),
        "b_fgate": ParamDef((s.n_heads,), (None,), init="ones"),
        "norm": ParamDef((di,), (None,), init="ones"),
        "w_down": ParamDef((di, d), ("ff", "embed")),
    }


@dataclasses.dataclass
class MLSTMState:
    c: jax.Array    # (B, H, Dh, Dh) matrix memory, fp32
    n: jax.Array    # (B, H, Dh) normaliser
    m: jax.Array    # (B, H) max-stabiliser (log space)


jax.tree_util.register_dataclass(
    MLSTMState, data_fields=["c", "n", "m"], meta_fields=[])


def init_mlstm_state(batch: int, s: XLSTMSpec, dtype=jnp.float32
                     ) -> MLSTMState:
    h, dh = s.n_heads, s.head_dim
    return MLSTMState(jnp.zeros((batch, h, dh, dh), jnp.float32),
                      jnp.zeros((batch, h, dh), jnp.float32),
                      jnp.full((batch, h), -1e30, jnp.float32))


def _mlstm_qkv(p: dict, x: jax.Array, s: XLSTMSpec):
    """x (B, S, D) -> q/k/v (B, S, H, Dh), gates (B, S, H), gate z."""
    b, sl, _ = x.shape
    up = linear(x, p["w_up"])
    u, z = jnp.split(up, 2, axis=-1)
    q = linear(u, p["wq"]).reshape(b, sl, s.n_heads, s.head_dim)
    k = linear(u, p["wk"]).reshape(b, sl, s.n_heads, s.head_dim) \
        * (s.head_dim ** -0.5)
    v = linear(u, p["wv"]).reshape(b, sl, s.n_heads, s.head_dim)
    ig = (jnp.einsum("bsd,dh->bsh", u, p["w_igate"])
          + p["b_igate"]).astype(jnp.float32)
    fg = (jnp.einsum("bsd,dh->bsh", u, p["w_fgate"])
          + p["b_fgate"]).astype(jnp.float32)
    return q, k, v, ig, fg, z


def mlstm_train(p: dict, x: jax.Array, s: XLSTMSpec
                ) -> tuple[jax.Array, "MLSTMState"]:
    """Chunked parallel mLSTM over the full sequence.

    Returns (out, final state) — the state seeds decode.
    """
    b, sl, _ = x.shape
    q, k, v, ig, fg, z = _mlstm_qkv(p, x, s)
    ch = min(s.chunk, sl)
    nc = -(-sl // ch)
    pad = nc * ch - sl

    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    # (nc, B, ch, ...)
    qc, kc, vc = (pad_t(t).reshape(b, nc, ch, s.n_heads, s.head_dim)
                  .transpose(1, 0, 2, 3, 4) for t in (q, k, v))
    igc = pad_t(ig).reshape(b, nc, ch, s.n_heads).transpose(1, 0, 2, 3)
    fgc = pad_t(fg).reshape(b, nc, ch, s.n_heads).transpose(1, 0, 2, 3)

    init = init_mlstm_state(b, s)

    def step(state, inp):
        qi, ki, vi, igi, fgi = inp          # (B, ch, H, ...)
        logf = jax.nn.log_sigmoid(fgi)      # (B, ch, H)
        cum = jnp.cumsum(logf, axis=1)      # inclusive prefix of log-forgets
        total = cum[:, -1]                  # (B, H)
        # per-position stabiliser, matching the step recurrence
        #   m_t = max(m_{t-1} + logf_t, ig_t)  =>  m_t = u_t + cum_t with
        #   u_t = max(m_0, cummax_{t'<=t}(ig_{t'} - cum_{t'}))
        u = jnp.maximum(state.m[:, None],
                        jax.lax.cummax(igi - cum, axis=1))   # (B, ch, H)
        m_pos = u + cum
        m_last = m_pos[:, -1]
        # intra-chunk decay: D[t, t'] = exp(cum_t - cum_t' + ig_t' - m_t)
        logd = (cum[:, :, None] - cum[:, None, :]
                + igi[:, None, :])          # (B, t, t', H)
        t_ids = jnp.arange(ch)
        causal = t_ids[:, None] >= t_ids[None, :]
        logd = jnp.where(causal[None, :, :, None], logd, -jnp.inf)
        d = jnp.exp(logd - m_pos[:, :, None])
        sim = jnp.einsum("bthd,bshd->btsh", qi.astype(jnp.float32),
                         ki.astype(jnp.float32))
        w = sim * d
        intra = jnp.einsum("btsh,bshd->bthd", w, vi.astype(jnp.float32))
        norm_intra = jnp.sum(w, axis=2)                      # (B, t, H)
        # inter-chunk contribution: q_t against C_0, decayed to position t
        qdec = jnp.exp(cum + state.m[:, None] - m_pos)       # (B, ch, H)
        inter = jnp.einsum("bthd,bhde,bth->bthe",
                           qi.astype(jnp.float32), state.c, qdec)
        norm_inter = jnp.einsum("bthd,bhd,bth->bth",
                                qi.astype(jnp.float32), state.n, qdec)
        num = intra + inter
        den = jnp.abs(norm_intra + norm_inter)
        out = num / jnp.maximum(den, 1.0)[..., None]
        # state update to the chunk end (stabilised by m_last)
        decay = jnp.exp(state.m + total - m_last)            # (B, H)
        kdec = jnp.exp(igi + total[:, None] - cum - m_last[:, None])
        c_new = state.c * decay[..., None, None] + jnp.einsum(
            "bthd,bthe,bth->bhde", ki.astype(jnp.float32),
            vi.astype(jnp.float32), kdec)
        n_new = state.n * decay[..., None] + jnp.einsum(
            "bthd,bth->bhd", ki.astype(jnp.float32), kdec)
        return MLSTMState(c_new, n_new, m_last), out

    final, outs = jax.lax.scan(step, init, (qc, kc, vc, igc, fgc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * ch, s.d_inner)
    out = out[:, :sl].astype(x.dtype)
    out = rmsnorm(out, p["norm"]) * jax.nn.silu(z)
    return linear(out, p["w_down"]), final


def mlstm_decode(p: dict, x: jax.Array, s: XLSTMSpec, state: MLSTMState
                 ) -> tuple[jax.Array, MLSTMState]:
    """One-token mLSTM step; x (B, 1, D)."""
    b = x.shape[0]
    q, k, v, ig, fg, z = _mlstm_qkv(p, x, s)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]      # (B, H, Dh)
    ig, fg = ig[:, 0], fg[:, 0]              # (B, H)
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(state.m + logf, ig)
    decay = jnp.exp(state.m + logf - m_new)
    inject = jnp.exp(ig - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    c_new = state.c * decay[..., None, None] \
        + inject[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n_new = state.n * decay[..., None] + inject[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    out = (num / jnp.maximum(den, 1.0)[..., None]).reshape(b, 1, s.d_inner)
    out = rmsnorm(out.astype(x.dtype), p["norm"]) * jax.nn.silu(z)
    return linear(out, p["w_down"]), MLSTMState(c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(s: XLSTMSpec) -> dict:
    d = s.d_model
    return {
        # z, i, f, o projections (input + recurrent)
        "w_in": ParamDef((d, 4 * d), ("embed", "ff")),
        "w_rec": ParamDef((d, 4 * d), ("embed", "ff"), scale=0.01),
        "b": ParamDef((4 * d,), ("ff",), init="zeros"),
        "norm": ParamDef((d,), (None,), init="ones"),
        "w_up": ParamDef((d, 2 * d), ("embed", "ff")),
        "w_down": ParamDef((d, d), ("ff", "embed")),
    }


@dataclasses.dataclass
class SLSTMState:
    c: jax.Array    # (B, D) cell
    n: jax.Array    # (B, D) normaliser
    h: jax.Array    # (B, D) hidden
    m: jax.Array    # (B, D) stabiliser


jax.tree_util.register_dataclass(
    SLSTMState, data_fields=["c", "n", "h", "m"], meta_fields=[])


def init_slstm_state(batch: int, s: XLSTMSpec, dtype=jnp.float32
                     ) -> SLSTMState:
    d = s.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_cell(p: dict, xt: jax.Array, st: SLSTMState
                ) -> tuple[SLSTMState, jax.Array]:
    """One sLSTM step; xt (B, D) fp32."""
    d = xt.shape[-1]
    pre = (xt @ p["w_in"].astype(jnp.float32)
           + st.h @ p["w_rec"].astype(jnp.float32)
           + p["b"].astype(jnp.float32))
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + st.m, i)
    i_s = jnp.exp(i - m_new)
    f_s = jnp.exp(logf + st.m - m_new)
    c = f_s * st.c + i_s * jnp.tanh(z)
    n = f_s * st.n + i_s
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h, m_new), h


def slstm_train(p: dict, x: jax.Array, s: XLSTMSpec
                ) -> tuple[jax.Array, "SLSTMState"]:
    """Sequential scan over time (non-associative recurrence)."""
    b, sl, d = x.shape
    xf = x.astype(jnp.float32)

    def step(st, xt):
        st2, h = _slstm_cell(p, xt, st)
        return st2, h

    final, hs = jax.lax.scan(step, init_slstm_state(b, s),
                             xf.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = rmsnorm(h, p["norm"])
    up = linear(h, p["w_up"])
    a, g = jnp.split(up, 2, axis=-1)
    return linear(a * jax.nn.gelu(g), p["w_down"]), final


def slstm_decode(p: dict, x: jax.Array, s: XLSTMSpec, state: SLSTMState
                 ) -> tuple[jax.Array, SLSTMState]:
    st2, h = _slstm_cell(p, x[:, 0].astype(jnp.float32), state)
    h = rmsnorm(h[:, None].astype(x.dtype), p["norm"])
    up = linear(h, p["w_up"])
    a, g = jnp.split(up, 2, axis=-1)
    return linear(a * jax.nn.gelu(g), p["w_down"]), st2
