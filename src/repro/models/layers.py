"""Shared layer library: norms, MLPs, RoPE, GQA attention (train/prefill/
decode), chunked-softmax attention for long sequences.

All functions are pure; parameters arrive as dicts produced from the
ParamDef trees in each block builder.  Activations are (B, S, D); the
attention entry points switch between the Pallas flash kernel and the
chunked XLA path via repro.kernels.ops.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.params import ParamDef

__all__ = [
    "rmsnorm", "layernorm", "norm_defs", "apply_norm",
    "linear", "mlp_defs", "apply_mlp",
    "rope_angles", "apply_rope",
    "attention_defs", "attention_train", "attention_decode",
    "attention_decode_paged",
    "AttnSpec", "KVCache", "init_kv_cache", "seed_kv_cache",
]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_defs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), ("embed",), init="ones")}
    return {"scale": ParamDef((d,), ("embed",), init="ones"),
            "bias": ParamDef((d,), ("embed",), init="zeros")}


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """(..., in) @ (in, out) keeping leading dims; einsum so the SPMD
    partitioner can propagate shardings without reshapes."""
    return jnp.einsum("...d,df->...f", x, w)


def mlp_defs(d: int, ff: int, kind: str) -> dict:
    if kind in ("swiglu", "geglu"):
        return {"wi": ParamDef((d, ff), ("embed", "ff")),
                "wg": ParamDef((d, ff), ("embed", "ff")),
                "wo": ParamDef((ff, d), ("ff", "embed"))}
    return {"wi": ParamDef((d, ff), ("embed", "ff")),
            "wo": ParamDef((ff, d), ("ff", "embed"))}


def apply_mlp(p: dict, x: jax.Array, kind: str, tuner=None) -> jax.Array:
    m = 1
    for dim in x.shape[:-1]:
        m *= dim
    d, ff = p["wi"].shape[-2], p["wi"].shape[-1]
    n_in = 2 * ff if kind in ("swiglu", "geglu") else ff
    ops.observe(m, d, n_in, tuner, site="mlp.in_proj")
    ops.observe(m, ff, d, tuner, site="mlp.out_proj")
    if kind == "swiglu":
        return linear(jax.nn.silu(linear(x, p["wg"])) * linear(x, p["wi"]),
                      p["wo"])
    if kind == "geglu":
        return linear(jax.nn.gelu(linear(x, p["wg"])) * linear(x, p["wi"]),
                      p["wo"])
    return linear(jax.nn.gelu(linear(x, p["wi"])), p["wo"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int,
                base: float = 10_000.0) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape (..., dim/2) for integer positions."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, dim, 2,
                                          dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array,
               fraction: float = 1.0) -> jax.Array:
    """Rotate the first ``fraction`` of the head dim; x: (B, S, H, Dh),
    sin/cos: (S, rot/2) — or (B, S, rot/2) when every sequence in the
    batch sits at its own position (the continuous-batching paged
    decode path, where positions are (B, S))."""
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    if sin.ndim == 3:     # per-sequence positions: (B, S, rot/2)
        sin_ = sin[:, :, None, : rot // 2].astype(jnp.float32)
        cos_ = cos[:, :, None, : rot // 2].astype(jnp.float32)
    else:
        sin_ = sin[None, :, None, : rot // 2].astype(jnp.float32)
        cos_ = cos[None, :, None, : rot // 2].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos_ - x2f * sin_, x2f * cos_ + x1f * sin_], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_fraction: float = 1.0
    window: int | None = None
    qk_norm: bool = False
    causal: bool = True


def attention_defs(s: AttnSpec) -> dict:
    d, h, hk, hd = s.d_model, s.n_heads, s.n_kv_heads, s.head_dim
    defs = {"wq": ParamDef((d, h * hd), ("embed", "heads")),
            "wk": ParamDef((d, hk * hd), ("embed", "kv_heads")),
            "wv": ParamDef((d, hk * hd), ("embed", "kv_heads")),
            "wo": ParamDef((h * hd, d), ("heads", "embed"))}
    if s.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones")
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return defs


def _project_qkv(p: dict, x: jax.Array, s: AttnSpec, positions: jax.Array,
                 tuner=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, sq, d = x.shape
    # the q/k/v projections are plain GEMMs; tag them so the recorded
    # routine mix carries the dense dispatch volume, not just the
    # SYRK/TRSM-eligible sites
    ops.observe(b * sq, d,
                (s.n_heads + 2 * s.n_kv_heads) * s.head_dim, tuner,
                site="attn.qkv_proj")
    q = linear(x, p["wq"]).reshape(b, sq, s.n_heads, s.head_dim)
    k = linear(x, p["wk"]).reshape(b, sq, s.n_kv_heads, s.head_dim)
    v = linear(x, p["wv"]).reshape(b, sq, s.n_kv_heads, s.head_dim)
    if s.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if s.rope_fraction > 0:
        sin, cos = rope_angles(positions, int(s.head_dim * s.rope_fraction))
        q = apply_rope(q, sin, cos, 1.0 if s.rope_fraction == 1.0
                       else s.rope_fraction)
        k = apply_rope(k, sin, cos, 1.0 if s.rope_fraction == 1.0
                       else s.rope_fraction)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, H, D) by repeating each KV head."""
    b, sq, hk, hd = k.shape
    if hk == n_heads:
        return k
    rep = n_heads // hk
    return jnp.repeat(k, rep, axis=2)


def chunked_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool, window: int | None,
                          q_offset: int = 0,
                          chunk: int = 512) -> jax.Array:
    """Online-softmax attention, scanned over query chunks (XLA path).

    Never materialises the full (Sq, Skv) score matrix: per scan step the
    live score block is (B, H, chunk, Skv).  q/k/v: (B, H, S, D).
    """
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    scale = dh ** -0.5
    nc = -(-sq // chunk)
    pad = nc * chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qc = qp.reshape(b, h, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    kv_ids = jnp.arange(skv)

    def step(_, qi_ci):
        qi, ci = qi_ci
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        q_ids = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, skv), dtype=bool)
        if causal:
            mask &= kv_ids[None, :] <= q_ids[:, None]
        if window is not None:
            mask &= kv_ids[None, :] > q_ids[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(step, None, (qc, jnp.arange(nc)))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, nc * chunk, dv)
    return out[:, :, :sq]


def attention_train(p: dict, x: jax.Array, s: AttnSpec, tuner=None
                    ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence self-attention (training / prefill internals).

    Returns (out, (k, v)) — the pre-repeat (B, S, Hkv, Dh) projections so
    prefill can seed the decode cache without recomputation.

    The attention core is one :func:`ops.flash_attention` dispatch on
    the flattened (B*H, Sq, Dh) heads: causal (and sliding-window)
    layers dispatch as routine="attn" and the tuner resolves the flash
    blocks, the dense vs block-sparse triangular KV grid, and — on the
    XLA backend — whether the SYRK score-materialisation path wins for
    this shape (recorded as routine="syrk" through ops.syrk, like the
    retired fixed-threshold lowering).  Non-causal unwindowed layers
    stay gemm-tagged.
    """
    b, sq, _ = x.shape
    positions = jnp.arange(sq)
    q, k, v = _project_qkv(p, x, s, positions, tuner)
    kr = _repeat_kv(k, s.n_heads)
    vr = _repeat_kv(v, s.n_heads)
    qt = q.transpose(0, 2, 1, 3)           # (B, H, S, Dh)
    kt = kr.transpose(0, 2, 1, 3)
    vt = vr.transpose(0, 2, 1, 3)
    flat = (b * s.n_heads, sq, s.head_dim)
    out = ops.flash_attention(qt.reshape(flat), kt.reshape(flat),
                              vt.reshape(flat), causal=s.causal,
                              window=s.window, tuner=tuner,
                              site="attn.core")
    out = out.reshape(b, s.n_heads, sq, s.head_dim).transpose(0, 2, 1, 3)
    ops.observe(b * sq, s.n_heads * s.head_dim, x.shape[-1], tuner,
                site="attn.out_proj")
    out = linear(out.reshape(b, sq, s.n_heads * s.head_dim), p["wo"])
    return out, (k, v)


def seed_kv_cache(k: jax.Array, v: jax.Array, capacity: int, *,
                  windowed: bool, quantized: bool = False) -> KVCache:
    """Build the decode cache from prefill projections k/v (B, S, Hkv, D).

    Full cache: first S slots filled.  Ring cache: the last ``capacity``
    positions land at slot = pos % capacity (a cyclic roll).
    """
    b, sq, hk, hd = k.shape
    if not windowed:
        pad = capacity - sq
        if pad < 0:
            raise ValueError(f"prompt {sq} exceeds cache {capacity}")
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    elif sq >= capacity:
        shift = sq % capacity
        kc = jnp.roll(k[:, -capacity:], shift, axis=1)
        vc = jnp.roll(v[:, -capacity:], shift, axis=1)
    else:
        pad = capacity - sq
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if quantized:
        kq, ks = _quantize_kv(kc)
        vq, vs = _quantize_kv(vc)
        return KVCache(kq, vq, windowed, ks, vs)
    return KVCache(kc, vc, windowed)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Either a full cache (capacity = max seq) or a ring buffer
    (capacity = window) for sliding-window layers.

    Optionally int8-quantised (beyond-paper §Perf optimisation for
    memory-bound MHA decode): k/v stored int8 with a per-(batch, slot,
    head) fp16 scale — 2.06x fewer cache bytes than bf16."""
    k: jax.Array            # (B, cap, Hkv, Dh) — bf16/f32 or int8
    v: jax.Array
    windowed: bool
    k_scale: jax.Array | None = None   # (B, cap, Hkv) when quantised
    v_scale: jax.Array | None = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_kv_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
                  dtype: jnp.dtype, *, windowed: bool = False,
                  quantized: bool = False) -> KVCache:
    shape = (batch, capacity, n_kv_heads, head_dim)
    if quantized:
        sshape = (batch, capacity, n_kv_heads)
        return KVCache(jnp.zeros(shape, jnp.int8),
                       jnp.zeros(shape, jnp.int8), windowed,
                       jnp.ones(sshape, jnp.float16),
                       jnp.ones(sshape, jnp.float16))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   windowed)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "k_scale", "v_scale"],
    meta_fields=["windowed"])


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, S, H, D) -> int8 values + per-(B, S, H) fp16 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q: jax.Array, scale: jax.Array,
                   dtype: jnp.dtype) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def attention_decode(p: dict, x: jax.Array, s: AttnSpec, cache: KVCache,
                     pos: jax.Array, tuner=None
                     ) -> tuple[jax.Array, KVCache]:
    """One-token decode: x (B, 1, D); pos scalar int32 (tokens so far).

    The cache update is TRSM-adjacent: each step appends one row and
    reads the triangular valid prefix, a sequential dependency along
    the cache axis exactly like TRSM's M-panel substitution — so the
    (cap, Dh, B*H) contraction is tagged routine="trsm" (degrading to
    gemm on artifacts without trsm signal) rather than priced as a
    parallel GEMM.
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, s, pos[None], tuner)
    cap = cache.k.shape[1]
    ops.observe(cap, s.head_dim, b * s.n_heads, tuner,
                routine="trsm", site="attn.cache_update")
    slot = pos % cap if cache.windowed else jnp.minimum(pos, cap - 1)
    if cache.quantized:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        kc = jax.lax.dynamic_update_slice(cache.k, kq, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, vq, (0, slot, 0, 0))
        ksc = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, slot, 0))
        vsc = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, slot, 0))
        new_cache = KVCache(kc, vc, cache.windowed, ksc, vsc)
        k = _dequantize_kv(kc, ksc, x.dtype)
        v = _dequantize_kv(vc, vsc, x.dtype)
    else:
        k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
        new_cache = KVCache(k, v, cache.windowed)

    kk = _repeat_kv(k, s.n_heads)
    vv = _repeat_kv(v, s.n_heads)
    scores = jnp.einsum("bohd,bkhd->bhk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (s.head_dim ** -0.5)
    kv_ids = jnp.arange(cap)
    if cache.windowed:
        # ring buffer: valid slots are the last min(pos+1, cap) writes
        age = (slot - kv_ids) % cap
        valid = age < jnp.minimum(pos + 1, cap)
    else:
        valid = kv_ids <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vv.astype(jnp.float32))
    out = out.reshape(b, 1, s.n_heads * s.head_dim).astype(x.dtype)
    ops.observe(b, s.n_heads * s.head_dim, x.shape[-1], tuner,
                site="attn.out_proj")
    return linear(out, p["wo"]), new_cache


def attention_decode_paged(p: dict, x: jax.Array, s: AttnSpec, pool,
                           page_table: jax.Array, pos: jax.Array,
                           tuner=None):
    """One-token decode against a paged KV pool (continuous batching).

    x (B, 1, D); ``pos`` is (B,) int32 — every sequence in the batch
    sits at its own position (ragged admission), with -1 marking an
    inactive batch slot; ``page_table`` (B, P) int32 maps each
    sequence's logical pages to physical pages of ``pool``
    (:class:`repro.serve.kv_cache.PagedKV`), -1 marking holes.

    The compute is element-for-element the fixed-batch
    :func:`attention_decode`: the page gather materialises the same
    (B, cap, Hkv, Dh) view the contiguous cache holds (holes land
    beyond the ``kv_ids <= pos`` valid prefix where the mask erases
    them), so per-sequence outputs are bitwise identical to the
    fixed-batch path — the scheduler's golden-parity contract.  The
    cache update keeps its TRSM-site recorder tag: still a sequential
    append + triangular-prefix read, just scattered through the page
    table.
    """
    from repro.serve.kv_cache import append_token, gather_pages

    if s.window is not None:
        raise NotImplementedError(
            "paged decode does not support sliding-window (ring) caches")
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, s, pos[:, None], tuner)
    cap = page_table.shape[1] * pool.page_size
    ops.observe(cap, s.head_dim, b * s.n_heads, tuner,
                routine="trsm", site="attn.cache_update")
    active = pos >= 0
    pool = type(pool)(
        append_token(pool.k, page_table, pos, k_new[:, 0], active),
        append_token(pool.v, page_table, pos, v_new[:, 0], active))
    k = gather_pages(pool.k, page_table)     # (B, cap, Hkv, Dh)
    v = gather_pages(pool.v, page_table)
    kk = _repeat_kv(k, s.n_heads)
    vv = _repeat_kv(v, s.n_heads)
    scores = jnp.einsum("bohd,bkhd->bhk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (s.head_dim ** -0.5)
    valid = jnp.arange(cap)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vv.astype(jnp.float32))
    out = out.reshape(b, 1, s.n_heads * s.head_dim).astype(x.dtype)
    ops.observe(b, s.n_heads * s.head_dim, x.shape[-1], tuner,
                site="attn.out_proj")
    return linear(out, p["wo"]), pool
