"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and keys/values are projected through low-rank latents; the
decode cache stores only the compressed latent c_kv (kv_lora_rank) plus
the decoupled RoPE key (qk_rope_dim) per token — the memory saving that
defines MLA.  Shapes follow the paper: per head the query/key split into
a non-positional part (qk_nope_dim) and a shared rotary part
(qk_rope_dim); values have their own head dim.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import (
    chunked_attention_xla,
    linear,
    rmsnorm,
    rope_angles,
)
from repro.models.params import ParamDef

__all__ = ["MLASpec", "mla_defs", "mla_train", "mla_decode",
           "mla_decode_paged", "MLACache", "init_mla_cache",
           "seed_mla_cache"]


@dataclasses.dataclass(frozen=True)
class MLASpec:
    d_model: int
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_defs(s: MLASpec) -> dict:
    h = s.n_heads
    return {
        # query path: d -> q_lora -> heads * (nope + rope)
        "wq_a": ParamDef((s.d_model, s.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamDef((s.q_lora_rank,), (None,), init="ones"),
        "wq_b": ParamDef((s.q_lora_rank, h * s.qk_head_dim),
                         ("lora", "heads")),
        # kv path: d -> kv_lora (+ shared rope key direct from d)
        "wkv_a": ParamDef((s.d_model, s.kv_lora_rank), ("embed", "lora")),
        "kv_norm": ParamDef((s.kv_lora_rank,), (None,), init="ones"),
        "wk_rope": ParamDef((s.d_model, s.qk_rope_dim), ("embed", None)),
        "wk_b": ParamDef((s.kv_lora_rank, h * s.qk_nope_dim),
                         ("lora", "heads")),
        "wv_b": ParamDef((s.kv_lora_rank, h * s.v_head_dim),
                         ("lora", "heads")),
        "wo": ParamDef((h * s.v_head_dim, s.d_model), ("heads", "embed")),
    }


def _rope_1head(x: jax.Array, positions: jax.Array) -> jax.Array:
    """Rotate a (B, S, R) shared rope key / (B, S, H, R) query rope part.

    ``positions`` is (S,) — or (B, S) when every sequence in the batch
    sits at its own position (the paged continuous-batching decode)."""
    r = x.shape[-1]
    sin, cos = rope_angles(positions, r)
    x1, x2 = x[..., : r // 2], x[..., r // 2:]
    if positions.ndim == 2:   # per-sequence: sin/cos already (B, S, r/2)
        if x.ndim == 4:
            sin = sin[:, :, None, :]
            cos = cos[:, :, None, :]
    elif x.ndim == 4:
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:
        sin = sin[None, :, :]
        cos = cos[None, :, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin],
                          axis=-1)
    return out.astype(x.dtype)


def _latents(p: dict, x: jax.Array, s: MLASpec, positions: jax.Array,
             tuner=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q (B,S,H,qk_head_dim), c_kv (B,S,R_kv), k_rope (B,S,R_rope)."""
    b, sq, _ = x.shape
    # latent down-projections (d -> lora rank) are the skinny GEMMs MLA
    # trades cache memory for; tag them so the tuner prices that shape
    ops.observe(b * sq, s.d_model,
                s.q_lora_rank + s.kv_lora_rank + s.qk_rope_dim, tuner,
                site="mla.down_proj")
    ops.observe(b * sq, s.q_lora_rank, s.n_heads * s.qk_head_dim,
                tuner, site="mla.up_proj_q")
    q_lat = rmsnorm(linear(x, p["wq_a"]), p["q_norm"])
    q = linear(q_lat, p["wq_b"]).reshape(b, sq, s.n_heads, s.qk_head_dim)
    q_nope, q_rope = q[..., : s.qk_nope_dim], q[..., s.qk_nope_dim:]
    q_rope = _rope_1head(q_rope, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    c_kv = rmsnorm(linear(x, p["wkv_a"]), p["kv_norm"])
    k_rope = _rope_1head(linear(x, p["wk_rope"]), positions)
    return q, c_kv, k_rope


def _expand_kv(p: dict, c_kv: jax.Array, k_rope: jax.Array, s: MLASpec,
               tuner=None) -> tuple[jax.Array, jax.Array]:
    """Decompress latents to per-head K (nope+rope) and V."""
    b, sk, _ = c_kv.shape
    # latent up-projection (kv lora rank -> per-head K/V)
    ops.observe(b * sk, s.kv_lora_rank,
                s.n_heads * (s.qk_nope_dim + s.v_head_dim), tuner,
                site="mla.up_proj_kv")
    k_nope = linear(c_kv, p["wk_b"]).reshape(b, sk, s.n_heads, s.qk_nope_dim)
    v = linear(c_kv, p["wv_b"]).reshape(b, sk, s.n_heads, s.v_head_dim)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (b, sk, s.n_heads, s.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def mla_train(p: dict, x: jax.Array, s: MLASpec, tuner=None
              ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (out, (c_kv, k_rope)) — the latents seed the decode cache."""
    b, sq, _ = x.shape
    positions = jnp.arange(sq)
    q, c_kv, k_rope = _latents(p, x, s, positions, tuner)
    k, v = _expand_kv(p, c_kv, k_rope, s, tuner)
    # causal scores: SYRK-shaped like GQA attention (triangular output)
    ops.observe(sq, s.qk_head_dim, sq, tuner, routine="syrk",
                site="mla.qk", count=b * s.n_heads)
    out = chunked_attention_xla(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=None,
        chunk=min(512, sq))
    out = out.transpose(0, 2, 1, 3).reshape(b, sq,
                                            s.n_heads * s.v_head_dim)
    return linear(out, p["wo"]), (c_kv, k_rope)


def seed_mla_cache(c_kv: jax.Array, k_rope: jax.Array,
                   capacity: int) -> MLACache:
    b, sq, _ = c_kv.shape
    pad = capacity - sq
    if pad < 0:
        raise ValueError(f"prompt {sq} exceeds cache {capacity}")
    return MLACache(jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                    jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))))


@dataclasses.dataclass
class MLACache:
    """Compressed latent cache: (B, cap, kv_lora_rank) + (B, cap, rope)."""
    c_kv: jax.Array
    k_rope: jax.Array


jax.tree_util.register_dataclass(
    MLACache, data_fields=["c_kv", "k_rope"], meta_fields=[])


def init_mla_cache(batch: int, capacity: int, s: MLASpec,
                   dtype: jnp.dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, capacity, s.kv_lora_rank), dtype),
        jnp.zeros((batch, capacity, s.qk_rope_dim), dtype))


def mla_decode(p: dict, x: jax.Array, s: MLASpec, cache: MLACache,
               pos: jax.Array, tuner=None) -> tuple[jax.Array, MLACache]:
    """One-token decode against the latent cache.

    Absorbed-projection trick: scores are computed in latent space
    (q_nope absorbed through wk_b), so the cache is never decompressed
    to per-head K/V — the FLOP/memory saving MLA decode is built for.
    """
    b = x.shape[0]
    q, c_kv_new, k_rope_new = _latents(p, x, s, pos[None], tuner)
    # latent cache update: sequential append + triangular-prefix read,
    # TRSM-adjacent exactly like the GQA KV cache update
    ops.observe(cache.c_kv.shape[1], s.kv_lora_rank, b * s.n_heads,
                tuner, routine="trsm", site="mla.cache_update")
    cache = MLACache(
        jax.lax.dynamic_update_slice(cache.c_kv, c_kv_new, (0, pos, 0)),
        jax.lax.dynamic_update_slice(cache.k_rope, k_rope_new, (0, pos, 0)))
    cap = cache.c_kv.shape[1]
    q_nope = q[..., : s.qk_nope_dim]       # (B, 1, H, nope)
    q_rope = q[..., s.qk_nope_dim:]        # (B, 1, H, rope)
    # absorb: q_lat[h] = q_nope[h] @ wk_b[h]^T  -> (B, H, R_kv)
    wk_b = p["wk_b"].reshape(s.kv_lora_rank, s.n_heads, s.qk_nope_dim)
    q_lat = jnp.einsum("bohd,rhd->bhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    s_lat = jnp.einsum("bhr,bkr->bhk", q_lat,
                       cache.c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bohd,bkd->bhk", q_rope.astype(jnp.float32),
                        cache.k_rope.astype(jnp.float32))
    scores = (s_lat + s_rope) * (s.qk_head_dim ** -0.5)
    valid = jnp.arange(cap) <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # output in latent space, then decompress through wv_b per head
    o_lat = jnp.einsum("bhk,bkr->bhr", probs,
                       cache.c_kv.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(s.kv_lora_rank, s.n_heads, s.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wv_b.astype(jnp.float32))
    out = out.reshape(b, 1, s.n_heads * s.v_head_dim).astype(x.dtype)
    return linear(out, p["wo"]), cache


def mla_decode_paged(p: dict, x: jax.Array, s: MLASpec, pool,
                     page_table: jax.Array, pos: jax.Array, tuner=None):
    """One-token decode against a paged latent pool (continuous batching).

    The paged twin of :func:`mla_decode`: ``pos`` is (B,) int32 per-
    sequence positions (-1 = inactive slot), ``page_table`` (B, P)
    maps logical pages to physical pages of the
    :class:`repro.serve.kv_cache.PagedLatent` pool.  Same absorbed-
    projection math on the page-gathered latent view, bitwise equal
    per sequence to the contiguous path; the latent cache update keeps
    its TRSM-site recorder tag.
    """
    from repro.serve.kv_cache import append_token, gather_pages

    b = x.shape[0]
    q, c_kv_new, k_rope_new = _latents(p, x, s, pos[:, None], tuner)
    cap = page_table.shape[1] * pool.page_size
    ops.observe(cap, s.kv_lora_rank, b * s.n_heads,
                tuner, routine="trsm", site="mla.cache_update")
    active = pos >= 0
    pool = type(pool)(
        append_token(pool.c_kv, page_table, pos, c_kv_new[:, 0], active),
        append_token(pool.k_rope, page_table, pos, k_rope_new[:, 0],
                     active))
    c_kv = gather_pages(pool.c_kv, page_table)       # (B, cap, R_kv)
    k_rope = gather_pages(pool.k_rope, page_table)   # (B, cap, R_rope)
    q_nope = q[..., : s.qk_nope_dim]       # (B, 1, H, nope)
    q_rope = q[..., s.qk_nope_dim:]        # (B, 1, H, rope)
    wk_b = p["wk_b"].reshape(s.kv_lora_rank, s.n_heads, s.qk_nope_dim)
    q_lat = jnp.einsum("bohd,rhd->bhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    s_lat = jnp.einsum("bhr,bkr->bhk", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bohd,bkd->bhk", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scores = (s_lat + s_rope) * (s.qk_head_dim ** -0.5)
    valid = jnp.arange(cap)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", probs, c_kv.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(s.kv_lora_rank, s.n_heads, s.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wv_b.astype(jnp.float32))
    out = out.reshape(b, 1, s.n_heads * s.v_head_dim).astype(x.dtype)
    return linear(out, p["wo"]), pool
