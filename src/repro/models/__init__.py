"""Model zoo: shared layer library + 10 assigned architectures."""

from repro.models.config import ArchConfig, SHAPES, ShapeSpec, shape_for
from repro.models.transformer import LM, Ctx, build_lm
from repro.models.encdec import EncDecLM, build_encdec

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec", "shape_for",
           "LM", "Ctx", "build_lm", "EncDecLM", "build_encdec"]
