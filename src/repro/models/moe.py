"""Mixture-of-Experts layer: top-k routing, two dispatch paths.

1. ``apply_moe``    — one-hot einsum dispatch (GShard style).  Dense and
   simple; O(T·E·C·D) dispatch FLOPs make it suitable only for the small
   smoke/test configs.
2. ``apply_moe_ep`` — production expert-parallel path, designed to run
   INSIDE ``shard_map``: per-device sort-based dispatch (gather/scatter,
   zero FLOPs), ``all_to_all`` over the expert axis, grouped GEMM on the
   local experts, ``all_to_all`` back, local combine.  This is the
   TPU-idiomatic translation of GPU MoE kernels (DESIGN.md).

The per-expert GEMMs are the paper's "small & irregular" regime — the
ADSALA tuner's strongest use case: expert bucket rows (~100s) times
d_model, exactly the GEMM sizes where "use every chip" loses badly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import linear
from repro.models.params import ParamDef

__all__ = ["MoESpec", "moe_defs", "apply_moe", "apply_moe_ep",
           "apply_moe_tp"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    #: mesh axis name carrying expert parallelism in the EP path
    ep_axis: str = "model"

    def capacity(self, n_tokens: int) -> int:
        cap = int(self.capacity_factor * n_tokens * self.top_k
                  / self.n_experts)
        return max(8, -(-cap // 8) * 8)


def moe_defs(s: MoESpec) -> dict:
    e, d, f = s.n_experts, s.d_model, s.d_ff
    # "experts" / "expert_ff" are resolved by the sharding rules: expert-
    # parallel meshes shard the leading dim, expert-TP meshes (n_experts
    # not divisible by the axis) shard the FF dim instead.
    defs = {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "wi": ParamDef((e, d, f), ("experts", "embed", "expert_ff")),
        "wg": ParamDef((e, d, f), ("experts", "embed", "expert_ff")),
        "wo": ParamDef((e, f, d), ("experts", "expert_ff", "embed")),
    }
    if s.n_shared:
        defs["shared_wi"] = ParamDef((d, s.n_shared * f), ("embed", "ff"))
        defs["shared_wg"] = ParamDef((d, s.n_shared * f), ("embed", "ff"))
        defs["shared_wo"] = ParamDef((s.n_shared * f, d), ("ff", "embed"))
    return defs


def _route(p: dict, xf: jax.Array, s: MoESpec
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(gate_vals, gate_idx, aux_loss) for flat tokens xf (T, D)."""
    logits = linear(xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, s.top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], s.n_experts, dtype=jnp.float32),
        axis=0)
    aux = s.n_experts * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    return gate_vals, gate_idx, aux


def _shared_ffn(p: dict, xf: jax.Array, tuner=None) -> jax.Array:
    ops.observe(xf.shape[0], xf.shape[1],
                2 * p["shared_wi"].shape[-1], tuner,
                site="moe.shared_in")
    ops.observe(xf.shape[0], p["shared_wo"].shape[-2],
                p["shared_wo"].shape[-1], tuner, site="moe.shared_out")
    sh = jax.nn.silu(linear(xf, p["shared_wg"])) * linear(xf, p["shared_wi"])
    return linear(sh, p["shared_wo"])


# ---------------------------------------------------------------------------
# Path 1: dense one-hot dispatch (small configs, pure jit)
# ---------------------------------------------------------------------------

def apply_moe(p: dict, x: jax.Array, s: MoESpec, tuner=None
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  One-hot einsum dispatch."""
    b, sl, d = x.shape
    n_tok = b * sl
    xf = x.reshape(n_tok, d)
    cap = s.capacity(n_tok)
    gate_vals, gate_idx, aux = _route(p, xf, s)

    onehot = jax.nn.one_hot(gate_idx, s.n_experts, dtype=jnp.int32)
    flat = onehot.reshape(n_tok * s.top_k, s.n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat
    pos = (pos_in_expert * flat).sum(-1).reshape(n_tok, s.top_k)
    keep = pos < cap

    disp_e = onehot.astype(x.dtype)
    disp_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=x.dtype)
    buckets = jnp.einsum("td,tke,tkc->ecd", xf, disp_e, disp_c)

    hi = ops.grouped_matmul(buckets, p["wi"], tuner=tuner, site="moe.wi")
    hg = ops.grouped_matmul(buckets, p["wg"], tuner=tuner, site="moe.wg")
    y = ops.grouped_matmul(jax.nn.silu(hg) * hi, p["wo"], tuner=tuner,
                           site="moe.wo")

    combine = disp_e * (gate_vals * keep).astype(x.dtype)[..., None]
    out = jnp.einsum("ecd,tke,tkc->td", y, combine, disp_c)
    if s.n_shared:
        out = out + _shared_ffn(p, xf, tuner)
    return out.reshape(b, sl, d), aux


# ---------------------------------------------------------------------------
# Path 2: expert-parallel sort-based dispatch (inside shard_map)
# ---------------------------------------------------------------------------

def _dispatch(xf: jax.Array, gate_idx: jax.Array, s: MoESpec, cap: int):
    """Sort-based bucket build: gathers/scatters only, zero FLOPs.

    Returns (buckets (E, cap, D), dest (T*k,), order, valid) where dest
    maps each sorted (token, choice) to its bucket row.
    """
    n_tok = xf.shape[0]
    flat_expert = gate_idx.reshape(-1)                     # (T*k,)
    order = jnp.argsort(flat_expert)                       # stable
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=s.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n_tok * s.top_k) - starts[sorted_expert]
    token_of = order // s.top_k
    valid = rank < cap
    dest = jnp.where(valid, sorted_expert * cap + rank, s.n_experts * cap)
    buckets = jnp.zeros((s.n_experts * cap + 1, xf.shape[1]), xf.dtype)
    buckets = buckets.at[dest].set(xf[token_of], mode="drop",
                                   unique_indices=True)
    return buckets[:-1].reshape(s.n_experts, cap, -1), dest, order, valid


def _combine(y: jax.Array, dest: jax.Array, order: jax.Array,
             valid: jax.Array, gate_vals: jax.Array, n_tok: int,
             s: MoESpec) -> jax.Array:
    d = y.shape[-1]
    yf = jnp.concatenate(
        [y.reshape(s.n_experts * y.shape[1], d),
         jnp.zeros((1, d), y.dtype)], axis=0)
    per_choice = yf[dest]                                  # (T*k, D) sorted
    unsort = jnp.argsort(order)
    per_choice = per_choice[unsort].reshape(n_tok, s.top_k, d)
    keep = (valid[unsort]).reshape(n_tok, s.top_k)
    w = (gate_vals * keep).astype(y.dtype)
    return jnp.einsum("tkd,tk->td", per_choice, w)


def apply_moe_ep(p: dict, x: jax.Array, s: MoESpec, tuner=None
                 ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE (n_experts divisible by the ep axis).

    MUST run inside shard_map with ``x`` a per-device shard (B_loc,
    S_loc, D), expert weights sharded on their leading dim over
    ``s.ep_axis``, the router replicated.

    Steps: local top-k route -> sort-based bucket build -> all_to_all
    (experts) -> grouped GEMM -> all_to_all back -> combine.
    """
    b, sl, d = x.shape
    n_tok = b * sl
    xf = x.reshape(n_tok, d)
    cap = s.capacity(n_tok)
    gate_vals, gate_idx, aux = _route(p, xf, s)
    aux = jax.lax.pmean(aux, s.ep_axis)

    buckets, dest, order, valid = _dispatch(xf, gate_idx, s, cap)

    # (E, C, D) -> (E/ep, ep*C, D): rows for my local experts from all peers
    buckets = jax.lax.all_to_all(buckets, s.ep_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
    hi = ops.grouped_matmul(buckets, p["wi"], tuner=tuner, site="moe.wi")
    hg = ops.grouped_matmul(buckets, p["wg"], tuner=tuner, site="moe.wg")
    y = ops.grouped_matmul(jax.nn.silu(hg) * hi, p["wo"], tuner=tuner,
                           site="moe.wo")
    y = jax.lax.all_to_all(y, s.ep_axis, split_axis=1, concat_axis=0,
                           tiled=True)                     # (E, C, D)

    out = _combine(y, dest, order, valid, gate_vals, n_tok, s)
    if s.n_shared:
        out = out + _shared_ffn(p, xf, tuner)
    return out.reshape(b, sl, d), aux


def apply_moe_tp(p: dict, x: jax.Array, s: MoESpec, tuner=None
                 ) -> tuple[jax.Array, jax.Array]:
    """Expert-TP MoE for small expert counts (mixtral: 8 experts on a
    16-way model axis).  MUST run inside shard_map with ``x`` replicated
    over the tp axis (tokens sharded over data axes only) and expert
    weights sharded on the FF dim (wi/wg last dim, wo middle dim).

    Every tp member computes all experts on its FF slice; a single psum
    over the tp axis rebuilds the expert outputs — the standard
    Megatron-style tensor parallelism applied per expert.
    """
    b, sl, d = x.shape
    n_tok = b * sl
    xf = x.reshape(n_tok, d)
    cap = s.capacity(n_tok)
    gate_vals, gate_idx, aux = _route(p, xf, s)
    aux = jax.lax.pmean(aux, s.ep_axis)

    buckets, dest, order, valid = _dispatch(xf, gate_idx, s, cap)
    hi = ops.grouped_matmul(buckets, p["wi"], tuner=tuner,
                            site="moe.wi")         # (E, C, F/tp)
    hg = ops.grouped_matmul(buckets, p["wg"], tuner=tuner, site="moe.wg")
    y = ops.grouped_matmul(jax.nn.silu(hg) * hi, p["wo"], tuner=tuner,
                           site="moe.wo")          # partial sums
    y = jax.lax.psum(y, s.ep_axis)

    out = _combine(y, dest, order, valid, gate_vals, n_tok, s)
    if s.n_shared:
        out = out + _shared_ffn(p, xf, tuner)
    return out.reshape(b, sl, d), aux
