"""Expert-parallel MoE on 8 simulated devices: the deepseek-style
shard_map path (route -> all_to_all -> grouped GEMM -> all_to_all) with
ADSALA tuning the expert GEMM tiles.

Run:  PYTHONPATH=src python examples/moe_expert_parallel.py
(sets its own XLA device-count flag; run as its own process)
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.moe import MoESpec, apply_moe, apply_moe_ep, moe_defs
from repro.models.params import init_params


def main() -> None:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MoESpec(d_model=64, n_experts=8, top_k=2, d_ff=128,
                   capacity_factor=2.0, ep_axis="model")
    params = init_params(moe_defs(spec), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 64))

    def f(p, xl):
        out, aux = apply_moe_ep(p, xl, spec)
        return out, jax.lax.pmean(aux, ("data", "model"))

    w_specs = {k: (P() if k.startswith(("router", "shared"))
                   else P("model", None, None)) for k in params}
    ep = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(w_specs, P("data", "model", None)),
        out_specs=(P("data", "model", None), P()), check_rep=False))

    out, aux = ep(params, x)
    ref, _ = apply_moe(params, x, spec)
    err = float(jnp.abs(out - ref).max())
    print(f"[moe-ep] out {out.shape}, aux={float(aux):.4f}, "
          f"max|EP - dense| = {err:.2e}")

    # what the collective schedule looks like
    hlo = ep.lower(params, x).compile().as_text()
    n_a2a = hlo.count(" all-to-all")
    print(f"[moe-ep] compiled with {n_a2a} all-to-all ops "
          f"(dispatch + return per MoE layer)")
    print("[moe-ep] OK" if err < 1e-3 else "[moe-ep] MISMATCH")


if __name__ == "__main__":
    main()
