"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the xlstm-125m assigned architecture at its REAL width (125M
params) on CPU with a short sequence so a few hundred steps complete in
minutes, exercising the full production stack: data pipeline ->
prefetch -> jit train step -> fault-tolerant driver -> checkpoints.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, build_model
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft.driver import DriverConfig, TrainDriver
from repro.train.optim import AdamWConfig
from repro.train.step import build_train_step, init_train_state
from repro.models.config import ShapeSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/adsala_train_e2e")
    args = ap.parse_args()

    # the real 125M config, shortened depth for CPU wall-clock sanity
    cfg = dataclasses.replace(get_config("xlstm-125m"), n_layers=4)
    model = build_model(cfg)
    shape = ShapeSpec("e2e", args.seq, args.batch, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    step_fn, _, _ = build_train_step(model, cfg, shape, None, opt)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    state = init_train_state(model, cfg, opt, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[e2e] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")

    src = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    data = ({k: jnp.asarray(v) for k, v in b.items()}
            for b in Prefetcher(iter(src), depth=2))

    driver = TrainDriver(
        DriverConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                     max_steps=args.steps),
        jit_step, state, data)
    t0 = time.perf_counter()
    summary = driver.run()
    dt = time.perf_counter() - t0
    hist = driver.metrics_history
    print(f"[e2e] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {summary['step']} steps ({dt:.0f}s, "
          f"{summary['step']/dt:.2f} steps/s)")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not learn"
    print("[e2e] OK — loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
