"""Serving example: batched generation with the ADSALA tuner in the loop
(paper Fig 3 runtime workflow), using the stablelm-1.6b smoke config.

Run:  PYTHONPATH=src python examples/serve_with_tuner.py
"""

import os
import subprocess
import sys


def main() -> None:
    # build a tuner artifact if missing (tiny install)
    art = "/tmp/adsala_quickstart"
    if not os.path.exists(os.path.join(art, "model.json")):
        print("[serve-example] building tuner artifact first ...")
        subprocess.run([sys.executable, "examples/quickstart.py"],
                       check=True, env={**os.environ,
                                        "PYTHONPATH": "src"})
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "stablelm-1.6b", "--scale", "smoke",
         "--requests", "4", "--prompt-len", "32", "--gen-tokens", "12",
         "--artifact", art],
        check=True, env={**os.environ, "PYTHONPATH": "src"})


if __name__ == "__main__":
    main()
