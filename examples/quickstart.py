"""Quickstart: the full ADSALA workflow in one minute on one CPU.

1. install  — gather GEMM timings on the TPU-v5e analytic platform,
              train + select the runtime model (paper Fig 2),
2. runtime  — load the artifact, let the tuner pick worker configs
              (paper Fig 3),
3. verify   — tuned configs beat the all-chips default.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    AdsalaTuner,
    InstallConfig,
    SimulatedBackend,
    gather_data,
    install,
)

ART = "/tmp/adsala_quickstart"


def main() -> None:
    # -- 1. installation (small budget for the demo) -----------------------
    cfg = InstallConfig(
        n_samples=100, repeats=2, tile_ids=(0, 3),
        models=("linear_regression", "bayesian_regression",
                "decision_tree", "xgboost"),
        grid_budget="small", cv_splits=3, seed=0)
    backend = SimulatedBackend(seed=0)
    print("== install: gathering timings on the v5e analytic platform ==")
    data = gather_data(backend, cfg)
    report = install(backend, cfg, data=data, artifact_dir=ART)
    print(report.table())

    # -- 2. runtime ----------------------------------------------------------
    print("\n== runtime: tuner decisions ==")
    tuner = AdsalaTuner.from_artifact(ART)
    for (m, k, n) in [(64, 2048, 64), (64, 64, 4096), (512, 512, 512),
                      (8192, 8192, 8192), (30000, 200, 30000)]:
        c = tuner.select(m, k, n)
        print(f"GEMM {m:>6}x{k:>6}x{n:>6} -> {c.n_chips:>3} chips, "
              f"partition {c.partition:>2}, tile {c.tile}")

    # -- 3. verify -------------------------------------------------------------
    rng = np.random.default_rng(0)
    t_def = t_tuned = 0.0
    for _ in range(30):
        m, k, n = (int(x) for x in rng.integers(64, 8192, 3))
        t_tuned += backend.time_gemm_clean(m, k, n, tuner.select(m, k, n))
        t_def += backend.time_gemm_clean(m, k, n, cfg.default_config)
    print(f"\naggregate speedup vs all-512-chips default: "
          f"{t_def / t_tuned:.2f}x")
    print(f"tuner stats: {tuner.stats}")


if __name__ == "__main__":
    main()
