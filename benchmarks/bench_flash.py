"""Tuned triangular flash attention: dense vs block-sparse tri grid.

Reports, as ``name,us_per_call,derived`` CSV lines, the three columns
of the README "Tuned flash attention" table at Sq = Skv = 2k / 8k / 32k
(2k only under ``--smoke``):

  * blocks launched per batch-head (the sequential grid steps — the
    dense grid launches every tile and streams its K/V blocks even when
    ``pl.when`` predicates the masked MXU work away; the tri map never
    launches them);
  * tile FLOPs streamed through the pipeline (launched tiles x
    4*bq*bkv*Dh, the QK^T + AV MXU volume a launched tile occupies);
  * measured wall clock of the blocked CPU attention proxy
    (MeasuredCPUBackend routine="attn") and the analytic TPU v5e priced
    time, dense vs tri configs.

``--smoke`` (the CI flash job) also gates the PR's acceptance criteria:
at Sq = Skv >= 2048 the triangular grid must execute <= 60% of the
dense grid's steps, and the two kernels' outputs must be bitwise equal
in interpret mode.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.costmodel import (
    FLASH_BLOCKS,
    GemmConfig,
    estimate_routine_time,
)
from repro.core.timing import MeasuredCPUBackend
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    flash_grid_counts,
)

D_HEAD = 64


def _cfg(bq: int, bkv: int, grid: str) -> GemmConfig:
    return GemmConfig(1, "M", 3, flash_block_id=FLASH_BLOCKS.index(
        (bq, bkv)), flash_grid=grid)


def _best_of(fn, reps: int = 3) -> float:
    fn()  # warm (operand buffers, BLAS threads)
    return min(fn() for _ in range(reps))


def run(smoke: bool = False) -> list[str]:
    lines = []
    seqs = (2048,) if smoke else (2048, 8192, 32768)
    for s in seqs:
        # 256x256 keeps the 2k grid deep enough (g=8) for the triangle
        # to pay; past 2k the historical 512x512 default is fine (g>=16)
        bq, bkv = (256, 256) if s <= 2048 else (512, 512)
        tri, dense = flash_grid_counts(s, s, bq, bkv, causal=True)
        ratio = tri / dense
        lines.append(f"flash_blocks_dense_{s},{dense},tiles")
        lines.append(f"flash_blocks_tri_{s},{tri},"
                     f"ratio={ratio:.3f}_bq{bq}_bkv{bkv}")
        tile_flops = 4 * bq * bkv * D_HEAD
        lines.append(f"flash_tile_gflops_dense_{s},"
                     f"{dense * tile_flops / 1e9:.2f},GF")
        lines.append(f"flash_tile_gflops_tri_{s},"
                     f"{tri * tile_flops / 1e9:.2f},GF")
        if s >= 2048:
            assert ratio <= 0.60, (
                f"triangular grid ran {ratio:.1%} of dense steps at "
                f"S={s} (acceptance bound: 60%)")
        # analytic TPU v5e pricing of the same two configs
        for grid in ("dense", "tri"):
            t = estimate_routine_time(s, D_HEAD, s, _cfg(bq, bkv, grid),
                                      routine="attn").total_s
            lines.append(f"flash_priced_{grid}_{s},{t * 1e6:.1f},"
                         "tpu_v5e_model_us")
        # measured wall clock of the blocked CPU attention proxy; the
        # 32k row is ~2x 137 GF of numpy GEMM — full mode only
        if not smoke or s <= 2048:
            be = MeasuredCPUBackend(max_dim=s)
            for grid in ("dense", "tri"):
                cfg_ = _cfg(bq, bkv, grid)
                t = _best_of(lambda: be.time_routine(
                    s, D_HEAD, s, cfg_, routine="attn"),
                    reps=3 if s <= 2048 else 1)
                lines.append(f"flash_cpu_{grid}_{s},{t * 1e6:.0f},"
                             "measured_us")

    # interpret-mode kernel parity: the tri grid must be bitwise equal
    # to the dense grid (identical block arithmetic, fewer launches)
    s0, b0 = (256, 64) if smoke else (512, 128)
    rng = np.random.default_rng(0)
    q, k, v = (rng.standard_normal((2, s0, D_HEAD)).astype(np.float32)
               for _ in range(3))
    t0 = time.perf_counter()
    out_d = np.asarray(flash_attention_pallas(
        q, k, v, bq=b0, bkv=b0, causal=True, interpret=True,
        grid="dense"))
    t_dense = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_t = np.asarray(flash_attention_pallas(
        q, k, v, bq=b0, bkv=b0, causal=True, interpret=True, grid="tri"))
    t_tri = time.perf_counter() - t0
    np.testing.assert_array_equal(out_t, out_d)
    lines.append(f"flash_interpret_dense_{s0},{t_dense * 1e6:.0f},"
                 "trace+run_us")
    lines.append(f"flash_interpret_tri_{s0},{t_tri * 1e6:.0f},"
                 "bitwise_equal")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
