"""Search harness benchmark: beam vs exhaustive on the enlarged space,
and uniform-grid vs beam-searched installs at an equal timing budget.

Reports, as ``name,us_per_call,derived`` CSV lines:

  * the enlarged/default space size ratio (must be >= 10x);
  * beam-search quality on the enlarged space — max predicted-time
    regret vs the exhaustive argmin and the fraction of (dim, config)
    cells it demanded prices for (the smoke assertions: width 8 within
    1%, pricing <= 25% of the space);
  * wall-clock of the beam vs pricing the space exhaustively;
  * two real installs spending the SAME number of timed cells — a dense
    uniform grid over few dims vs a beam-guided sparse grid over ~4x
    the dims — scored on one shared noise-free evaluation set (mean
    speedup over the default worker config).  This is the README's
    "what does search buy at install time" table.

``--smoke`` (used by the CI search job) shrinks the dims/budget to
seconds; the assertions run in both modes.
"""

from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import (
    AdsalaTuner,
    ConfigSpace,
    InstallConfig,
    ROUTINES,
    SimulatedBackend,
    beam_search,
    exhaustive_best,
    gather_data,
    install,
)
from repro.core.halton import sample_gemm_dims


def _mixed_routines(n: int) -> list[str]:
    return [ROUTINES[i % len(ROUTINES)] for i in range(n)]


def _eval_speedup(tuner: AdsalaTuner, dims: np.ndarray,
                  routines: list[str]) -> float:
    """Mean noise-free speedup over the default worker config on a
    shared held-out set — the equal-footing score for both installs."""
    from repro.core.installer import DEFAULT_WORKER_CONFIG

    be = SimulatedBackend(seed=1)
    ratios = []
    for (m, k, n), r in zip(dims, routines):
        cfg = tuner.select(int(m), int(k), int(n), r)
        t_c = be.time_routine_clean(int(m), int(k), int(n), cfg,
                                    routine=r)
        t_d = be.time_routine_clean(int(m), int(k), int(n),
                                    DEFAULT_WORKER_CONFIG, routine=r)
        ratios.append(t_d / t_c)
    return float(np.mean(ratios))


def run(smoke: bool = False) -> list[str]:
    lines = []

    # --- space sizes: the enlarged space must be >= 10x the default ------
    default_space = ConfigSpace.default(512)
    enlarged = ConfigSpace.enlarged(512)
    ratio = enlarged.size() / default_space.size()
    assert ratio >= 10.0, (
        f"enlarged space only {ratio:.1f}x the default grid")
    lines.append(f"search_space_default,{default_space.size()},configs")
    lines.append(f"search_space_enlarged,{enlarged.size()},"
                 f"{ratio:.1f}x_default")

    # --- beam quality/cost on the enlarged space -------------------------
    # width scales with how many dims must ALL be within 1%: 8 covers
    # the smoke set; the 5x larger full set needs 24 (still < 25% of
    # the space priced — see the sweep in the suite's README table)
    n_dims, width = (8, 8) if smoke else (40, 24)
    dims = sample_gemm_dims(n_dims, mem_limit_bytes=500 * 2**20, seed=3)
    routines = _mixed_routines(len(dims))

    t0 = time.perf_counter()
    beam = beam_search(dims, enlarged, width=width, routines=routines)
    t_beam = time.perf_counter() - t0
    t0 = time.perf_counter()
    exact = exhaustive_best(dims, enlarged, routines=routines)
    t_exact = time.perf_counter() - t0

    regret = max(b[0] / e[0] for b, e in zip(beam.costs, exact.costs))
    assert regret <= 1.01, (
        f"beam width {width} regret {regret:.4f} exceeds 1% "
        "of exhaustive")
    assert beam.priced_fraction <= 0.25, (
        f"beam priced {beam.priced_fraction:.1%} of the space (> 25%)")
    lines.append(f"beam_w{width}_max_regret,{(regret - 1) * 1e6:.0f},"
                 f"ppm_over_exhaustive_n={n_dims}")
    lines.append(f"beam_w{width}_priced,{beam.n_priced},"
                 f"{beam.priced_fraction:.1%}_of_{beam.n_space}_cells")
    lines.append(f"beam_w{width}_wall,{t_beam * 1e6:.0f},"
                 f"exhaustive={t_exact * 1e6:.0f}us")

    # --- equal-budget installs: dense uniform grid vs beam-guided --------
    # Both spend the same number of timed (dim, config) cells.  The
    # uniform grid burns its budget timing every config for few dims;
    # the beam install times ~quota survivors per dim and covers ~4x
    # the dims with the same budget.
    # >= 12 dims keeps the stratified test split non-empty
    n_uniform = 12 if smoke else 24
    base = dict(repeats=2, tile_ids=(0, 3),
                models=("linear_regression",) if smoke
                else ("linear_regression", "decision_tree", "xgboost"),
                routines=tuple(ROUTINES), grid_budget="small",
                cv_splits=3, seed=0)
    cfg_u = InstallConfig(n_samples=n_uniform, **base)
    n_cells = n_uniform * cfg_u.resolved_space().size()
    quota = 10
    cfg_b = InstallConfig(n_samples=n_cells // quota,
                          timing_budget=n_cells, **base)

    eval_dims = sample_gemm_dims(32 if smoke else 120,
                                 mem_limit_bytes=500 * 2**20, seed=17)
    eval_routines = _mixed_routines(len(eval_dims))

    scores = {}
    for tag, icfg in (("uniform", cfg_u), ("beam", cfg_b)):
        backend = SimulatedBackend(seed=0)
        with tempfile.TemporaryDirectory() as art:
            t0 = time.perf_counter()
            data = gather_data(backend, icfg)
            install(backend, icfg, data=data, artifact_dir=art)
            wall = time.perf_counter() - t0
            timed = int(data.timed_mask().sum())
            tuner = AdsalaTuner.from_artifact(art)
            tuner._cache.clear()
            scores[tag] = _eval_speedup(tuner, eval_dims, eval_routines)
        lines.append(f"install_{tag}_wall,{wall * 1e6:.0f},"
                     f"{timed}cells_{icfg.n_samples}dims")
        lines.append(f"install_{tag}_speedup,{scores[tag]:.3f},"
                     f"mean_vs_default_n={len(eval_dims)}")
    lines.append(f"install_beam_vs_uniform,"
                 f"{scores['beam'] / scores['uniform']:.3f},"
                 f"equal_budget_{n_cells}cells")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
