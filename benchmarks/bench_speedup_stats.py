"""Tables V/VI: ADSALA speedup statistics on a fresh low-discrepancy set.

Paper protocol: an additional scrambled-Halton test set (independent of
train/test), speedup = t(default = all workers) / t(ADSALA-chosen),
inclusive of model evaluation time; reported for 0-100 MB and 0-500 MB
ranges, with measurement noise on ("hyper-threading" analogue: the
noisy simulated platform) and off (clean timings).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import simulated_run
from repro.core import AdsalaTuner
from repro.core.halton import gemm_bytes, sample_gemm_dims


def _stats(tag: str, speedups: np.ndarray) -> list[str]:
    q = lambda p: float(np.percentile(speedups, p))
    return [
        f"{tag}_mean,{float(speedups.mean()):.3f},speedup",
        f"{tag}_std,{float(speedups.std()):.3f},",
        f"{tag}_min,{float(speedups.min()):.3f},",
        f"{tag}_p25,{q(25):.3f},",
        f"{tag}_p50,{q(50):.3f},",
        f"{tag}_p75,{q(75):.3f},",
        f"{tag}_max,{float(speedups.max()):.3f},",
    ]


def run(n_points: int = 60) -> list[str]:
    backend, icfg, _, _, art = simulated_run(500)
    tuner = AdsalaTuner.from_artifact(art)
    # fresh low-discrepancy set, disjoint seed (paper: 174 points)
    dims = sample_gemm_dims(n_points, mem_limit_bytes=500 * 2**20,
                            dtype_bytes=icfg.dtype_bytes, seed=4242)
    t_eval_s = 150e-6  # representative tuner evaluation latency
    lines = []
    for noisy, noise_tag in ((True, "ht_on"), (False, "ht_off")):
        speed = []
        sizes = gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2],
                           icfg.dtype_bytes)
        for (m, k, n) in dims:
            m, k, n = int(m), int(k), int(n)
            chosen = tuner.select(m, k, n)
            if noisy:
                t_c = backend.time_gemm(m, k, n, chosen)
                t_d = backend.time_gemm(m, k, n, icfg.default_config)
            else:
                t_c = backend.time_gemm_clean(m, k, n, chosen)
                t_d = backend.time_gemm_clean(m, k, n, icfg.default_config)
            speed.append(t_d / (t_c + t_eval_s))
        speed = np.asarray(speed)
        for limit_mb, range_tag in ((500, "0_500mb"), (100, "0_100mb")):
            mask = sizes <= limit_mb * 2**20
            if mask.sum() >= 5:
                lines += _stats(f"table56_{noise_tag}_{range_tag}",
                                speed[mask])
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
