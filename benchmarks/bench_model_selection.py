"""Tables III/IV: model performance + estimated speedups per platform.

Columns match the paper: normalised test RMSE, ideal mean/aggregate
speedup, model evaluation time (µs), estimated mean/aggregate speedup —
plus the cache-amortised (warm) columns this implementation adds.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import measured_run, simulated_run


def _rows_from_dicts(tag: str, reports: list[dict],
                     selected: str) -> list[str]:
    lines = []
    for r in reports:
        lines.append(
            f"{tag}_{r['name']},{r['eval_time_us']:.1f},"
            f"nrmse={r['normalised_rmse']:.3f};"
            f"ideal={r['ideal_mean_speedup']:.3f};"
            f"est={r['est_mean_speedup']:.3f};"
            f"warm={r['warm_est_mean_speedup']:.3f}")
    lines.append(f"{tag}_selected,0,{selected}")
    return lines


def _rows(tag: str, report) -> list[str]:
    return _rows_from_dicts(tag, [r.to_dict() for r in report.reports],
                            report.selected)


def run() -> list[str]:
    lines = []
    *_, report, art = simulated_run(500)
    if report is not None:
        lines += _rows("table3_v5esim", report)
    else:  # cached install: the selection table lives in the artifact
        with open(os.path.join(art, "config.json")) as f:
            c = json.load(f)
        lines += _rows_from_dicts("table3_v5esim", c["selection"],
                                  c["selected"])
    *_, report_m, _ = measured_run()
    lines += _rows("table4_cpumeas", report_m)
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
