"""Continuous batching vs fixed-batch serving on ragged traces.

The ISSUE-9 scenario: a request trace with ragged prompt/output lengths
served two ways on the same smoke model —

* **fixed-batch** (the pre-PR-9 serve loop): FIFO groups of ``slots``
  requests, prompts right-padded to the group max, every group decoded
  until its *slowest* member finishes; early finishers burn idle slot
  steps.
* **continuous batching** (:class:`repro.serve.scheduler`): per-step
  admit/retire over the paged KV cache; a retired sequence's slot and
  pages serve the next request on the same step.

The headline metric is **goodput** — kept tokens per slot-step
(1.0 = every decode slot produced a kept token every step).  On a
ragged trace continuous batching must win; on a uniform trace the two
schedules are identical and goodput must match exactly — that pair of
assertions is the ``--smoke`` CI contract.  Wall-clock us/token is
reported for both paths (same jitted kernels underneath, so the delta
is scheduling, not compute).

Reports ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import time

import numpy as np


def _traces(vocab: int, n: int, seed: int):
    """(ragged, uniform) request traces of n requests each."""
    rng = np.random.default_rng(seed)
    # few distinct prompt lengths: bounds prefill retraces in both paths
    lengths = (4, 6, 8)
    ragged = [(rng.integers(0, vocab, int(rng.choice(lengths))).tolist(),
               int(rng.integers(2, 12))) for _ in range(n)]
    uniform = [(rng.integers(0, vocab, 6).tolist(), 6) for _ in range(n)]
    return ragged, uniform


def _run_cb(model, cfg, params, trace, *, slots, n_pages, page_size,
            max_seq_len):
    from repro.serve.scheduler import ContinuousBatchingScheduler

    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=slots, n_pages=n_pages,
        page_size=page_size, max_seq_len=max_seq_len)
    for prompt, max_new in trace:
        sched.submit(prompt, max_new)
    t0 = time.perf_counter()
    finished = sched.run_until_drained()
    wall = time.perf_counter() - t0
    toks = sum(len(f.tokens) for f in finished.values())
    assert len(finished) == len(trace)
    return {"tokens": toks, "steps": sched.steps,
            "goodput": sched.goodput(), "wall_s": wall}


def _run_fixed(model, cfg, params, trace, *, slots, cap):
    """The pre-PR-9 loop: FIFO groups, padded prompts, slowest-member
    barrier.  Same jitted prefill/decode kernels as production serve."""
    import jax
    import jax.numpy as jnp

    from repro.train.step import make_ctx

    dctx = make_ctx(None, "decode", cache_len=cap)
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, dctx))
    prefills: dict[int, object] = {}

    def prefill_fn(length):
        if length not in prefills:
            pctx = make_ctx(None, "prefill", cache_len=cap, remat=False)
            prefills[length] = jax.jit(
                lambda p, t: model.prefill(p, t, pctx))
        return prefills[length]

    tokens = steps = 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), slots):
        group = trace[i:i + slots]
        lmax = max(len(p) for p, _ in group)
        batch = np.zeros((len(group), lmax), np.int32)
        for j, (p, _) in enumerate(group):
            batch[j, :len(p)] = p       # right-pad to the group max
        logits, cache = prefill_fn(lmax)(params, jnp.asarray(batch))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # the whole group decodes until its slowest member is done
        group_steps = max(n for _, n in group) - 1
        for s in range(group_steps):
            logits, cache = decode(params, tok, cache,
                                   jnp.int32(lmax + s))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        tokens += sum(n for _, n in group)   # kept tokens only
        steps += group_steps
    wall = time.perf_counter() - t0
    goodput = tokens / (steps * slots) if steps else 0.0
    return {"tokens": tokens, "steps": steps, "goodput": goodput,
            "wall_s": wall}


def run(smoke: bool = False) -> list[str]:
    import jax

    from repro.configs import build_model, get_smoke_config

    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    slots, page_size = 4, 4
    n_req = 16 if smoke else 32
    max_seq_len = 20
    from repro.serve.kv_cache import pages_for
    cap = pages_for(max_seq_len, page_size) * page_size
    n_pages = slots * pages_for(max_seq_len, page_size) * 2

    ragged, uniform = _traces(cfg.vocab, n_req, seed=23)
    lines: list[str] = []
    results = {}
    for label, trace in (("ragged", ragged), ("uniform", uniform)):
        cb = _run_cb(model, cfg, params, trace, slots=slots,
                     n_pages=n_pages, page_size=page_size,
                     max_seq_len=max_seq_len)
        fb = _run_fixed(model, cfg, params, trace, slots=slots, cap=cap)
        results[label] = (cb, fb)
        for name, r in (("cb", cb), ("fixed", fb)):
            us_tok = r["wall_s"] * 1e6 / max(r["tokens"], 1)
            lines.append(
                f"sched_{name}_{label},{us_tok:.0f},"
                f"goodput={r['goodput']:.3f};steps={r['steps']};"
                f"tokens={r['tokens']}")

    cb_r, fb_r = results["ragged"]
    cb_u, fb_u = results["uniform"]
    gain = cb_r["goodput"] / max(fb_r["goodput"], 1e-9)
    lines.append(f"sched_goodput_gain_ragged,{gain:.3f},x_vs_fixed_batch")
    lines.append(f"sched_goodput_gap_uniform,"
                 f"{abs(cb_u['goodput'] - fb_u['goodput']) * 1e6:.0f},"
                 f"abs_x1e6")
    if smoke:
        assert cb_r["tokens"] == fb_r["tokens"], "dropped tokens"
        assert cb_r["goodput"] > fb_r["goodput"], (
            f"continuous batching did not beat fixed-batch on the "
            f"ragged trace: {cb_r['goodput']:.3f} <= "
            f"{fb_r['goodput']:.3f}")
        assert abs(cb_u["goodput"] - fb_u["goodput"]) < 1e-9, (
            f"uniform-trace goodput parity broken: {cb_u['goodput']:.6f}"
            f" vs {fb_u['goodput']:.6f}")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
