"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a machine-readable
``BENCH_10.json`` (per-suite rows + medians, install wall-clock and the
selected model's warm-tuner speedups) so the perf trajectory is tracked
across PRs instead of scraped from logs.  Modules share a cached ADSALA
install run per platform (benchmarks/common.py); ADSALA_BENCH_FULL=1
raises the install budget to paper scale, ADSALA_BENCH_JSON overrides
the JSON output path (default ``results/BENCH_10.json``).
"""

from __future__ import annotations

import json
import os
import statistics
import sys
import time
import traceback

# allow the documented `python benchmarks/run.py` invocation: the
# script dir is on sys.path but the repo root (the `benchmarks`
# package parent) is not
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _parse_row(line: str) -> dict | None:
    parts = line.split(",", 2)
    if len(parts) != 3:
        return None
    name, us, derived = parts
    try:
        return {"name": name, "us": float(us), "derived": derived}
    except ValueError:
        return None


def _install_summary() -> dict:
    """Wall-clock + selection stats of the shared v5e-sim install run.

    Timed around ``simulated_run()`` (the gather+install when cold, the
    artifact read when cached — ``cached`` says which); the selection
    rows come from the persisted config.json either way.
    """
    from benchmarks.common import simulated_run

    t0 = time.time()
    _, cfg, data, report, art = simulated_run()
    wall = time.time() - t0
    out: dict = {
        "platform": "v5e-sim",
        "n_samples": int(cfg.n_samples),
        "wall_s": round(wall, 3),
        "cached": report is None,
    }
    try:
        with open(os.path.join(art, "config.json")) as f:
            config = json.load(f)
        sel = config.get("selected")
        out["selected"] = sel
        row = next((r for r in config.get("selection", [])
                    if r.get("name") == sel), None)
        if row:
            out["warm_est_mean_speedup"] = row["warm_est_mean_speedup"]
            out["warm_est_aggregate_speedup"] = \
                row["warm_est_aggregate_speedup"]
            out["ideal_mean_speedup"] = row["ideal_mean_speedup"]
            out["normalised_rmse"] = row["normalised_rmse"]
    except (OSError, KeyError, StopIteration):
        pass
    return out


def main() -> None:
    from benchmarks import (
        bench_ablation,
        bench_affinity,
        bench_breakdown,
        bench_dispatch_overhead,
        bench_flash,
        bench_gflops_curve,
        bench_heatmap,
        bench_histogram,
        bench_install_vectorised,
        bench_model_selection,
        bench_predesigned,
        bench_registry,
        bench_reinstall,
        bench_roofline,
        bench_routine_grid,
        bench_scheduler,
        bench_search,
        bench_spec_derivation,
        bench_speedup_stats,
        bench_workload_install,
    )
    suites = [
        ("install_vectorised", bench_install_vectorised.run),
        ("routine_grid", bench_routine_grid.run),
        ("search_harness", bench_search.run),
        ("workload_install", bench_workload_install.run),
        ("reinstall_loop", bench_reinstall.run),
        ("registry_transfer", bench_registry.run),
        ("serving_scheduler", bench_scheduler.run),
        ("dispatch_overhead", bench_dispatch_overhead.run),
        ("flash_attention", bench_flash.run),
        ("spec_derivation", bench_spec_derivation.run),
        ("fig1_fig8_histogram", bench_histogram.run),
        ("fig9_heatmap", bench_heatmap.run),
        ("table3_table4_model_selection", bench_model_selection.run),
        ("table5_table6_speedup_stats", bench_speedup_stats.run),
        ("fig11_fig12_gflops_curve", bench_gflops_curve.run),
        ("fig13_fig14_predesigned", bench_predesigned.run),
        ("table7_breakdown", bench_breakdown.run),
        ("fig7_affinity", bench_affinity.run),
        ("ablation_preprocessing", bench_ablation.run),
    ]
    bench_json: dict = {"schema": 1, "generated_unix": time.time(),
                        "full_budget":
                        os.environ.get("ADSALA_BENCH_FULL") == "1",
                        "suites": {}, "roofline": []}
    # the shared install run doubles as the perf headline: install
    # wall-clock + warm-tuner speedups of the selected model
    try:
        bench_json["install"] = _install_summary()
    except Exception:
        traceback.print_exc()
        bench_json["install"] = {"error": "install summary failed"}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        rows: list[dict] = []
        try:
            for line in fn():
                print(line)
                row = _parse_row(line)
                if row:
                    rows.append(row)
            wall_us = (time.time() - t0) * 1e6
            print(f"suite_{name},{wall_us:.0f},wall_us")
            bench_json["suites"][name] = {
                "status": "ok", "wall_us": round(wall_us),
                "rows": rows,
                "median_us": (statistics.median(r["us"] for r in rows)
                              if rows else None),
            }
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"suite_{name},0,FAILED")
            bench_json["suites"][name] = {
                "status": "failed",
                "wall_us": round((time.time() - t0) * 1e6),
                "rows": rows, "median_us": None,
            }
    # roofline table (one row per dry-run cell)
    try:
        rows = bench_roofline.run(csv=False)
        for r in rows:
            print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{r['total_ms']*1e3:.0f},"
                  f"dominant={r['dominant']};"
                  f"fraction={r['roofline_fraction']:.3f};"
                  f"useful={r['useful_ratio']:.3f}")
        bench_json["roofline"] = rows
    except Exception:
        failures += 1
        traceback.print_exc()
    out_path = os.environ.get("ADSALA_BENCH_JSON",
                              os.path.join("results", "BENCH_10.json"))
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(bench_json, f, indent=1)
    print(f"bench_json,{len(bench_json['suites'])},{out_path}",
          file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
