"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules share a cached ADSALA
install run per platform (benchmarks/common.py); ADSALA_BENCH_FULL=1
raises the install budget to paper scale.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_ablation,
        bench_affinity,
        bench_breakdown,
        bench_dispatch_overhead,
        bench_gflops_curve,
        bench_heatmap,
        bench_histogram,
        bench_install_vectorised,
        bench_model_selection,
        bench_predesigned,
        bench_roofline,
        bench_routine_grid,
        bench_spec_derivation,
        bench_speedup_stats,
    )
    suites = [
        ("install_vectorised", bench_install_vectorised.run),
        ("routine_grid", bench_routine_grid.run),
        ("dispatch_overhead", bench_dispatch_overhead.run),
        ("spec_derivation", bench_spec_derivation.run),
        ("fig1_fig8_histogram", bench_histogram.run),
        ("fig9_heatmap", bench_heatmap.run),
        ("table3_table4_model_selection", bench_model_selection.run),
        ("table5_table6_speedup_stats", bench_speedup_stats.run),
        ("fig11_fig12_gflops_curve", bench_gflops_curve.run),
        ("fig13_fig14_predesigned", bench_predesigned.run),
        ("table7_breakdown", bench_breakdown.run),
        ("fig7_affinity", bench_affinity.run),
        ("ablation_preprocessing", bench_ablation.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            for line in fn():
                print(line)
            print(f"suite_{name},{(time.time()-t0)*1e6:.0f},wall_us")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"suite_{name},0,FAILED")
    # roofline table (one row per dry-run cell)
    try:
        rows = bench_roofline.run(csv=False)
        for r in rows:
            print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{r['total_ms']*1e3:.0f},"
                  f"dominant={r['dominant']};"
                  f"fraction={r['roofline_fraction']:.3f};"
                  f"useful={r['useful_ratio']:.3f}")
    except Exception:
        failures += 1
        traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
