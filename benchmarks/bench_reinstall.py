"""Closed-loop serving: does the drift-triggered re-install pay off?

Stages the ISSUE-8 scenario end to end:

1. install an artifact mix-weighted by a *prefill-like* profile
   (large square gemms);
2. shift serving to a *decode-like* mix (skinny gemms, per-head syrk,
   trsm cache updates) recorded into per-traffic-class recorders;
3. let the :class:`repro.serve.ReinstallManager` notice the drift and
   re-install + hot-swap in the background while hammer threads keep
   dispatching through the manager;
4. measure predicted-time regret on the *shifted* mix against the
   noise-free oracle, before and after the swap:

       regret = mean( t_clean(chosen) / t_clean(best) - 1 )

Reports ``name,us_per_call,derived`` CSV: pre/post regret, the
improvement ratio, pre/post drift, the fire-to-swap wall-clock and the
dispatches served during the install.  ``--smoke`` (the CI reinstall
job) asserts the closed loop's contract: post-swap regret < pre-swap,
drift closed below the threshold, and zero dropped dispatches.
"""

from __future__ import annotations

import sys
import tempfile
import threading
import time

import numpy as np

from repro.core import (
    AdsalaTuner,
    InstallConfig,
    SimulatedBackend,
    WorkloadProfile,
    candidate_configs,
    install,
)
from repro.kernels.recorder import DispatchEvent, DispatchRecorder
from repro.serve import ReinstallConfig, ReinstallManager

ROUTINES3 = ("gemm", "syrk", "trsm")
THRESHOLD = 0.25


def prefill_profile() -> WorkloadProfile:
    """Install-time mix: big square prompt-processing gemms."""
    events = [
        DispatchEvent("gemm", 4096, 2048, 2048, count=96, site="proj"),
        DispatchEvent("gemm", 4096, 2048, 8192, count=32, site="mlp.up"),
        DispatchEvent("gemm", 4096, 8192, 2048, count=32, site="mlp.dn"),
        DispatchEvent("syrk", 4096, 64, 4096, count=8, site="attn.qk"),
    ]
    return WorkloadProfile.from_events(
        events, by="flops", source={"kind": "bench", "name": "prefill"})


def decode_events() -> list[DispatchEvent]:
    """Shifted serving mix: skinny decode gemms + per-head syrk scores
    + trsm-tagged cache updates (cf. the PR 4 recorded mixes)."""
    return [
        DispatchEvent("gemm", 64, 2048, 2048, count=96, site="proj"),
        DispatchEvent("gemm", 64, 2048, 8192, count=32, site="mlp.up"),
        DispatchEvent("gemm", 64, 8192, 2048, count=32, site="mlp.dn"),
        DispatchEvent("gemm", 64, 2048, 50257, count=1, site="logits"),
        DispatchEvent("syrk", 512, 64, 512, count=64, site="attn.qk"),
        DispatchEvent("trsm", 64, 64, 2048, count=16, site="cache"),
    ]


def _regret(artifact: str, backend: SimulatedBackend,
            eval_dims: np.ndarray, names: list[str],
            t_best: np.ndarray) -> float:
    """Mean oracle regret of the artifact's tuner on the eval mix.

    The clean times are re-priced over the *tuner's own* candidate list
    — a budgeted install persists the beam-survivor union, not the
    dense grid, so indexing a shared dense matrix with the tuner's
    argmin would compare different configs.  ``t_best`` stays the
    global dense-grid oracle: a budgeted artifact whose pool misses the
    true best pays for it honestly."""
    tuner = AdsalaTuner.from_artifact(artifact)
    pred = tuner.predicted_times_many([tuple(d) for d in eval_dims],
                                      routines=names)
    clean = backend.time_routine_clean_batch(eval_dims, tuner.candidates,
                                             routines=names)
    chosen = clean[np.arange(len(eval_dims)), np.argmin(pred, axis=1)]
    return float(np.mean(chosen / np.maximum(t_best, 1e-12) - 1.0))


def run(smoke: bool = False) -> list[str]:
    lines: list[str] = []
    backend = SimulatedBackend(seed=0)
    n_samples = 120 if smoke else 400
    base = dict(n_samples=n_samples, repeats=2, tile_ids=(0, 3),
                routines=ROUTINES3, models=("lightgbm",),
                cv_splits=2, seed=0)

    # 1. the artifact serving starts on: weighted by the PREFILL mix
    art = tempfile.mkdtemp(prefix="reinstall_live_")
    install(backend, InstallConfig(**base, workload=prefill_profile()),
            artifact_dir=art)

    # 2. serving shifts: per-traffic-class recorders fill with the
    # decode mix (prefill volume dries up — one residual event)
    recs = {"prefill": DispatchRecorder(), "decode": DispatchRecorder()}
    recs["prefill"].events.append(
        DispatchEvent("gemm", 4096, 2048, 2048, count=1, site="proj"))
    for _ in range(8):
        recs["decode"].events.extend(decode_events())

    shifted = WorkloadProfile.from_events(decode_events(), by="flops")
    n_eval = 80 if smoke else 200
    eval_dims = shifted.sample_dims(
        n_eval, bias=1.0, mem_limit_bytes=InstallConfig().mem_limit_mb
        * 2**20, dtype_bytes=2, seed=1234)
    quotas = shifted.routine_quotas(ROUTINES3, n_eval, floor=0.0)
    names = np.repeat(np.asarray(ROUTINES3, dtype=object),
                      [quotas[r] for r in ROUTINES3])
    names = list(names[np.random.default_rng(7).permutation(len(names))])
    cands = candidate_configs(InstallConfig().max_chips, tiles=(0, 3))
    clean = backend.time_routine_clean_batch(eval_dims, cands,
                                             routines=names)
    t_best = clean.min(axis=1)          # global dense-grid oracle

    r_pre = _regret(art, backend, eval_dims, names, t_best)

    # 3. the closed loop: manager notices, re-installs in the
    # background, swaps — while hammer threads keep dispatching
    mgr = ReinstallManager(
        art, recs, backend=backend,
        cfg=ReinstallConfig(
            threshold=THRESHOLD, cooldown_s=0.0, min_events=16,
            # ~25% of the dense cell grid: below ~20 cells/dim the
            # beam-survivor pool under-covers the skinny decode shapes
            # and the budgeted model misprices them badly
            install=InstallConfig(**base,
                                  timing_budget=2400 if smoke else 8000)))
    d_pre = mgr.drift()
    shapes = [(int(m), int(k), int(n)) for m, k, n in eval_dims[:12]]
    served = [0] * 4
    errors: list = []
    stop = threading.Event()

    def hammer(tid: int) -> None:
        while not stop.is_set():
            try:
                for i, (m, k, n) in enumerate(shapes):
                    mgr.select(m, k, n, ROUTINES3[i % 3])
                    served[tid] += 1
                # decode-step cadence; a hard spin would just fight the
                # background install for the GIL and stretch the swap
                time.sleep(0.002)
            except Exception as e:          # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    t0 = time.perf_counter()
    fired = mgr.check()
    mgr.wait()
    wall = time.perf_counter() - t0
    stop.set()
    for t in threads:
        t.join()

    d_post = mgr.drift()
    r_post = _regret(art, backend, eval_dims, names, t_best)

    lines.append(f"reinstall_wall,{wall * 1e6:.0f},fire_to_swap")
    lines.append(f"reinstall_served_during,{sum(served)},"
                 f"dispatches_4threads")
    lines.append(f"reinstall_drift_pre,{d_pre * 1e6:.0f},tv_x1e6")
    lines.append(f"reinstall_drift_post,{d_post * 1e6:.0f},tv_x1e6")
    lines.append(f"reinstall_regret_pre,{r_pre * 1e6:.0f},"
                 f"regret_x1e6_on_shifted_mix")
    lines.append(f"reinstall_regret_post,{r_post * 1e6:.0f},"
                 f"regret_x1e6_on_shifted_mix")
    lines.append(f"reinstall_regret_improvement,"
                 f"{r_pre / max(r_post, 1e-9):.2f},x")
    if smoke:
        assert fired and mgr.swaps == 1 and mgr.last_error is None, (
            f"closed loop did not complete: fired={fired} "
            f"swaps={mgr.swaps} err={mgr.last_error!r}")
        assert not errors and all(n > 0 for n in served), (
            f"dispatches dropped during the swap: errors={errors[:3]}")
        assert d_post < THRESHOLD, (
            f"post-swap drift {d_post:.3f} not below {THRESHOLD}")
        assert r_post < r_pre, (
            f"post-swap regret {r_post:.4f} not below pre-swap "
            f"{r_pre:.4f} on the shifted mix")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
