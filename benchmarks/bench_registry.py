"""Per-arch registry: measured installs, sim-to-real gap, transfer.

Stages the ISSUE-10 scenario end to end on real wall-clock timings:

1. the first **measured mixed-routine install** — gemm/syrk/trsm timed
   by the hardened ``MeasuredCPUBackend`` (warmup + median-of-k) on
   this host, through a 1-chip cache-blocking ConfigSpace, into a
   fingerprint-keyed :class:`~repro.core.registry.ArtifactRegistry`
   cell;
2. the same install config on ``SimulatedBackend`` → the **sim-to-real
   per-routine Tables III/IV gap** (how far the analytic model's
   per-routine ideal speedups sit from measured reality);
3. a second architecture, emulated by a deterministic per-routine /
   per-tile skew over the measured backend, cold-starts via a
   **transfer install** from the real cell's donor rows at ≤ 10 % of
   the donor's timing-sample budget — compared against a scratch
   install at the *same* local cell budget and against a full-budget
   local install:

       regret = mean( t_real(chosen) / t_real(best) - 1 )

Reports ``name,us_per_call,derived`` CSV.  ``--smoke`` (the CI
``registry`` job) asserts the ISSUE-10 contract: fingerprint JSON
round-trip, calibration ≤ 10 % of the donor budget, transfer regret no
worse than equal-budget scratch, and transfer within 1.5× of the
full-budget install's regret.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile

import numpy as np

from repro.core import (
    AdsalaTuner,
    ArtifactRegistry,
    ConfigSpace,
    HardwareFingerprint,
    InstallConfig,
    MeasuredCPUBackend,
    SimulatedBackend,
    install,
)
from repro.core.halton import sample_gemm_dims

ROUTINES3 = ("gemm", "syrk", "trsm")
#: distinct (bm, bk) cache-blocking pairs on the 1-chip measured space
TILES = (0, 2, 3, 5)
MAX_DIM = 512
#: smallest timed dim — cells below ~128^3 run in microseconds, where
#: perf_counter jitter swamps real config differences
MIN_DIM = 128


class SkewedBackend:
    """A second architecture, emulated deterministically: the measured
    backend's wall-clock scaled by a per-routine factor (different
    relative BLAS-3 throughput, the paper's Cascade Lake vs Zen 3
    situation) plus a mild per-tile factor (different cache hierarchy
    reordering the blocking knob).  Deterministic so the bench's
    transfer-vs-scratch comparison is about *information*, not luck."""

    ROUTINE_SKEW = {"gemm": 1.9, "syrk": 2.6, "trsm": 1.4, "attn": 2.0}

    def __init__(self, inner: MeasuredCPUBackend) -> None:
        self.inner = inner

    def _factor(self, cfg, routine: str) -> float:
        tile = 1.0 + 0.05 * np.sin(2.3 * cfg.tile_id
                                   + hash(routine) % 7)
        return self.ROUTINE_SKEW[routine] * float(tile)

    def time_routine(self, m, k, n, cfg, *, routine="gemm"):
        return self._factor(cfg, routine) * self.inner.time_routine(
            m, k, n, cfg, routine=routine)


def measured_cfg(n_samples: int, fp, seed: int = 0,
                 **kw) -> InstallConfig:
    base = dict(
        n_samples=n_samples, repeats=1, max_chips=1, tile_ids=TILES,
        space=ConfigSpace.default(1, tiles=TILES, partitions=("M",)),
        routines=ROUTINES3, models=("lightgbm",), cv_splits=2,
        dim_min=MIN_DIM, dim_max=MAX_DIM, mem_limit_mb=16, seed=seed,
        fingerprint=fp)
    base.update(kw)
    return InstallConfig(**base)


def _truth_matrix(truth: SkewedBackend, eval_dims: np.ndarray,
                  names: list[str], cfgs: list) -> np.ndarray:
    """Hardened wall-clock measurements on the target backend; measured
    ONCE and shared across every compared artifact so regret deltas
    reflect the artifacts' choices, not truth re-measurement noise."""
    t = np.empty((len(eval_dims), len(cfgs)))
    for i, (m, k, n) in enumerate(eval_dims):
        for j, c in enumerate(cfgs):
            t[i, j] = truth.time_routine(int(m), int(k), int(n), c,
                                         routine=names[i])
    return t


def _regret(artifact: str, truth_t: np.ndarray, eval_dims: np.ndarray,
            names: list[str], cfgs: list) -> float:
    """Mean oracle regret of the artifact's tuner on the shared truth."""
    tuner = AdsalaTuner.from_artifact(artifact)
    col = [cfgs.index(c) for c in tuner.candidates]
    pred = tuner.predicted_times_many([tuple(d) for d in eval_dims],
                                      routines=names)
    chosen_j = np.asarray(col)[np.argmin(pred, axis=1)]
    chosen = truth_t[np.arange(len(eval_dims)), chosen_j]
    return float(np.mean(chosen / np.maximum(truth_t.min(axis=1), 1e-12)
                         - 1.0))


def run(smoke: bool = False) -> list[str]:
    lines: list[str] = []
    n_samples = 64 if smoke else 96
    n_eval = 30 if smoke else 48

    # 0. fingerprint this host; smoke asserts the JSON round-trip
    fp_real = HardwareFingerprint.collect(probe_sizes=(64, 128),
                                          probe_repeats=3)
    fp_back = HardwareFingerprint.from_dict(
        json.loads(json.dumps(fp_real.to_dict())))
    lines.append(f"registry_fingerprint_probe,"
                 f"{np.mean(fp_real.probe_gflops) * 1e3:.0f},"
                 f"mgflops_mean;key={fp_real.key()}")
    if smoke:
        assert fp_back == fp_real and fp_back.key() == fp_real.key(), (
            "fingerprint JSON round-trip is lossy")

    # 1. first measured mixed-routine install, into a registry cell
    root = tempfile.mkdtemp(prefix="bench_registry_")
    reg = ArtifactRegistry(root)
    real = MeasuredCPUBackend(max_dim=MAX_DIM, seed=0, repeats=5,
                              warmup=1)
    cfg = measured_cfg(n_samples, fp_real)
    rep_real = reg.install(fp_real, real, cfg)
    sel_real = next(r for r in rep_real.reports
                    if r.name == rep_real.selected)
    lines.append(f"registry_measured_nrmse,"
                 f"{sel_real.normalised_rmse * 1e6:.0f},x1e-6")
    for routine, s in sel_real.per_routine.items():
        lines.append(f"registry_measured_ideal_{routine},"
                     f"{s['ideal_mean_speedup'] * 1e3:.0f},"
                     f"speedup_x1e3;n={int(s['n_test'])}")

    # 2. sim-to-real per-routine gap: identical install config, v5e
    # analytic backend — how far Tables III/IV drift from measurement
    rep_sim = install(SimulatedBackend(seed=0),
                      dataclasses.replace(cfg, fingerprint=None))
    sel_sim = next(r for r in rep_sim.reports
                   if r.name == rep_sim.selected)
    for routine in ROUTINES3:
        s_real = sel_real.per_routine.get(routine)
        s_sim = sel_sim.per_routine.get(routine)
        if s_real is None or s_sim is None:
            continue
        gap = abs(s_sim["ideal_mean_speedup"]
                  - s_real["ideal_mean_speedup"])
        lines.append(
            f"registry_sim_real_gap_{routine},{gap * 1e3:.0f},"
            f"abs_ideal_mean_x1e3;sim="
            f"{s_sim['ideal_mean_speedup']:.3f};real="
            f"{s_real['ideal_mean_speedup']:.3f}")

    # 3. second arch: transfer vs equal-budget scratch vs full install
    fp_b = HardwareFingerprint(
        cpu_model=fp_real.cpu_model + " (skewed)", cores=fp_real.cores,
        cache_kb=fp_real.cache_kb, mesh_shape=(1,))
    arch_b = SkewedBackend(MeasuredCPUBackend(max_dim=MAX_DIM, seed=1,
                                              repeats=5, warmup=1))
    cal_dims = 6 if smoke else 8
    rep_tr = reg.install(fp_b, arch_b,
                         measured_cfg(n_samples, fp_b, seed=1,
                                      calibration_dims=cal_dims,
                                      calibration_top_k=len(TILES)),
                         transfer_from="nearest")
    tconf = json.load(open(os.path.join(rep_tr.artifact_dir,
                                        "config.json")))
    cal_cells = tconf["transfer"]["calibration_cells"]
    donor_cells = tconf["transfer"]["donor_cells"]
    budget_frac = cal_cells / max(donor_cells, 1)
    lines.append(f"registry_transfer_budget,{cal_cells},"
                 f"cells;donor={donor_cells};"
                 f"fraction={budget_frac:.3f}")

    n_cfgs = len(tconf["candidates"])
    scratch_art = os.path.join(root, "scratch_equal_budget")
    install(arch_b, measured_cfg(max(2, cal_cells // n_cfgs), fp_b,
                                 seed=1),
            artifact_dir=scratch_art)
    full_art = os.path.join(root, "scratch_full_budget")
    install(arch_b, measured_cfg(n_samples, fp_b, seed=1),
            artifact_dir=full_art)

    # ground truth: hardened measurements on arch B (median-of-7)
    truth = SkewedBackend(MeasuredCPUBackend(max_dim=MAX_DIM, seed=2,
                                             repeats=7, warmup=1))
    eval_dims = sample_gemm_dims(
        n_eval, mem_limit_bytes=16 * 2**20, dim_min=MIN_DIM,
        dim_max=MAX_DIM, dtype_bytes=2, seed=321)
    names = [ROUTINES3[i % 3] for i in range(len(eval_dims))]
    cfgs = AdsalaTuner.from_artifact(full_art).candidates
    truth_t = _truth_matrix(truth, eval_dims, names, cfgs)
    r_transfer = _regret(rep_tr.artifact_dir, truth_t, eval_dims,
                         names, cfgs)
    r_scratch = _regret(scratch_art, truth_t, eval_dims, names, cfgs)
    r_full = _regret(full_art, truth_t, eval_dims, names, cfgs)
    lines.append(f"registry_regret_transfer,{r_transfer * 1e6:.0f},"
                 f"regret_x1e6;cal_cells={cal_cells}")
    lines.append(f"registry_regret_scratch_equal,{r_scratch * 1e6:.0f},"
                 f"regret_x1e6;same_budget")
    lines.append(f"registry_regret_scratch_full,{r_full * 1e6:.0f},"
                 f"regret_x1e6;{n_samples}dims")
    lines.append(f"registry_transfer_vs_full,"
                 f"{r_transfer / max(r_full, 1e-9):.2f},x")

    # serve-side resolution: arch B's cell now resolves exactly
    from repro.core import resolve_serving_artifact
    resolved = resolve_serving_artifact(root, fingerprint=fp_b)
    lines.append(f"registry_resolve_exact,{int(resolved.exact)},"
                 f"cell={resolved.cell.key()}")

    if smoke:
        assert budget_frac <= 0.10, (
            f"calibration spent {budget_frac:.1%} of the donor budget "
            "(> 10%)")
        # measured-timing tolerance: 1% absolute regret, and a 3%
        # floor for near-tie grids where both land within noise
        assert r_transfer <= max(r_scratch + 0.01, 0.03), (
            f"transfer regret {r_transfer:.4f} worse than equal-budget "
            f"scratch {r_scratch:.4f}")
        # the floor covers the regret *estimator's* own noise: one
        # flipped near-tie eval dim moves the mean by ~1%, so a
        # near-perfect full install (r ~ 0) would otherwise demand
        # transfer match it within estimator jitter
        assert r_transfer <= 1.5 * max(r_full, 0.03), (
            f"transfer regret {r_transfer:.4f} not within 1.5x of the "
            f"full install's {r_full:.4f}")
        assert resolved.exact and \
            resolved.path == rep_tr.artifact_dir, (
                "registry did not resolve arch B's own cell")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
