"""Shared install-run cache for the paper-table benchmarks.

The ADSALA installation (gather -> preprocess -> tune -> select) is the
expensive part; every benchmark table reads from one shared run per
"platform".  Platforms mirror the paper's two testbeds:

  v5e-sim   — the TPU v5e analytic backend (Setonix-analogue: the
              platform the technique targets)
  cpu-meas  — wall-clock measured blocked GEMMs on this host
              (Gadi-analogue: a second, measured platform)
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import (
    GatheredData,
    InstallConfig,
    MeasuredCPUBackend,
    SimulatedBackend,
    gather_data,
    install,
)

RESULTS = os.environ.get("ADSALA_RESULTS", "results")

_FULL = os.environ.get("ADSALA_BENCH_FULL", "") == "1"

#: install budget — CI-sized by default; ADSALA_BENCH_FULL=1 for the
#: paper-scale run (1763 samples in the paper; 400 here)
N_SAMPLES = 400 if _FULL else 150
N_MODELS = ("linear_regression", "elasticnet", "bayesian_regression",
            "decision_tree", "random_forest", "adaboost", "xgboost",
            "lightgbm")


def install_cfg(mem_limit_mb: int = 500, **kw) -> InstallConfig:
    base = dict(
        n_samples=N_SAMPLES, mem_limit_mb=mem_limit_mb, repeats=3,
        tile_ids=(0, 3), models=N_MODELS, grid_budget="small",
        cv_splits=3, seed=0)
    base.update(kw)
    return InstallConfig(**base)


_CACHE: dict = {}


def simulated_run(mem_limit_mb: int = 500):
    """(backend, cfg, data, report, artifact_dir) for the v5e platform."""
    key = ("sim", mem_limit_mb)
    if key not in _CACHE:
        cfg = install_cfg(mem_limit_mb)
        backend = SimulatedBackend(seed=0)
        art = os.path.join(RESULTS, f"adsala_artifact_{mem_limit_mb}mb")
        data_path = os.path.join(RESULTS,
                                 f"gathered_{mem_limit_mb}mb.npz")
        if os.path.exists(data_path):
            data = GatheredData.load(data_path)
            report = None
            if not os.path.exists(os.path.join(art, "model.json")):
                report = install(backend, cfg, data=data, artifact_dir=art)
        else:
            data = gather_data(backend, cfg)
            os.makedirs(RESULTS, exist_ok=True)
            data.save(data_path)
            report = install(backend, cfg, data=data, artifact_dir=art)
        _CACHE[key] = (backend, cfg, data, report, art)
    return _CACHE[key]


def measured_run():
    """Small measured-CPU platform run (real wall-clock timings)."""
    key = ("meas",)
    if key not in _CACHE:
        # single-core host: candidates restricted to 1 chip, tile sweep
        from repro.core.costmodel import GemmConfig
        cfg = install_cfg(
            mem_limit_mb=100, n_samples=40 if not _FULL else 120,
            repeats=3, max_chips=1,
            tile_ids=(0, 2, 3, 5),
            models=("linear_regression", "bayesian_regression",
                    "decision_tree", "xgboost"),
            default_config=GemmConfig(1, "M", 5),
            dim_max=1024)
        backend = MeasuredCPUBackend(max_dim=1024)
        art = os.path.join(RESULTS, "adsala_artifact_cpu")
        data = gather_data(backend, cfg)
        report = install(backend, cfg, data=data, artifact_dir=art)
        _CACHE[key] = (backend, cfg, data, report, art)
    return _CACHE[key]
