"""Table VII: wall-time decomposition for the paper's two case-study
GEMMs (64x2048x64 and 64x64x4096) — kernel-call (compute), data-copy
(memory) and sync (collective) terms, default vs ADSALA-chosen workers.

The paper's VTune profile showed data copies dominating the 96-thread
runs (163 of 168 s); the TPU analogue is the collective + launch floor
dominating the 512-chip dispatch of a microscopic GEMM.
"""

from __future__ import annotations

from benchmarks.common import simulated_run
from repro.core import AdsalaTuner, estimate_gemm_time


def run() -> list[str]:
    _, icfg, _, _, art = simulated_run(500)
    tuner = AdsalaTuner.from_artifact(art)
    lines = []
    for (m, k, n) in ((64, 2048, 64), (64, 64, 4096)):
        chosen = tuner.select(m, k, n)
        for tag, cfg in (("default", icfg.default_config),
                         ("adsala", chosen)):
            tb = estimate_gemm_time(m, k, n, cfg)
            lines.append(
                f"table7_{m}x{k}x{n}_{tag},{tb.total_s*1e6:.2f},"
                f"chips={cfg.n_chips};kernel_us={tb.compute_s*1e6:.2f};"
                f"copy_us={tb.memory_s*1e6:.2f};"
                f"sync_us={tb.collective_s*1e6:.2f}")
        t_d = estimate_gemm_time(m, k, n, icfg.default_config).total_s
        t_c = estimate_gemm_time(m, k, n, chosen).total_s
        lines.append(f"table7_{m}x{k}x{n}_speedup,{t_d/t_c:.1f},x")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
