"""Fig 9 analogue: optimal worker count vs (m, k, n) — grid CSV."""

from __future__ import annotations

import numpy as np

from benchmarks.common import simulated_run


def run() -> list[str]:
    _, _, data, _, _ = simulated_run(500)
    chips = np.array([c.n_chips for c in data.cfgs])
    opt = chips[data.optimal_worker_index()]
    # bucket by (max_dim, min_dim) octaves — the heatmap's axes
    lines = []
    mx = data.dims.max(axis=1)
    mn = data.dims.min(axis=1)
    for lo, hi, tag in ((0, 1024, "small"), (1024, 8192, "mid"),
                        (8192, 10**9, "large")):
        mask = (mx >= lo) & (mx < hi)
        if mask.sum() >= 3:
            lines.append(
                f"fig9_maxdim_{tag},{float(np.median(opt[mask])):.0f},"
                f"median_chips;n={int(mask.sum())}")
    for lo, hi, tag in ((0, 256, "slim"), (256, 4096, "mid"),
                        (4096, 10**9, "square")):
        mask = (mn >= lo) & (mn < hi)
        if mask.sum() >= 3:
            lines.append(
                f"fig9_mindim_{tag},{float(np.median(opt[mask])):.0f},"
                f"median_chips;n={int(mask.sum())}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
