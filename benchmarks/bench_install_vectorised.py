"""Install-time hot path: scalar-loop vs vectorised timing program.

The paper's premise is that the install-time timing program plus runtime
model evaluation must cost less than the GEMM time they save.  This
suite measures the data-gathering grid (the dominant install cost) both
ways on the same (dims x configs) workload:

  * ``scalar``  — the historical double loop over estimate_gemm_time
  * ``batched`` — one broadcasted estimate_batch_terms pass

and reports the batched tuner dispatch (select_many over a grouped/MoE
shape list) against per-shape scalar selects.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import simulated_run
from repro.core import (
    AdsalaTuner,
    SimulatedBackend,
    candidate_configs,
    estimate_batch_terms,
    estimate_gemm_time,
    time_gemm_grid,
)
from repro.core.halton import sample_gemm_dims


def _bench(fn, reps: int = 3) -> float:
    fn()  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    lines = []

    # --- timing-program grid: 400 dims x 128 configs ----------------------
    dims = sample_gemm_dims(400, mem_limit_bytes=500 * 2**20, seed=0)
    cfgs = candidate_configs(512)[:128]

    def scalar_grid():
        for m, k, n in dims:
            for c in cfgs:
                estimate_gemm_time(int(m), int(k), int(n), c).total_s

    t_scalar = _bench(scalar_grid, reps=1)
    t_batch = _bench(
        lambda: estimate_batch_terms(dims, cfgs).total_s)
    lines.append(f"install_grid_scalar,{t_scalar*1e6:.0f},400x128_cells")
    lines.append(f"install_grid_batched,{t_batch*1e6:.0f},400x128_cells")
    lines.append(
        f"install_grid_speedup,{t_scalar/t_batch:.1f},x_scalar_over_batched")

    # --- full gather_data path (3 repeats, median) ------------------------
    backend = SimulatedBackend(seed=0)
    t_gather = _bench(lambda: time_gemm_grid(backend, dims, cfgs, 3))
    lines.append(f"gather_data_batched,{t_gather*1e6:.0f},3_repeats_median")

    # --- batched tuner dispatch ------------------------------------------
    _, _, _, _, art = simulated_run(500)
    shapes = [(int(m), int(k), int(n)) for m, k, n in dims[:64]]

    tuner = AdsalaTuner.from_artifact(art)
    tuner._cache.clear()
    t_scalar_sel = _bench(
        lambda: [tuner._cache.clear(), [tuner.select(*s) for s in shapes]],
        reps=5)
    tuner._cache.clear()
    t_batch_sel = _bench(
        lambda: [tuner._cache.clear(), tuner.select_many(shapes)],
        reps=5)
    lines.append(f"tuner_select_scalar_64,{t_scalar_sel*1e6:.0f},cold_cache")
    lines.append(f"tuner_select_many_64,{t_batch_sel*1e6:.0f},cold_cache")
    lines.append(
        f"tuner_dispatch_speedup,{t_scalar_sel/t_batch_sel:.1f},"
        "x_scalar_over_batched")

    # --- warm-start: artifact-preloaded cache hits ------------------------
    import json
    import os
    with open(os.path.join(art, "config.json")) as f:
        ws = json.load(f)["warm_start"]
    warm = AdsalaTuner.from_artifact(art)
    n_pre = len(warm._cache)
    probe = [tuple(d) for d in ws["dims"][:32]]
    warm.select_many(probe)
    lines.append(
        f"warm_start_preloaded,{n_pre},cache_entries")
    lines.append(
        f"warm_start_hit_rate,{warm.stats['cache_hits']/len(probe):.2f},"
        "install_sampled_dims")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
