"""Figs 13/14: GFLOPS on predesigned matrices (m=k=n; one small dim;
two small dims), ADSALA-chosen vs default all-chips."""

from __future__ import annotations

import numpy as np

from benchmarks.common import simulated_run
from repro.core import AdsalaTuner


def _gflops(m, k, n, t):
    return 2.0 * m * k * n / max(t, 1e-12) / 1e9


def run() -> list[str]:
    backend, icfg, _, _, art = simulated_run(500)
    tuner = AdsalaTuner.from_artifact(art)
    sweep = [256, 1024, 4096, 16384]
    small = 64
    cases = []
    for s in sweep:
        cases.append(("square", (s, s, s)))
        cases.append(("small_m", (small, s, s)))
        cases.append(("small_k", (s, small, s)))
        cases.append(("small_n", (s, s, small)))
        cases.append(("small_kn", (s, small, small)))
        cases.append(("small_mk", (small, small, s)))
    lines = []
    for tag, (m, k, n) in cases:
        chosen = tuner.select(m, k, n)
        t_c = backend.time_gemm_clean(m, k, n, chosen)
        t_d = backend.time_gemm_clean(m, k, n, icfg.default_config)
        lines.append(
            f"fig1314_{tag}_{m}x{k}x{n},{t_c*1e6:.2f},"
            f"gflops_adsala={_gflops(m,k,n,t_c):.1f};"
            f"gflops_default={_gflops(m,k,n,t_d):.1f};"
            f"speedup={t_d/t_c:.2f};chips={chosen.n_chips}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
