"""Beyond-paper ablation: what each preprocessing stage contributes.

The paper motivates Yeo-Johnson and LOF qualitatively (§II-C/IV-C);
this ablation quantifies them: XGBoost test nRMSE with each stage
removed, same data/split/seed.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import simulated_run
from repro.core.installer import _PARTITIONS
from repro.core.features import build_features
from repro.core.ml import XGBRegressor, rmse, stratified_train_test_split
from repro.core.ml.base import normalised_rmse
from repro.core.preprocessing import (
    PreprocessPipeline,
    StandardScaler,
    YeoJohnson,
)


def _xy(data, seed=0):
    X, y = data.to_rows(per_dim=12, seed=seed)
    return X, y


def run() -> list[str]:
    _, _, data, _, _ = simulated_run(500)
    X, y = _xy(data)
    Xtr, Xte, ytr, yte = stratified_train_test_split(X, y, seed=0)
    lines = []

    def fit_eval(tag, tr, y_tr, te):
        m = XGBRegressor(n_estimators=100, max_depth=5, seed=0)
        m.fit(tr, y_tr)
        lines.append(f"ablation_{tag},"
                     f"{normalised_rmse(yte, m.predict(te)):.4f},nrmse")

    # full pipeline
    pipe = PreprocessPipeline()
    tr, y_tr = pipe.fit_transform(Xtr, ytr)
    fit_eval("full_pipeline", tr, y_tr, pipe.transform(Xte))

    # no Yeo-Johnson (scale only)
    sc = StandardScaler()
    fit_eval("no_yeojohnson", sc.fit_transform(Xtr), ytr,
             sc.transform(Xte))

    # no LOF (YJ + scale, keep all rows)
    yj, sc2 = YeoJohnson(), StandardScaler()
    tr2 = sc2.fit_transform(yj.fit_transform(Xtr))
    fit_eval("no_lof", tr2, ytr, sc2.transform(yj.transform(Xte)))

    # raw features
    fit_eval("raw_features", Xtr, ytr, Xte)

    # group-1-only features (no parallel terms) — Table II ablation
    keep = list(range(9)) + [17, 18]
    pipe2 = PreprocessPipeline()
    tr3, y_tr3 = pipe2.fit_transform(Xtr[:, keep], ytr)
    fit_eval("group1_features_only", tr3, y_tr3,
             pipe2.transform(Xte[:, keep]))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
