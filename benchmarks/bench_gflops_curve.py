"""Figs 11/12: GFLOPS vs GEMM memory occupancy, ADSALA vs default."""

from __future__ import annotations

import numpy as np

from benchmarks.common import simulated_run
from repro.core import AdsalaTuner
from repro.core.halton import gemm_bytes, sample_gemm_dims


def run(n_points: int = 48) -> list[str]:
    backend, icfg, _, _, art = simulated_run(500)
    tuner = AdsalaTuner.from_artifact(art)
    dims = sample_gemm_dims(n_points, mem_limit_bytes=500 * 2**20,
                            seed=777)
    sizes = gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2],
                       icfg.dtype_bytes)
    edges = [0, 20, 100, 250, 500]
    lines = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        mask = (sizes > lo * 2**20) & (sizes <= hi * 2**20)
        if mask.sum() < 3:
            continue
        g_a, g_d = [], []
        for m, k, n in dims[mask]:
            m, k, n = int(m), int(k), int(n)
            flops = 2.0 * m * k * n
            t_c = backend.time_gemm_clean(m, k, n, tuner.select(m, k, n))
            t_d = backend.time_gemm_clean(m, k, n, icfg.default_config)
            g_a.append(flops / t_c / 1e9)
            g_d.append(flops / t_d / 1e9)
        lines.append(
            f"fig1112_{lo}_{hi}mb,{float(np.mean(g_a)):.1f},"
            f"gflops_adsala;default={float(np.mean(g_d)):.1f};"
            f"n={int(mask.sum())}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
