"""Fig 1 / Fig 8: histogram of the optimal worker count.

Paper finding: "thread counts lower than the maximum often provide
better GEMM wall-time".  TPU translation: optimal chip counts across the
sampled GEMM domain, overall (<=100 MB, Fig 1) and for the small-dim
subset (min(m,k,n) < 1000, Fig 8).
"""

from __future__ import annotations

import collections

import numpy as np

from benchmarks.common import simulated_run


def run() -> list[str]:
    _, cfg, data, _, _ = simulated_run(100)
    chips = np.array([c.n_chips for c in data.cfgs])
    opt = chips[data.optimal_worker_index()]
    lines = []
    hist = collections.Counter(opt)
    for c in sorted(hist):
        lines.append(f"fig1_hist_chips_{c},{hist[c]},count")
    frac_below_max = float(np.mean(opt < chips.max()))
    lines.append(f"fig1_frac_optimal_below_max,{frac_below_max:.3f},frac")

    small = data.dims.min(axis=1) < 1000
    if small.any():
        opt_small = opt[small]
        med = float(np.median(opt_small))
        lines.append(f"fig8_small_dim_median_chips,{med},chips")
        lines.append(
            "fig8_small_dim_frac_below_half_max,"
            f"{float(np.mean(opt_small < chips.max() / 2)):.3f},frac")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
