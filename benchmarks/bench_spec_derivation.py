"""Spec-derivation latency: partition_params + batch_specs across the
full 10-arch zoo x both production meshes.

Spec derivation runs on the serving cold-start path (every new
(arch x mesh) cell derives its rule table + param/batch specs before the
first compile), so regressions here stretch time-to-first-token.  Uses
AbstractMesh stand-ins — no devices needed, same code path the real
launchers hit.
"""

from __future__ import annotations

import time

from repro.configs import ARCH_IDS, build_model, get_config
from repro.dist.sharding import abstract_mesh, batch_specs, partition_params
from repro.models.config import SHAPES

MESHES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


def _time(fn, repeats: int = 5) -> float:
    """Best-of-repeats wall time in seconds (cold-start metric: min is
    the least noisy estimator on a busy 2-core box)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    shape = SHAPES["train_4k"]
    total_param_us = 0.0
    total_batch_us = 0.0
    for mesh_name, mesh_shape in MESHES.items():
        mesh = abstract_mesh(mesh_shape)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            model = build_model(cfg)
            t_param = _time(lambda: partition_params(model, cfg, mesh))
            t_batch = _time(lambda: batch_specs(cfg, shape, mesh))
            total_param_us += t_param * 1e6
            total_batch_us += t_batch * 1e6
            yield (f"spec_partition_params_{arch}_{mesh_name},"
                   f"{t_param * 1e6:.0f},us")
            yield (f"spec_batch_specs_{arch}_{mesh_name},"
                   f"{t_batch * 1e6:.0f},us")
    n = len(ARCH_IDS) * len(MESHES)
    yield (f"spec_partition_params_mean,{total_param_us / n:.0f},"
           f"mean_over_{n}_cells")
    yield (f"spec_batch_specs_mean,{total_batch_us / n:.0f},"
           f"mean_over_{n}_cells")


if __name__ == "__main__":
    for line in run():
        print(line)
