"""Roofline table: merge dry-run artifacts with the analytic model.

Prints one row per (arch x shape x mesh) with the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/analytic ratio, and a what-to-fix
note.  Writes results/roofline.json for EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.models.config import SHAPES
from repro.roofline.analytic import roofline_for_cell

_NOTES = {
    ("compute", "train"): "raise per-chip utilisation: larger microbatch "
                          "or less remat",
    ("compute", "prefill"): "attention-dominated: fuse QK/AV (flash "
                            "kernel) and skip out-of-window blocks",
    ("compute", "decode"): "batch more requests per step to amortise "
                           "weight reads",
    ("memory", "train"): "optimizer-state traffic dominates: shard "
                         "further / fuse adam update",
    ("memory", "prefill"): "activation traffic: larger fused blocks, "
                           "keep residuals in VMEM",
    ("memory", "decode"): "weight-read bound (classic decode): quantise "
                          "weights or grow batch",
    ("collective", "train"): "TP all-reduce bound: overlap with compute, "
                             "or shift TP->data parallelism",
    ("collective", "prefill"): "gather/all-reduce bound: sequence "
                               "parallelism or comm/compute overlap",
    ("collective", "decode"): "latency-bound collectives: shrink TP "
                              "degree for decode",
}


def run(dryrun_dir: str = "results/dryrun",
        out_path: str = "results/roofline.json",
        csv: bool = True) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec["status"] != "ok":
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        rt = roofline_for_cell(cfg, shape, rec["mesh"], rec)
        note = _NOTES[(rt.dominant, shape.kind)]
        # recorded per-cell dispatch mix (PR 4): fraction of the tagged
        # contraction volume that is SYRK/TRSM-eligible (absent on
        # dry-run artifacts predating the DispatchRecorder)
        mix = rec.get("dispatch", {}).get("routine_mix", {})
        rows.append({
            "arch": rt.arch, "shape": rt.shape, "mesh": rt.mesh,
            "devices": rt.n_devices,
            "compute_ms": rt.compute_s * 1e3,
            "memory_ms": rt.memory_s * 1e3,
            "collective_ms": rt.collective_s * 1e3,
            "total_ms": rt.total_s * 1e3,
            "dominant": rt.dominant,
            "roofline_fraction": rt.roofline_fraction,
            "model_flops": rt.model_flops,
            "analytic_flops": rt.analytic_flops,
            "useful_ratio": rt.useful_ratio,
            "hlo_flops_per_dev": rt.hlo_flops_per_dev,
            "peak_gib": rt.peak_bytes / 2**30,
            "routine_mix": mix,
            "syrk_frac": mix.get("syrk", 0.0),
            "trsm_frac": mix.get("trsm", 0.0),
            "note": note,
        })
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    if csv:
        print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,"
              "dominant,roofline_fraction,useful_ratio,peak_gib,"
              "syrk_frac,trsm_frac")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['compute_ms']:.3f},{r['memory_ms']:.3f},"
                  f"{r['collective_ms']:.3f},{r['dominant']},"
                  f"{r['roofline_fraction']:.3f},{r['useful_ratio']:.3f},"
                  f"{r['peak_gib']:.2f},"
                  f"{r['syrk_frac']:.3f},{r['trsm_frac']:.3f}")
    return rows


if __name__ == "__main__":
    run()
