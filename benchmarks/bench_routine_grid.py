"""Mixed-routine install grid: batched timing-program cost + per-routine
install speedups (the arXiv 2406.19621 Tables III/IV analogue).

Reports, as ``name,us_per_call,derived`` CSV lines:

  * the batched ``estimate_batch_terms`` pass over a mixed
    {gemm, syrk, trsm} grid vs the scalar ``estimate_routine_time`` loop
    (the BLAS-3 generalisation of bench_install_vectorised);
  * a small mixed-routine ``install()`` on the simulated backend with
    the selected model's per-routine warm/ideal speedups.

``--smoke`` (used by the CI routines job) shrinks the grid and install
budget to seconds.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import (
    InstallConfig,
    ROUTINES,
    SimulatedBackend,
    candidate_configs,
    estimate_batch_terms,
    estimate_routine_time,
    gather_data,
    install,
)
from repro.core.halton import sample_gemm_dims


def _bench(fn, reps: int = 3) -> float:
    fn()  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False) -> list[str]:
    lines = []
    n_dims, n_cfgs = (60, 32) if smoke else (300, 128)

    dims = sample_gemm_dims(n_dims, mem_limit_bytes=500 * 2**20, seed=0)
    cfgs = candidate_configs(512)[:n_cfgs]
    routines = [ROUTINES[i % len(ROUTINES)] for i in range(len(dims))]

    def scalar_grid():
        for (m, k, n), r in zip(dims, routines):
            for c in cfgs:
                estimate_routine_time(int(m), int(k), int(n), c,
                                      routine=r).total_s

    t_scalar = _bench(scalar_grid, reps=1)
    t_batch = _bench(
        lambda: estimate_batch_terms(dims, cfgs,
                                     routines=routines).total_s)
    cells = f"{n_dims}x{n_cfgs}_mixed_cells"
    lines.append(f"routine_grid_scalar,{t_scalar * 1e6:.0f},{cells}")
    lines.append(f"routine_grid_batched,{t_batch * 1e6:.0f},{cells}")
    lines.append(f"routine_grid_speedup,"
                 f"{t_scalar / max(t_batch, 1e-12):.1f},x")

    # parity spot-check so the benchmark can't silently drift from the
    # reference path it claims to accelerate
    bb = estimate_batch_terms(dims[:6], cfgs, routines=routines[:6])
    for i, (m, k, n) in enumerate(dims[:6]):
        want = estimate_routine_time(int(m), int(k), int(n), cfgs[0],
                                     routine=routines[i]).total_s
        assert bb.total_s[i, 0] == want, "batched/scalar drift"

    # --- mixed-routine install with per-routine report --------------------
    icfg = InstallConfig(
        n_samples=24 if smoke else 90,
        repeats=2, tile_ids=(0, 3),
        models=("linear_regression",) if smoke
        else ("linear_regression", "decision_tree", "xgboost"),
        routines=tuple(ROUTINES), grid_budget="small", cv_splits=3,
        seed=0)
    backend = SimulatedBackend(seed=0)
    t0 = time.perf_counter()
    data = gather_data(backend, icfg)
    report = install(backend, icfg, data=data)
    t_install = time.perf_counter() - t0
    lines.append(f"routine_install,{t_install * 1e6:.0f},"
                 f"{icfg.n_samples}dims_3routines")
    sel = next(r for r in report.reports if r.name == report.selected)
    for routine, s in sel.per_routine.items():
        lines.append(
            f"routine_speedup_{routine},{s['warm_est_mean_speedup']:.3f},"
            f"ideal={s['ideal_mean_speedup']:.3f}"
            f"_n={int(s['n_test'])}")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
