"""Dispatch-recorder overhead: is observability cheap enough to leave
compiled into the serving hot path?

Reports, as ``name,us_per_call,derived`` CSV lines:

  * the raw :func:`repro.kernels.recorder.record` cost with no recorder
    active (the permanent no-op tax every tagged call site pays) and
    with one active;
  * an *eager* decode serve step with recorder off vs on — the worst
    case, since eager steps re-run every call site per token;
  * a *jitted* decode step off vs on — the production case, where
    recording happens at trace time only and steady-state cost must be
    identical.

``--smoke`` (used by the CI dispatch job) shrinks repetitions to
seconds and asserts the recorder-off eager step is within noise of the
recorder-on step.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_smoke_config
from repro.kernels import recorder
from repro.kernels.recorder import DispatchRecorder
from repro.train.step import make_ctx


def _best(fn, reps: int) -> float:
    fn()  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False) -> list[str]:
    lines = []
    reps = 3 if smoke else 5
    n_raw = 20_000 if smoke else 200_000

    # --- raw record() path -------------------------------------------
    def raw_inactive():
        for _ in range(n_raw):
            recorder.record("gemm", 64, 64, 64, site="bench")

    def raw_active():
        with DispatchRecorder():
            for _ in range(n_raw):
                recorder.record("gemm", 64, 64, 64, site="bench")

    t_off = _best(raw_inactive, reps) / n_raw
    t_on = _best(raw_active, reps) / n_raw
    lines.append(f"record_noop,{t_off * 1e6:.4f},per_call")
    lines.append(f"record_active,{t_on * 1e6:.4f},per_call")

    # --- serve decode step, eager ------------------------------------
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cap = 32
    dctx = make_ctx(None, "decode", cache_len=cap)
    cache = model.init_cache(2, dctx)
    tok = jnp.zeros((2, 1), jnp.int32)

    def step():
        logits, _ = model.decode_step(params, tok, cache, jnp.int32(4),
                                      dctx)
        logits.block_until_ready()

    def step_recorded():
        with DispatchRecorder():
            step()

    t_step_off = _best(step, reps)
    t_step_on = _best(step_recorded, reps)
    lines.append(f"eager_decode_recorder_off,{t_step_off * 1e6:.0f},wall")
    lines.append(f"eager_decode_recorder_on,{t_step_on * 1e6:.0f},wall")
    ratio = t_step_on / max(t_step_off, 1e-12)
    lines.append(f"eager_decode_overhead,{ratio:.3f},on/off_ratio")
    if smoke:
        # CI rail only: the 2-core container jitters eager steps ~2x
        # under concurrent load, so the full benchmark run just reports
        assert ratio < 2.0, \
            f"recorder-on eager decode {ratio:.2f}x slower"

    # --- serve decode step, jitted (production) ----------------------
    jstep = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos,
                                                           dctx))

    def jit_off():
        logits, _ = jstep(params, tok, cache, jnp.int32(4))
        logits.block_until_ready()

    with DispatchRecorder() as rec:
        jit_off()          # trace happens here: events recorded once
    n_traced = len(rec.events)

    def jit_on():
        with DispatchRecorder():
            jit_off()

    t_j_off = _best(jit_off, reps)
    t_j_on = _best(jit_on, reps)
    lines.append(f"jit_decode_recorder_off,{t_j_off * 1e6:.0f},wall")
    lines.append(f"jit_decode_recorder_on,{t_j_on * 1e6:.0f},"
                 f"wall_trace_events={n_traced}")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
