"""Workload-aware vs uniform installation at equal sample budget.

Builds a decode-serve-like recorded dispatch profile, runs two installs
with identical budget/models/candidates — one over the uniform Halton
grid, one mix-weighted by the profile (ISSUE 5 tentpole) — and measures
predicted-time *regret* of each resulting tuner on the profile's own
shape distribution against the noise-free oracle:

    regret = mean( t_clean(chosen) / t_clean(best) - 1 )

Reports, as ``name,us_per_call,derived`` CSV lines, the two install
wall-clocks, both regrets, and the improvement ratio.  ``--smoke``
(used by the CI workload job) shrinks the budget to seconds and asserts
the weighted install wins, so the headline property is continuously
checked outside the test suite too.
"""

from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import (
    AdsalaTuner,
    InstallConfig,
    SimulatedBackend,
    WorkloadProfile,
    candidate_configs,
    install,
)
from repro.kernels.recorder import DispatchEvent

ROUTINES3 = ("gemm", "syrk", "trsm")


def serve_profile() -> WorkloadProfile:
    """Decode-serve-like mix: skinny projection gemms + per-head syrk
    scores + a trsm-tagged cache update (cf. the PR 4 recorded mixes)."""
    events = [
        DispatchEvent("gemm", 64, 2048, 2048, count=96, site="proj"),
        DispatchEvent("gemm", 64, 2048, 8192, count=32, site="mlp.up"),
        DispatchEvent("gemm", 64, 8192, 2048, count=32, site="mlp.down"),
        DispatchEvent("gemm", 64, 2048, 50257, count=1, site="logits"),
        DispatchEvent("syrk", 512, 64, 512, count=64, site="attn.qk"),
        DispatchEvent("trsm", 64, 64, 2048, count=16, site="cache"),
    ]
    return WorkloadProfile.from_events(
        events, by="flops", source={"kind": "bench", "name": "decode"})


def _regret(artifact: str, eval_dims: np.ndarray, names: list[str],
            clean: np.ndarray, t_best: np.ndarray) -> float:
    tuner = AdsalaTuner.from_artifact(artifact)
    pred = tuner.predicted_times_many([tuple(d) for d in eval_dims],
                                      routines=names)
    chosen = clean[np.arange(len(eval_dims)), np.argmin(pred, axis=1)]
    return float(np.mean(chosen / np.maximum(t_best, 1e-12) - 1.0))


def run(smoke: bool = False) -> list[str]:
    lines: list[str] = []
    prof = serve_profile()
    n_samples = 120 if smoke else 400
    models = ("lightgbm",) if smoke else ("xgboost", "lightgbm")
    backend = SimulatedBackend(seed=0)
    base = dict(n_samples=n_samples, repeats=2, tile_ids=(0, 3),
                routines=ROUTINES3, models=models,
                cv_splits=2 if smoke else 3, seed=0)
    cfg_u = InstallConfig(**base)
    cfg_w = InstallConfig(**base, workload=prof, workload_bias=0.75)

    walls = {}
    arts = {}
    for tag, cfg in (("uniform", cfg_u), ("weighted", cfg_w)):
        arts[tag] = tempfile.mkdtemp(prefix=f"wl_{tag}_")
        t0 = time.perf_counter()
        install(backend, cfg, artifact_dir=arts[tag])
        walls[tag] = time.perf_counter() - t0
        lines.append(f"workload_install_{tag},{walls[tag] * 1e6:.0f},"
                     f"{n_samples}dims_wall")

    # eval set ~ the profile's own shape + routine distribution
    n_eval = 80 if smoke else 200
    eval_dims = prof.sample_dims(
        n_eval, bias=1.0, mem_limit_bytes=cfg_u.mem_limit_bytes,
        dtype_bytes=cfg_u.dtype_bytes, seed=1234)
    quotas = prof.routine_quotas(ROUTINES3, n_eval, floor=0.0)
    names = np.repeat(np.asarray(ROUTINES3, dtype=object),
                      [quotas[r] for r in ROUTINES3])
    names = list(names[np.random.default_rng(7).permutation(len(names))])
    cands = candidate_configs(cfg_u.max_chips, tiles=cfg_u.tile_ids)
    clean = backend.time_routine_clean_batch(eval_dims, cands,
                                             routines=names)
    t_best = clean.min(axis=1)

    r_u = _regret(arts["uniform"], eval_dims, names, clean, t_best)
    r_w = _regret(arts["weighted"], eval_dims, names, clean, t_best)
    lines.append(f"workload_regret_uniform,{r_u * 1e6:.0f},"
                 f"regret_x1e6_on_profile")
    lines.append(f"workload_regret_weighted,{r_w * 1e6:.0f},"
                 f"regret_x1e6_on_profile")
    lines.append(f"workload_regret_improvement,"
                 f"{r_u / max(r_w, 1e-9):.2f},x")
    if smoke:
        assert r_w < r_u, (
            f"mix-weighted install regret {r_w:.4f} not below uniform "
            f"{r_u:.4f} on the profile it was weighted by")
    return lines


def main() -> None:
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)


if __name__ == "__main__":
    main()
