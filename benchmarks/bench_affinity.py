"""Fig 7 analogue: partition-axis comparison (the paper compared
core-based vs thread-based OpenMP affinity; the TPU analogue is which
GEMM dimension the submesh shards — M / N / K / 2D placement)."""

from __future__ import annotations

import numpy as np

from repro.core import GemmConfig, estimate_gemm_time
from repro.core.halton import sample_gemm_dims


def run() -> list[str]:
    dims = sample_gemm_dims(40, mem_limit_bytes=500 * 2**20, seed=99)
    lines = []
    for chips in (4, 16, 64, 256):
        for part in ("M", "N", "K", "2D"):
            ts = [estimate_gemm_time(int(m), int(k), int(n),
                                     GemmConfig(chips, part, 3)).total_s
                  for m, k, n in dims]
            lines.append(
                f"fig7_partition_{part}_{chips}chips,"
                f"{float(np.mean(ts))*1e6:.2f},mean_us")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
