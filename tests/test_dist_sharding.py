"""Unit tests for the repro.dist sharding subsystem itself: divisibility
demotion, tuple-axis specs, state-spec mirroring, spec validity."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, build_model, get_config
from repro.dist.sharding import (
    abstract_mesh,
    auto_spec,
    batch_specs,
    data_axes,
    divisible_axes,
    is_partition_spec,
    logical_axis_dims,
    param_rules,
    partition_params,
    state_specs,
)
from repro.models.config import SHAPES
from repro.models.params import ParamDef

SINGLE = abstract_mesh({"data": 16, "model": 16})
MULTI = abstract_mesh({"pod": 2, "data": 16, "model": 16})


# ---------------------------------------------------------------------------
# divisibility demotion
# ---------------------------------------------------------------------------

def test_divisible_axes_demotes_outermost_first():
    # 48 % (pod*data = 32) != 0 but 48 % 16 == 0 -> demote to "data"
    assert divisible_axes(48, ("pod", "data"), MULTI) == "data"
    # 24 divides neither 32 nor 16 -> None
    assert divisible_axes(24, ("pod", "data"), MULTI) is None
    # full tuple survives when it divides
    assert divisible_axes(64, ("pod", "data"), MULTI) == ("pod", "data")
    # single-axis candidates demote straight to None
    assert divisible_axes(51865, ("model",), SINGLE) is None
    # every dim carrying the axis must divide, not just one
    assert divisible_axes({64, 24}, ("pod", "data"), MULTI) is None


def test_param_rules_demote_per_arch():
    # mixtral: 8 experts on a 16-way data axis -> replicated
    rules = param_rules(get_config("mixtral-8x22b"), SINGLE)
    assert rules["experts"] is None
    assert rules["expert_ff"] == "model"
    # deepseek: 160 experts divide pod*data=32 -> tuple-axis rule
    rules = param_rules(get_config("deepseek-v2-236b"), MULTI)
    assert rules["experts"] == ("pod", "data")
    # whisper's 51865 vocab divides nothing -> replicated
    rules = param_rules(get_config("whisper-tiny"), SINGLE)
    assert rules["vocab"] is None


def test_param_rules_on_tiny_mesh_adapt():
    """The same arch demotes differently on a small host mesh."""
    mesh = abstract_mesh({"data": 2, "model": 4})
    rules = param_rules(get_config("mixtral-8x22b"), mesh)
    assert rules["experts"] == "data"          # 8 % 2 == 0
    assert rules["heads"] == "model"


# ---------------------------------------------------------------------------
# spec validity across the zoo (no duplicate mesh axes, all entries real)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_have_no_duplicate_mesh_axes(arch, mesh):
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = partition_params(model, cfg, mesh)
    for spec in jax.tree.leaves(specs, is_leaf=is_partition_spec):
        flat = []
        for entry in tuple(spec):
            if entry is None:
                continue
            flat.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(flat) == len(set(flat)), f"{arch}: duplicate in {spec}"
        assert all(a in mesh.axis_names for a in flat), spec


# ---------------------------------------------------------------------------
# auto_spec
# ---------------------------------------------------------------------------

def test_auto_spec_batch_demotes_on_pod_mesh():
    # batch 16 does not divide pod*data=32 but divides data=16
    s = auto_spec((16, 4096, 8, 128), MULTI, batch_dim=0)
    assert tuple(s)[0] == "data"
    # batch 64 keeps the full tuple
    s = auto_spec((64, 4096, 8, 128), MULTI, batch_dim=0)
    assert tuple(s)[0] == ("pod", "data")


def test_auto_spec_model_axis_prefers_largest_divisible():
    s = auto_spec((128, 1000, 512, 256), SINGLE, batch_dim=0)
    # 1000 % 16 != 0; 512 is the largest divisible remaining dim
    assert tuple(s) == ("data", None, "model", None)


def test_auto_spec_without_model_axis():
    mesh = abstract_mesh({"data": 8})
    s = auto_spec((64, 4096), mesh, batch_dim=0)
    assert tuple(s) == ("data", None)


# ---------------------------------------------------------------------------
# batch_specs / state_specs
# ---------------------------------------------------------------------------

def test_batch_specs_match_batch_sds_keys():
    from repro.train.step import train_batch_sds
    from repro.serve.step import prefill_batch_sds

    cfg = get_config("whisper-tiny")
    train = batch_specs(cfg, SHAPES["train_4k"], MULTI)
    sds = train_batch_sds(cfg, SHAPES["train_4k"])
    assert set(train) == set(sds)
    assert tuple(train["tokens"]) == (("pod", "data"), None)  # 256 % 32 == 0
    prefill = batch_specs(cfg, SHAPES["prefill_32k"], SINGLE)
    assert set(prefill) == set(prefill_batch_sds(cfg, SHAPES["prefill_32k"]))
    assert "labels" not in prefill


def test_batch_specs_single_sequence_replicates():
    cfg = get_config("xlstm-125m")
    specs = batch_specs(cfg, SHAPES["long_500k"], SINGLE)  # batch = 1
    assert tuple(specs["tokens"]) == (None, None)


def test_state_specs_mirror_param_specs_for_both_moments():
    cfg = get_config("granite-8b")
    model = build_model(cfg)
    p_specs = partition_params(model, cfg, SINGLE)
    s = state_specs(p_specs)
    p_leaves = jax.tree.leaves(p_specs, is_leaf=is_partition_spec)
    for key in ("m", "v"):
        moment = jax.tree.leaves(s[key], is_leaf=is_partition_spec)
        assert len(moment) == len(p_leaves)
        assert all(a == b for a, b in zip(moment, p_leaves))
    assert s["step"] == P()
    assert "ef" not in s
    assert "ef" in state_specs(p_specs, compress=True)


def test_state_specs_match_init_state_layout():
    """Specs and the real optimizer state must have identical tree keys."""
    from repro.train.optim import AdamWConfig, init_state

    params = {"w": np.zeros((4, 4), np.float32)}
    state = init_state(params, AdamWConfig(compress=True))
    specs = state_specs({"w": P(None, None)}, compress=True)
    assert set(state) == set(specs)


# ---------------------------------------------------------------------------
# logical_axis_dims
# ---------------------------------------------------------------------------

def test_logical_axis_dims_collects_every_tagged_dim():
    defs = {"a": ParamDef((8, 16), ("ff", "heads")),
            "b": ParamDef((24,), ("ff",)),
            "c": ParamDef((5,), (None,))}
    dims = logical_axis_dims(defs)
    assert dims == {"ff": {8, 24}, "heads": {16}}


def test_data_axes_excludes_model():
    assert data_axes(MULTI) == ("pod", "data")
    assert data_axes(SINGLE) == ("data",)
