"""Optimizer + data-pipeline behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.train.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    compress_int8,
    compressed_grads,
    cosine_lr,
    decompress_int8,
    init_state,
)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    state = init_state({"w": jnp.zeros(3)}, cfg)
    for _ in range(150):
        grads = {"w": 2 * (state["params"]["w"] - target)}
        state, _ = adamw_update(state, grads, cfg)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(target), atol=0.05)


def test_clip_by_global_norm():
    grads = {"a": jnp.full(4, 10.0), "b": jnp.full(9, 10.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    new_norm = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(clipped)))
    assert float(gn) > 1.0
    np.testing.assert_allclose(float(new_norm), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(cosine_lr(cfg, jnp.int32(10))), 1.0)
    assert float(cosine_lr(cfg, jnp.int32(100))) < 1e-6


def test_int8_compression_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3)
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    """EF guarantees the *running sum* of quantised grads tracks the
    running sum of true grads (residual never lost)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.standard_normal(50) * 0.01)
              for _ in range(30)]
    ef = {"g": jnp.zeros(50)}
    total_sent = jnp.zeros(50)
    for g in g_true:
        sent, new_ef = compressed_grads({"g": g}, ef)
        total_sent = total_sent + sent["g"]
        ef = new_ef
    total_true = sum(g_true)
    resid = np.abs(np.asarray(total_true - total_sent))
    # residual bounded by one quantisation step, not growing with T
    assert resid.max() < 0.01


def test_compressed_training_still_converges():
    cfg = AdamWConfig(lr=0.05, warmup_steps=2, total_steps=300,
                      weight_decay=0.0, compress=True)
    target = jnp.asarray([0.5, -1.5])
    state = init_state({"w": jnp.zeros(2)}, cfg)
    assert "ef" in state
    for _ in range(250):
        grads = {"w": 2 * (state["params"]["w"] - target)}
        state, _ = adamw_update(state, grads, cfg)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(target), atol=0.1)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_data_deterministic_per_step():
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=3)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_labels_are_shifted_tokens():
    src = SyntheticLM(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_prefetcher_yields_in_order():
    src = SyntheticLM(vocab=10, seq_len=4, global_batch=2, seed=1)
    it = iter(src)
    pf = Prefetcher((next(it) for _ in range(5)), depth=2)
    batches = list(pf)
    assert len(batches) == 5
    ref = [src.batch_at(i) for i in range(5)]
    for got, want in zip(batches, ref):
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
