"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    flash_attention_pallas,
    flash_attention_ref,
    grouped_matmul_pallas,
    grouped_matmul_ref,
    matmul_pallas,
    matmul_ref,
)

_RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    return jnp.asarray(_RNG.standard_normal(shape), dtype=dtype)


_MATMUL_CASES = [
    # (m, k, n, bm, bk, bn)
    (64, 64, 64, 64, 64, 64),
    (128, 256, 128, 64, 128, 64),
    (100, 130, 70, 32, 64, 32),          # ragged, padded grid
    (8, 8, 8, 32, 32, 32),               # tile > dims
    (256, 64, 512, 128, 64, 128),
    (33, 257, 65, 16, 128, 16),
]


@pytest.mark.parametrize("m,k,n,bm,bk,bn", _MATMUL_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_oracle(m, k, n, bm, bk, bn, dtype):
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    out = matmul_pallas(a, b, bm=bm, bk=bk, bn=bn, interpret=True)
    ref = matmul_ref(a, b)
    tol = 5e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 96), k=st.integers(8, 96), n=st.integers(8, 96))
def test_matmul_property_random_shapes(m, k, n):
    a, b = _arr((m, k), jnp.float32), _arr((k, n), jnp.float32)
    out = matmul_pallas(a, b, bm=32, bk=32, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,k", [(64, 32), (100, 130), (33, 65), (8, 8)])
@pytest.mark.parametrize("lower", [True, False])
def test_syrk_matches_oracle(m, k, lower):
    from repro.kernels import syrk, syrk_ref
    a = _arr((m, k), jnp.float32)
    out = syrk(a, lower=lower, backend="pallas", interpret=True,
               tile=(32, 32, 32))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(syrk_ref(a, lower=lower)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,n,lower", [(64, 48, True), (100, 32, True),
                                       (64, 48, False), (33, 17, False),
                                       (16, 8, True)])
def test_trsm_matches_oracle(m, n, lower):
    from repro.kernels import trsm, trsm_ref
    ell = np.tril(_RNG.standard_normal((m, m))).astype(np.float32)
    np.fill_diagonal(ell, np.abs(np.diag(ell)) + m)   # well conditioned
    a = jnp.asarray(ell if lower else ell.T)
    b = _arr((m, n), jnp.float32)
    out = trsm(a, b, lower=lower, backend="pallas", interpret=True,
               tile=(32, 32, 32))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(trsm_ref(a, b, lower=lower)),
                               atol=1e-3, rtol=1e-3)


def test_syrk_trsm_reject_bad_shapes():
    from repro.kernels import syrk, trsm
    with pytest.raises(ValueError, match="SYRK"):
        syrk(_arr((2, 4, 4), jnp.float32), backend="xla")
    with pytest.raises(ValueError, match="TRSM"):
        trsm(_arr((4, 5), jnp.float32), _arr((4, 3), jnp.float32),
             backend="xla")
    with pytest.raises(ValueError, match="TRSM"):
        trsm(_arr((4, 4), jnp.float32), _arr((5, 3), jnp.float32),
             backend="xla")


@pytest.mark.parametrize("e,c,d,f", [(4, 64, 32, 48), (2, 100, 64, 64),
                                     (8, 16, 16, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_matches_oracle(e, c, d, f, dtype):
    x, w = _arr((e, c, d), dtype), _arr((e, d, f), dtype)
    out = grouped_matmul_pallas(x, w, bm=32, bk=32, bn=32, interpret=True)
    ref = grouped_matmul_ref(x, w)
    tol = 5e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("seq,bq,bkv", [(128, 32, 32), (96, 32, 64),
                                        (64, 64, 64)])
@pytest.mark.parametrize("window", [None, 48])
def test_flash_attention_matches_oracle(seq, bq, bkv, window):
    q = _arr((3, seq, 64), jnp.float32)
    k = _arr((3, seq, 64), jnp.float32)
    v = _arr((3, seq, 64), jnp.float32)
    out = flash_attention_pallas(q, k, v, bq=bq, bkv=bkv, causal=True,
                                 window=window, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q = _arr((2, 64, 32), jnp.bfloat16)
    out = flash_attention_pallas(q, q, q, bq=32, bkv=32, interpret=True)
    ref = flash_attention_ref(q, q, q)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_flash_attention_rejects_bad_shapes():
    q = _arr((2, 64, 32), jnp.float32)
    k = _arr((3, 64, 32), jnp.float32)
    with pytest.raises(ValueError):
        flash_attention_pallas(q, k, k, interpret=True)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_pallas(_arr((4, 8), jnp.float32), _arr((9, 4), jnp.float32),
                      interpret=True)


# ---------------------------------------------------------------------------
# dispatch layer (repro.kernels.ops)
# ---------------------------------------------------------------------------

def test_resolve_backend_validates_names():
    from repro.kernels import resolve_backend
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("auto") in ("pallas", "xla")
    for bad in ("palas", "PALLAS", "cuda", ""):
        with pytest.raises(ValueError, match="unknown backend"):
            resolve_backend(bad)


def test_ops_reject_unknown_backend():
    from repro.kernels import matmul
    a = _arr((16, 16), jnp.float32)
    with pytest.raises(ValueError, match="unknown backend"):
        matmul(a, a, backend="palas")


def _stub_tuner():
    from repro.core import AdsalaTuner, candidate_configs

    class _Model:
        def predict(self, X):
            return np.log(1e-6 * (X[:, 3] + 1e-3 * X[:, 0]))

    class _Pipe:
        def transform(self, X):
            return X

    return AdsalaTuner(_Model(), _Pipe(), candidate_configs(8, tiles=(0,)))


def test_grouped_matmul_single_batched_tuner_lookup():
    """All experts resolve through ONE select_many evaluation."""
    from repro.kernels import grouped_matmul, grouped_matmul_ref
    tuner = _stub_tuner()
    x, w = _arr((4, 32, 16), jnp.float32), _arr((4, 16, 24), jnp.float32)
    out = grouped_matmul(x, w, tuner=tuner, backend="pallas",
                         interpret=True)
    assert tuner.stats["calls"] == 4          # one per expert shape...
    assert tuner.stats["evaluations"] == 1    # ...but a single evaluation
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(grouped_matmul_ref(x, w)),
                               atol=1e-4, rtol=1e-4)


def test_grouped_matmul_group_sizes_refine_shapes():
    from repro.kernels import grouped_matmul
    tuner = _stub_tuner()
    x, w = _arr((3, 32, 16), jnp.float32), _arr((3, 16, 24), jnp.float32)
    grouped_matmul(x, w, tuner=tuner, group_sizes=[32, 8, 1],
                   backend="pallas", interpret=True)
    assert tuner.stats["calls"] == 3
    assert tuner.stats["evaluations"] == 3    # three distinct shapes
    assert ("gemm", 32, 16, 24) in tuner._cache


def test_grouped_matmul_validates_group_sizes():
    from repro.kernels import grouped_matmul
    x, w = _arr((3, 32, 16), jnp.float32), _arr((3, 16, 24), jnp.float32)
    with pytest.raises(ValueError, match="entries for"):
        grouped_matmul(x, w, group_sizes=[32, 8], backend="xla")
    with pytest.raises(ValueError, match="outside"):
        grouped_matmul(x, w, group_sizes=[32, 8, -1], backend="xla")
    with pytest.raises(ValueError, match="outside"):
        grouped_matmul(x, w, group_sizes=[32, 8, 33], backend="xla")


def test_syrk_trsm_routine_tuner_dispatch():
    """syrk/trsm consult the tuner under their own routine key — the
    same dims as a gemm call never alias its cache entry."""
    from repro.kernels import dispatch_hint, syrk, trsm
    tuner = _stub_tuner()
    a = _arr((32, 16), jnp.float32)
    syrk(a, tuner=tuner, backend="pallas", interpret=True)
    assert ("syrk", 32, 16, 32) in tuner._cache
    ell = jnp.asarray(np.tril(np.ones((32, 32), np.float32)) +
                      31 * np.eye(32, dtype=np.float32))
    trsm(ell, _arr((32, 8), jnp.float32), tuner=tuner, backend="pallas",
         interpret=True)
    assert ("trsm", 32, 32, 8) in tuner._cache
    hint = dispatch_hint(32, 16, 32, tuner, routine="syrk")
    assert hint == tuner._cache[("syrk", 32, 16, 32)][0]
    assert tuner.stats["evaluations"] == 2   # hint was a cache hit


def test_grouped_dispatch_hint_uses_select_many():
    from repro.kernels import grouped_dispatch_hint
    tuner = _stub_tuner()
    hints = grouped_dispatch_hint([(64, 32, 32)] * 5, tuner)
    assert len(hints) == 5 and len(set(hints)) == 1
    assert tuner.stats["evaluations"] == 1
    assert grouped_dispatch_hint([(64, 32, 32)], None) is None


def test_grouped_dispatch_hint_rejects_prefix_coverage():
    """A shape list covering only a prefix of the experts must raise, not
    silently leave the tail unhinted."""
    from repro.kernels import grouped_dispatch_hint
    tuner = _stub_tuner()
    with pytest.raises(ValueError, match="every expert needs a shape"):
        grouped_dispatch_hint([(64, 32, 32)] * 3, tuner, n_experts=8)
    # also guards the no-tuner path (validation before dispatch)
    with pytest.raises(ValueError, match="every expert needs a shape"):
        grouped_dispatch_hint([(64, 32, 32)] * 3, None, n_experts=8)
    assert grouped_dispatch_hint([(64, 32, 32)] * 3, None,
                                 n_experts=3) is None


def test_grouped_matmul_accepts_array_group_sizes():
    from repro.kernels import grouped_matmul, grouped_matmul_ref
    tuner = _stub_tuner()
    x, w = _arr((3, 32, 16), jnp.float32), _arr((3, 16, 24), jnp.float32)
    out = grouped_matmul(x, w, tuner=tuner,
                         group_sizes=np.array([32, 8, 1]),
                         backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(grouped_matmul_ref(x, w)),
                               atol=1e-4, rtol=1e-4)


def test_resolve_backend_env_override(monkeypatch):
    from repro.kernels.ops import resolve_backend
    monkeypatch.setenv("ADSALA_BACKEND", "xla")
    assert resolve_backend("auto") == "xla"
    monkeypatch.setenv("ADSALA_BACKEND", "pallas")
    assert resolve_backend("auto") == "pallas"
    # explicit argument wins over the environment
    assert resolve_backend("xla") == "xla"
    monkeypatch.setenv("ADSALA_BACKEND", "mosaic")
    with pytest.raises(ValueError, match="ADSALA_BACKEND"):
        resolve_backend("auto")
