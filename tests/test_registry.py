"""Per-architecture artifact registry (ISSUE 10).

Covers the fingerprint (JSON round-trip, cross-process key stability,
nearest-neighbour ordering), the per-cell PR-8 lifecycle
(commit / rollback / crash-window repair inside a namespaced root),
artifact provenance (fingerprint + backend blocks, legacy artifacts,
warn-once mismatch), transfer installs (regret no worse than a scratch
install at equal calibration budget), the hardened MeasuredCPUBackend
(median-of-k variance reduction) and the registry-backed
ReinstallManager.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core.costmodel import GemmConfig, TPUSpec
from repro.core.halton import sample_gemm_dims
from repro.core.installer import (
    ARTIFACT_COMMIT,
    InstallConfig,
    artifact_prev_dir,
    artifact_tmp_dir,
    install,
    is_artifact,
    load_artifact,
    transfer_gather,
)
from repro.core.registry import (
    FINGERPRINT_FILE,
    ArtifactRegistry,
    HardwareFingerprint,
    resolve_serving_artifact,
)
from repro.core.timing import (
    MeasuredCPUBackend,
    SimulatedBackend,
    backend_from_dict,
    describe_backend,
)
from repro.core.tuner import AdsalaTuner


def _fp(model: str = "Test CPU", cores: int = 8,
        mesh: tuple = (1,), gflops: tuple = ()) -> HardwareFingerprint:
    sizes = tuple(64 for _ in gflops)
    return HardwareFingerprint(cpu_model=model, cores=cores,
                               cache_kb=(32, 1024, 32768),
                               mesh_shape=mesh, probe_sizes=sizes,
                               probe_gflops=gflops)


def _tiny_cfg(**kw) -> InstallConfig:
    base = dict(n_samples=24, repeats=1, max_chips=1,
                tile_ids=(0, 1, 3, 5), models=("lightgbm",),
                routines=("gemm", "syrk"), cv_splits=2,
                dim_max=2048, grid_budget="small", seed=0)
    base.update(kw)
    return InstallConfig(**base)


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

class TestFingerprint:
    def test_collect_and_json_roundtrip(self, tmp_path):
        fp = HardwareFingerprint.collect(mesh_shape=(2, 4),
                                         probe_sizes=(64,),
                                         probe_repeats=1)
        assert fp.cores >= 1 and fp.cpu_model
        assert fp.mesh_shape == (2, 4)
        assert len(fp.probe_gflops) == 1 and fp.probe_gflops[0] > 0
        # dict -> json -> dict -> object is lossless
        back = HardwareFingerprint.from_dict(
            json.loads(json.dumps(fp.to_dict())))
        assert back == fp
        assert back.key() == fp.key()
        # file round-trip too
        p = tmp_path / "fp.json"
        fp.save(str(p))
        assert HardwareFingerprint.load(str(p)) == fp

    def test_key_ignores_probe_jitter(self):
        a = _fp(gflops=(50.0,))
        b = _fp(gflops=(57.5,))          # same box, different turbo
        assert a.key() == b.key()
        assert a.distance(b) > 0.0        # but the probe still separates

    def test_key_stable_across_processes(self):
        fp = HardwareFingerprint.collect(probe_sizes=())
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env = dict(os.environ, PYTHONPATH=src)
        out = subprocess.run(
            [sys.executable, "-c",
             "from repro.core.registry import HardwareFingerprint;"
             "print(HardwareFingerprint.collect(probe_sizes=()).key())"],
            env=env, capture_output=True, text=True, check=True,
            timeout=120)
        assert out.stdout.strip() == fp.key()

    def test_distance_orders_architectures(self):
        me = _fp("Zen 3", 16, gflops=(100.0,))
        same_sku = _fp("Zen 3", 16, gflops=(95.0,))
        fewer_cores = _fp("Zen 3", 8, gflops=(60.0,))
        other_arch = _fp("Cascade Lake", 16, gflops=(100.0,))
        other_mesh = _fp("Zen 3", 16, mesh=(2, 2), gflops=(100.0,))
        assert me.distance(me) == 0.0
        d = [me.distance(x) for x in
             (same_sku, fewer_cores, other_arch)]
        assert d[0] < d[1] < d[2]
        assert me.distance(other_mesh) > me.distance(same_sku)
        # symmetric
        assert me.distance(other_arch) == pytest.approx(
            other_arch.distance(me))

    def test_mismatched_probe_sizes_still_comparable(self):
        a = _fp(gflops=(50.0,))
        b = dataclasses.replace(_fp(), probe_sizes=(128,),
                                probe_gflops=(80.0,))
        assert a.distance(b) == 0.0       # no common size: stable only


# ---------------------------------------------------------------------------
# registry addressing + per-cell lifecycle
# ---------------------------------------------------------------------------

class TestRegistryCells:
    def test_register_resolve_nearest(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        a = _fp("Arch A", 8, gflops=(50.0,))
        b = _fp("Arch B", 16, gflops=(80.0,))
        c = _fp("Arch A", 4, gflops=(30.0,))
        assert reg.resolve(a) is None            # cold cell
        assert reg.nearest(a) is None            # empty registry
        install(SimulatedBackend(seed=0), _tiny_cfg(fingerprint=a),
                artifact_dir=reg.register(a))
        install(SimulatedBackend(seed=1), _tiny_cfg(fingerprint=b),
                artifact_dir=reg.register(b))
        assert reg.resolve(a) == reg.artifact_dir(a)
        assert {fp.key() for fp in reg.fingerprints()} == \
            {a.key(), b.key()}
        # c shares a's cpu model: a's cell must win over b's
        cell, art = reg.nearest(c)
        assert cell.key() == a.key() and art == reg.artifact_dir(a)
        # a's own nearest excludes itself
        cell, _ = reg.nearest(a)
        assert cell.key() == b.key()
        # registering c (without installing) adds a cell but nearest
        # only returns populated ones
        reg.register(c)
        cell, _ = reg.nearest(c)
        assert cell.key() == a.key()

    def test_unreadable_sidecar_warns_and_skips(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        reg.register(_fp("A"))
        bad = tmp_path / "reg" / "bad-cell"
        bad.mkdir()
        (bad / FINGERPRINT_FILE).write_text("{not json")
        with pytest.warns(UserWarning, match="unreadable"):
            fps = reg.fingerprints()
        assert len(fps) == 1

    def test_install_commit_and_rollback_in_cell(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        fp = _fp("Arch A")
        r1 = reg.install(fp, SimulatedBackend(seed=0), _tiny_cfg(seed=0))
        art = reg.artifact_dir(fp)
        assert r1.artifact_dir == art and is_artifact(art)
        assert json.load(open(os.path.join(
            art, "config.json")))["install"]["seed"] == 0
        # second install displaces the first into .prev
        reg.install(fp, SimulatedBackend(seed=1), _tiny_cfg(seed=1))
        assert json.load(open(os.path.join(
            art, "config.json")))["install"]["seed"] == 1
        assert is_artifact(artifact_prev_dir(art))
        # rollback restores the first, byte-for-byte
        reg.rollback(fp)
        assert json.load(open(os.path.join(
            art, "config.json")))["install"]["seed"] == 0

    def test_crash_window_repair_in_cell(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        fp = _fp("Arch A")
        reg.install(fp, SimulatedBackend(seed=0), _tiny_cfg())
        art = reg.artifact_dir(fp)
        # a killed install's uncommitted tmp: swept, live survives
        tmp = artifact_tmp_dir(art)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "config.json"), "w") as f:
            f.write("{}")                 # half-written, no COMMIT
        assert reg.resolve(fp) == art
        assert not os.path.isdir(tmp)
        # mid-commit crash: live renamed to .prev, new never promoted
        os.replace(art, artifact_prev_dir(art))
        assert reg.resolve(fp) == art     # repaired from .prev
        assert is_artifact(art)

    def test_adopt_copies_donor_atomically(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        donor_fp, cold_fp = _fp("Arch A"), _fp("Arch B")
        reg.install(donor_fp, SimulatedBackend(seed=0), _tiny_cfg())
        art = reg.adopt(cold_fp, reg.artifact_dir(donor_fp))
        assert art == reg.artifact_dir(cold_fp) and is_artifact(art)
        # the donor keeps its own artifact
        assert is_artifact(reg.artifact_dir(donor_fp))
        with pytest.raises(FileNotFoundError):
            reg.adopt(cold_fp, str(tmp_path / "nowhere"))

    def test_resolve_serving_artifact_fallback(self, tmp_path):
        root = str(tmp_path / "reg")
        reg = ArtifactRegistry(root)
        a = _fp("Arch A", 8)
        reg.install(a, SimulatedBackend(seed=0), _tiny_cfg())
        # exact hit: own cell, no warning
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            r = resolve_serving_artifact(root, fingerprint=a)
        assert r.exact and r.path == reg.artifact_dir(a)
        # cold node: nearest neighbour with a warning
        b = _fp("Arch B", 16)
        with pytest.warns(UserWarning, match="nearest cell"):
            r = resolve_serving_artifact(root, fingerprint=b)
        assert not r.exact and r.cell.key() == a.key()
        assert r.path == reg.artifact_dir(a)
        # fallback disabled: nothing resolves
        r = resolve_serving_artifact(root, fingerprint=b,
                                     allow_fallback=False)
        assert r.path is None and r.cell is None


# ---------------------------------------------------------------------------
# provenance: fingerprint/backend blocks, legacy artifacts, warn-once
# ---------------------------------------------------------------------------

class TestProvenance:
    @pytest.fixture(scope="class")
    def artifact(self, tmp_path_factory):
        d = str(tmp_path_factory.mktemp("prov") / "art")
        fp = _fp("Arch A", 8)
        install(SimulatedBackend(seed=0), _tiny_cfg(fingerprint=fp),
                artifact_dir=d)
        return d, fp

    def test_blocks_persisted(self, artifact):
        d, fp = artifact
        config = json.load(open(os.path.join(d, "config.json")))
        assert config["fingerprint"]["key"] == fp.key()
        assert config["backend"]["kind"] == "simulated"
        assert config["transfer"] is None
        assert os.path.isfile(os.path.join(d, "grid.npz"))

    def test_tuner_surfaces_provenance(self, artifact):
        d, fp = artifact
        t = AdsalaTuner.from_artifact(d)
        assert t.fingerprint.key() == fp.key()
        assert t.backend_info["kind"] == "simulated"
        assert backend_from_dict(t.backend_info).spec == TPUSpec()

    def test_mismatch_warns_once_not_per_dispatch(self, artifact):
        d, _ = artifact
        other = _fp("Arch B", 4)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            t = AdsalaTuner.from_artifact(d, local_fingerprint=other)
            for _ in range(25):           # dispatch-path re-checks
                assert not t.check_fingerprint(other)
        assert len([x for x in w
                    if "installed for" in str(x.message)]) == 1

    def test_match_does_not_warn(self, artifact):
        d, fp = artifact
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            t = AdsalaTuner.from_artifact(d, local_fingerprint=fp)
        assert t.check_fingerprint(fp)

    def test_legacy_artifact_without_blocks_loads(self, artifact,
                                                  tmp_path):
        d, fp = artifact
        legacy = str(tmp_path / "legacy")
        shutil.copytree(d, legacy)
        config = json.load(open(os.path.join(legacy, "config.json")))
        for key in ("fingerprint", "backend", "transfer"):
            config.pop(key, None)
        json.dump(config, open(os.path.join(legacy, "config.json"), "w"))
        os.remove(os.path.join(legacy, "grid.npz"))
        # load_artifact and from_artifact both succeed, provenance-free,
        # and the mismatch check is a silent no-op
        _, _, cands, conf = load_artifact(legacy)
        assert cands and "fingerprint" not in conf
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            t = AdsalaTuner.from_artifact(legacy, local_fingerprint=fp)
        assert t.fingerprint is None and t.backend_info is None
        assert t.check_fingerprint(fp)
        # a grid-less legacy artifact cannot be a transfer donor
        with pytest.raises(FileNotFoundError, match="grid.npz"):
            transfer_gather(SimulatedBackend(seed=0), _tiny_cfg(),
                            legacy)


# ---------------------------------------------------------------------------
# transfer installs
# ---------------------------------------------------------------------------

def _regret(tuner: AdsalaTuner, backend: SimulatedBackend,
            eval_dims: np.ndarray, names: list[str]) -> float:
    """Mean oracle regret over the tuner's own candidates (clean)."""
    pred = tuner.predicted_times_many([tuple(d) for d in eval_dims],
                                      routines=names)
    clean = backend.time_routine_clean_batch(eval_dims, tuner.candidates,
                                             routines=names)
    chosen = clean[np.arange(len(eval_dims)), np.argmin(pred, axis=1)]
    return float(np.mean(chosen / np.maximum(clean.min(axis=1), 1e-12)
                         - 1.0))


class TestTransferInstall:
    def test_transfer_beats_scratch_at_equal_budget(self, tmp_path):
        """The ISSUE-10 satellite contract, deterministic (simulated):
        donor on arch A, local arch B with shifted bandwidth/compute; a
        transfer install's oracle regret must not exceed a scratch
        install's that timed the SAME number of local cells."""
        donor_backend = SimulatedBackend(seed=0)
        spec_b = dataclasses.replace(
            TPUSpec(), hbm_bw=TPUSpec().hbm_bw * 0.45,
            peak_flops=TPUSpec().peak_flops * 0.8)
        fp_a, fp_b = _fp("Arch A", 8), _fp("Arch B", 16)

        donor_dir = str(tmp_path / "donor")
        cfg = _tiny_cfg(n_samples=40, fingerprint=fp_a)
        install(donor_backend, cfg, artifact_dir=donor_dir)

        local = SimulatedBackend(spec=spec_b, seed=1)
        tcfg = _tiny_cfg(n_samples=40, fingerprint=fp_b,
                         calibration_dims=8, seed=1)
        tdir = str(tmp_path / "transfer")
        install(local, tcfg, artifact_dir=tdir, transfer_from=donor_dir)
        tconf = json.load(open(os.path.join(tdir, "config.json")))
        cal_cells = tconf["transfer"]["calibration_cells"]
        donor_cells = tconf["transfer"]["donor_cells"]
        assert 0 < cal_cells <= 0.10 * donor_cells

        # scratch install on arch B timing the same number of cells:
        # dense grid over n = cal_cells // C dims
        n_cfgs = len(tconf["candidates"])
        sdir = str(tmp_path / "scratch")
        scfg = _tiny_cfg(n_samples=max(4, cal_cells // n_cfgs),
                         fingerprint=fp_b, seed=1)
        install(SimulatedBackend(spec=spec_b, seed=1), scfg,
                artifact_dir=sdir)

        eval_dims = sample_gemm_dims(
            64, mem_limit_bytes=cfg.mem_limit_bytes, dim_min=cfg.dim_min,
            dim_max=cfg.dim_max, dtype_bytes=cfg.dtype_bytes, seed=123)
        names = [cfg.routines[i % len(cfg.routines)]
                 for i in range(len(eval_dims))]
        clean_backend = SimulatedBackend(spec=spec_b, seed=0)
        r_transfer = _regret(AdsalaTuner.from_artifact(tdir),
                             clean_backend, eval_dims, names)
        r_scratch = _regret(AdsalaTuner.from_artifact(sdir),
                            clean_backend, eval_dims, names)
        assert r_transfer <= r_scratch + 0.01, (
            f"transfer regret {r_transfer:.4f} worse than scratch "
            f"{r_scratch:.4f} at equal calibration budget "
            f"({cal_cells} cells)")

    def test_transfer_block_and_correction(self, tmp_path):
        donor_dir = str(tmp_path / "donor")
        cfg = _tiny_cfg(n_samples=30)
        install(SimulatedBackend(seed=0), cfg, artifact_dir=donor_dir)

        # local machine exactly 3x slower: the fitted log-delta must
        # recover ~log(3) per routine
        class Slower:
            def __init__(self, inner, factor):
                self.inner, self.factor = inner, factor

            def time_routine(self, m, k, n, c, *, routine="gemm"):
                return self.factor * self.inner.time_routine(
                    m, k, n, c, routine=routine)

        slower = Slower(SimulatedBackend(seed=7), 3.0)
        data, info = transfer_gather(
            slower, _tiny_cfg(calibration_dims=10), donor_dir)
        assert info["calibration_dims"] == 10
        assert info["donor_fingerprint"] is None    # donor had none set
        for routine, delta in info["log_delta_per_routine"].items():
            assert delta == pytest.approx(np.log(3.0), abs=0.35), (
                f"{routine}: fitted delta {delta:.3f} far from "
                f"log(3)={np.log(3.0):.3f}")
        # corrected non-measured cells scaled by ~3x vs the donor grid
        from repro.core.installer import GatheredData
        donor = GatheredData.load(os.path.join(donor_dir, "grid.npz"))
        ratio = data.times / donor.times
        assert np.median(ratio) == pytest.approx(3.0, rel=0.35)

    def test_registry_transfer_nearest(self, tmp_path):
        reg = ArtifactRegistry(str(tmp_path / "reg"))
        fp_a, fp_b = _fp("Arch A", 8), _fp("Arch B", 16)
        reg.install(fp_a, SimulatedBackend(seed=0), _tiny_cfg())
        rep = reg.install(fp_b, SimulatedBackend(seed=1),
                          _tiny_cfg(calibration_dims=6),
                          transfer_from="nearest")
        conf = json.load(open(os.path.join(rep.artifact_dir,
                                           "config.json")))
        assert conf["transfer"]["donor"] == os.path.abspath(
            reg.artifact_dir(fp_a))
        assert conf["transfer"]["donor_fingerprint"]["key"] == fp_a.key()
        assert conf["fingerprint"]["key"] == fp_b.key()
        # nearest with an empty registry degrades to a scratch install
        reg2 = ArtifactRegistry(str(tmp_path / "reg2"))
        rep2 = reg2.install(fp_a, SimulatedBackend(seed=0), _tiny_cfg(),
                            transfer_from="nearest")
        conf2 = json.load(open(os.path.join(rep2.artifact_dir,
                                            "config.json")))
        assert conf2["transfer"] is None


# ---------------------------------------------------------------------------
# hardened measured backend + provenance round-trip
# ---------------------------------------------------------------------------

class TestMeasuredBackend:
    def test_repeats_validation(self):
        with pytest.raises(ValueError):
            MeasuredCPUBackend(repeats=0)
        with pytest.raises(ValueError):
            MeasuredCPUBackend(warmup=-1)

    def test_median_of_k_reduces_variance(self):
        """The ISSUE-10 hardening satellite: warmup + median-of-k must
        not be noisier than raw single-shot timing (and on shared CI
        boxes it is substantially quieter)."""
        cfg = GemmConfig(n_chips=1, partition="M", tile_id=0)
        noisy = MeasuredCPUBackend(repeats=1, warmup=0, seed=0)
        steady = MeasuredCPUBackend(repeats=5, warmup=1, seed=0)
        m = k = n = 160
        noisy.time_routine(m, k, n, cfg)      # page in buffers once
        raw = np.asarray([noisy.time_routine(m, k, n, cfg)
                          for _ in range(17)])
        hard = np.asarray([steady.time_routine(m, k, n, cfg)
                           for _ in range(17)])
        spread = np.subtract(*np.percentile(raw, [75, 25]))
        spread_h = np.subtract(*np.percentile(hard, [75, 25]))
        # strict improvement when there is noise to remove; an
        # already-quiet box passes via the 2%-of-median floor
        assert spread_h <= max(spread, 0.02 * float(np.median(hard))), (
            f"median-of-5 IQR {spread_h:.2e}s not below single-shot "
            f"IQR {spread:.2e}s")

    def test_backend_provenance_roundtrip(self):
        m = MeasuredCPUBackend(max_dim=512, seed=3, repeats=4, warmup=2)
        d = json.loads(json.dumps(describe_backend(m)))
        back = backend_from_dict(d)
        assert isinstance(back, MeasuredCPUBackend)
        assert (back.max_dim, back.seed, back.repeats, back.warmup) == \
            (512, 3, 4, 2)
        s = SimulatedBackend(spec=dataclasses.replace(
            TPUSpec(), hbm_bw=1e11), dtype_bytes=4, seed=9)
        back = backend_from_dict(json.loads(json.dumps(
            describe_backend(s))))
        assert back.spec == s.spec and back.dtype_bytes == 4
        with pytest.raises(ValueError, match="cannot reconstruct"):
            backend_from_dict({"kind": "gpu-cluster"})


# ---------------------------------------------------------------------------
# registry-backed serving loop
# ---------------------------------------------------------------------------

class TestRegistryServing:
    def test_reinstall_manager_targets_cell(self, tmp_path):
        from repro.kernels.recorder import DispatchRecorder
        from repro.serve import ReinstallManager

        reg = ArtifactRegistry(str(tmp_path / "reg"))
        fp = _fp("Arch A", 8)
        reg.install(fp, SimulatedBackend(seed=0), _tiny_cfg())
        mgr = ReinstallManager(registry=reg, fingerprint=fp,
                               recorders=DispatchRecorder())
        assert mgr.artifact_dir == reg.artifact_dir(fp)
        assert mgr.fingerprint.key() == fp.key()
        # backend rebuilt from the artifact's provenance block
        assert isinstance(mgr.backend, SimulatedBackend)
        # an empty cell refuses to serve
        with pytest.raises(FileNotFoundError):
            ReinstallManager(registry=reg, fingerprint=_fp("Cold", 2),
                             recorders=DispatchRecorder())
        with pytest.raises(ValueError, match="artifact_dir"):
            ReinstallManager(recorders=DispatchRecorder())

    def test_manager_rebuilds_measured_backend(self, tmp_path):
        from repro.kernels.recorder import DispatchRecorder
        from repro.serve import ReinstallManager

        art = str(tmp_path / "art")
        cfg = _tiny_cfg(n_samples=10, routines=("gemm",),
                        dim_max=96, mem_limit_mb=2)
        install(MeasuredCPUBackend(max_dim=128, repeats=2), cfg,
                artifact_dir=art)
        mgr = ReinstallManager(art, DispatchRecorder())
        assert isinstance(mgr.backend, MeasuredCPUBackend)
        assert mgr.backend.repeats == 2
        # explicit backend always wins over provenance
        mgr2 = ReinstallManager(art, DispatchRecorder(),
                                backend=SimulatedBackend(seed=5))
        assert isinstance(mgr2.backend, SimulatedBackend)
