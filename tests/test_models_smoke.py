"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus the serving-path
invariant (prefill + decode == full forward)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.models.transformer as T
from repro.configs import ARCH_IDS, get_config, get_smoke_config, build_model
from repro.models import Ctx

B, S = 2, 32


def _batch(cfg, rng, seq=S):
    tokens = jax.random.randint(rng, (B, seq), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["audio_emb"] = jax.random.normal(
            rng, (B, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch))(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    dctx = Ctx(mode="decode", cache_len=S + 8)
    cache = model.init_cache(B, dctx)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab)
    logits, new_cache = model.decode_step(params, tok, cache,
                                          jnp.int32(0), dctx)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(new_cache))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch, monkeypatch):
    """Serving invariant: decode after prefill == one big forward."""
    # capacity drops in MoE are non-causal by construction; disable them
    orig = T._moe_spec
    monkeypatch.setattr(
        T, "_moe_spec",
        lambda cfg: dataclasses.replace(orig(cfg), capacity_factor=8.0))
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng, jnp.float32)
    seq = 24
    tokens = jax.random.randint(rng, (B, seq + 1), 0, cfg.vocab)
    ctx = Ctx(mode="prefill", cache_len=seq + 8, remat=False)
    if cfg.family == "audio":
        audio = jax.random.normal(rng, (B, cfg.encoder_len, cfg.d_model))
        full_logits, _ = model.prefill(
            params, {"tokens": tokens, "audio_emb": audio}, ctx)
        _, cache = model.prefill(
            params, {"tokens": tokens[:, :seq], "audio_emb": audio}, ctx)
    else:
        full_logits, _ = model.prefill(params, tokens, ctx)
        _, cache = model.prefill(params, tokens[:, :seq], ctx)
    dctx = Ctx(mode="decode", cache_len=seq + 8)
    dec_logits, _ = model.decode_step(params, tokens[:, seq:seq + 1],
                                      cache, jnp.int32(seq), dctx)
    scale = float(jnp.abs(full_logits).max())
    assert float(jnp.abs(full_logits - dec_logits).max()) < 2e-4 * scale \
        + 1e-4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_spec(arch):
    """The full (non-smoke) configs carry the exact assigned dims."""
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-236b": (60, 5120, 128, 128, 102_400),
        "mixtral-8x22b": (56, 6144, 48, 8, 32_768),
        "starcoder2-3b": (30, 3072, 24, 2, 49_152),
        "granite-8b": (36, 4096, 32, 8, 49_152),
        "chatglm3-6b": (28, 4096, 32, 2, 65_024),
        "stablelm-1.6b": (24, 2048, 32, 32, 100_352),
        "whisper-tiny": (4, 384, 6, 6, 51_865),
        "chameleon-34b": (48, 8192, 64, 8, 65_536),
        "recurrentgemma-2b": (26, 2560, 10, 1, 256_000),
        "xlstm-125m": (12, 768, 4, 4, 50_304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.vocab)
    assert got == expected


def test_moe_configs_match_spec():
    ds = get_config("deepseek-v2-236b")
    assert (ds.n_experts, ds.top_k, ds.n_shared_experts,
            ds.kv_lora_rank) == (160, 6, 2, 512)
    mx = get_config("mixtral-8x22b")
    assert (mx.n_experts, mx.top_k, mx.d_ff) == (8, 2, 16384)
