"""TPU cost-model properties: the physics the tuner learns from."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import (
    DEFAULT_TILES,
    GemmConfig,
    TPUSpec,
    candidate_configs,
    estimate_gemm_time,
)


def _best(m, k, n):
    best = None
    for cfg in candidate_configs(512, tiles=(0, 3)):
        t = estimate_gemm_time(m, k, n, cfg).total_s
        if best is None or t < best[0]:
            best = (t, cfg)
    return best


def test_small_gemm_prefers_few_chips():
    """Paper Table VII: 64x2048x64 ran 81x faster on few workers."""
    _, cfg = _best(64, 2048, 64)
    assert cfg.n_chips <= 4


def test_large_square_gemm_prefers_many_chips():
    """Paper Fig 9: big square GEMMs want (near-)max workers."""
    _, cfg = _best(16384, 16384, 16384)
    assert cfg.n_chips >= 128


def test_paper_case_speedup_magnitude():
    """The 64x2048x64 case: few-worker vs all-workers ratio is large,
    matching the paper's 81.6x order of magnitude."""
    t_best, _ = _best(64, 2048, 64)
    t_max = estimate_gemm_time(64, 2048, 64,
                               GemmConfig(512, "2D", 3)).total_s
    assert t_max / t_best > 20


@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 8192), k=st.integers(8, 8192),
       n=st.integers(8, 8192))
def test_terms_positive_and_finite(m, k, n):
    tb = estimate_gemm_time(m, k, n, GemmConfig(16, "M", 0))
    for v in (tb.compute_s, tb.memory_s, tb.collective_s, tb.launch_s):
        assert np.isfinite(v) and v >= 0
    assert tb.total_s > 0


@settings(max_examples=20, deadline=None)
@given(p=st.sampled_from([2, 8, 64, 512]))
def test_collective_term_grows_with_chips(p):
    t1 = estimate_gemm_time(4096, 4096, 4096, GemmConfig(p, "K", 3))
    t2 = estimate_gemm_time(4096, 4096, 4096,
                            GemmConfig(min(512, p * 2), "K", 3))
    assert t2.collective_s >= t1.collective_s * 0.8


def test_compute_term_shrinks_with_chips():
    t1 = estimate_gemm_time(8192, 8192, 8192, GemmConfig(1, "M", 3))
    t64 = estimate_gemm_time(8192, 8192, 8192, GemmConfig(64, "M", 3))
    assert t64.compute_s < t1.compute_s / 30


def test_vmem_overflow_cliff():
    """Tiles beyond VMEM get the spill penalty (memory term jumps)."""
    small = estimate_gemm_time(4096, 4096, 4096, GemmConfig(1, "M", 0))
    spec = TPUSpec(vmem_bytes=2**16)   # absurdly small VMEM
    spilled = estimate_gemm_time(4096, 4096, 4096, GemmConfig(1, "M", 0),
                                 spec)
    assert spilled.memory_s > small.memory_s * 2


def test_noise_is_reproducible_and_bounded():
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    a = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0),
                           rng=rng1).total_s
    b = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0),
                           rng=rng2).total_s
    clean = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0)).total_s
    assert a == b
    assert 0.5 * clean < a < 5 * clean


def test_candidate_set_structure():
    cands = candidate_configs(512)
    chips = {c.n_chips for c in cands}
    assert chips == {1, 2, 4, 8, 16, 32, 64, 128, 256, 512}
    assert all(c.partition != "2D" or c.n_chips >= 4 for c in cands)
    assert all(0 <= c.tile_id < len(DEFAULT_TILES) for c in cands)
