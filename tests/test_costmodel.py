"""TPU cost-model properties: the physics the tuner learns from."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import (
    DEFAULT_TILES,
    GemmConfig,
    TPUSpec,
    candidate_configs,
    estimate_batch,
    estimate_batch_terms,
    estimate_gemm_time,
)


def _best(m, k, n):
    best = None
    for cfg in candidate_configs(512, tiles=(0, 3)):
        t = estimate_gemm_time(m, k, n, cfg).total_s
        if best is None or t < best[0]:
            best = (t, cfg)
    return best


def test_small_gemm_prefers_few_chips():
    """Paper Table VII: 64x2048x64 ran 81x faster on few workers."""
    _, cfg = _best(64, 2048, 64)
    assert cfg.n_chips <= 4


def test_large_square_gemm_prefers_many_chips():
    """Paper Fig 9: big square GEMMs want (near-)max workers."""
    _, cfg = _best(16384, 16384, 16384)
    assert cfg.n_chips >= 128


def test_paper_case_speedup_magnitude():
    """The 64x2048x64 case: few-worker vs all-workers ratio is large,
    matching the paper's 81.6x order of magnitude."""
    t_best, _ = _best(64, 2048, 64)
    t_max = estimate_gemm_time(64, 2048, 64,
                               GemmConfig(512, "2D", 3)).total_s
    assert t_max / t_best > 20


@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 8192), k=st.integers(8, 8192),
       n=st.integers(8, 8192))
def test_terms_positive_and_finite(m, k, n):
    tb = estimate_gemm_time(m, k, n, GemmConfig(16, "M", 0))
    for v in (tb.compute_s, tb.memory_s, tb.collective_s, tb.launch_s):
        assert np.isfinite(v) and v >= 0
    assert tb.total_s > 0


@settings(max_examples=20, deadline=None)
@given(p=st.sampled_from([2, 8, 64, 512]))
def test_collective_term_grows_with_chips(p):
    t1 = estimate_gemm_time(4096, 4096, 4096, GemmConfig(p, "K", 3))
    t2 = estimate_gemm_time(4096, 4096, 4096,
                            GemmConfig(min(512, p * 2), "K", 3))
    assert t2.collective_s >= t1.collective_s * 0.8


def test_compute_term_shrinks_with_chips():
    t1 = estimate_gemm_time(8192, 8192, 8192, GemmConfig(1, "M", 3))
    t64 = estimate_gemm_time(8192, 8192, 8192, GemmConfig(64, "M", 3))
    assert t64.compute_s < t1.compute_s / 30


def test_vmem_overflow_cliff():
    """Tiles beyond VMEM get the spill penalty (memory term jumps)."""
    small = estimate_gemm_time(4096, 4096, 4096, GemmConfig(1, "M", 0))
    spec = TPUSpec(vmem_bytes=2**16)   # absurdly small VMEM
    spilled = estimate_gemm_time(4096, 4096, 4096, GemmConfig(1, "M", 0),
                                 spec)
    assert spilled.memory_s > small.memory_s * 2


def test_noise_is_reproducible_and_bounded():
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    a = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0),
                           rng=rng1).total_s
    b = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0),
                           rng=rng2).total_s
    clean = estimate_gemm_time(512, 512, 512, GemmConfig(8, "M", 0)).total_s
    assert a == b
    assert 0.5 * clean < a < 5 * clean


def test_candidate_set_structure():
    cands = candidate_configs(512)
    chips = {c.n_chips for c in cands}
    assert chips == {1, 2, 4, 8, 16, 32, 64, 128, 256, 512}
    assert all(c.partition != "2D" or c.n_chips >= 4 for c in cands)
    assert all(0 <= c.tile_id < len(DEFAULT_TILES) for c in cands)


def test_chip_doublings_validates_and_truncates():
    """Regression: candidate_configs(0) used to die inside
    int(math.log2(max_chips)) with a ValueError mentioning math.log2,
    and non-powers-of-two were silently truncated (6 -> [1, 2, 4])
    without the behaviour being stated anywhere.  chip_doublings now
    owns both: a clear error for invalid input, documented flooring
    for valid non-powers."""
    from repro.core.costmodel import chip_doublings

    assert chip_doublings(1) == [1]
    assert chip_doublings(8) == [1, 2, 4, 8]
    # documented truncation: every doubling <= max_chips
    assert chip_doublings(6) == [1, 2, 4]
    assert chip_doublings(511) == [1, 2, 4, 8, 16, 32, 64, 128, 256]
    for bad in (0, -3, 2.5, "x", True):
        with pytest.raises(ValueError, match="max_chips"):
            chip_doublings(bad)
    # the candidate enumeration inherits the validation and the
    # documented truncation instead of a bare math-domain error
    with pytest.raises(ValueError, match="max_chips"):
        candidate_configs(0)
    assert {c.n_chips for c in candidate_configs(6)} == {1, 2, 4}


# ---------------------------------------------------------------------------
# vectorised estimate_batch vs the scalar reference path
# ---------------------------------------------------------------------------

def _scalar_grid(dims, cfgs, spec=TPUSpec()):
    out = np.empty((len(dims), len(cfgs)))
    for i, (m, k, n) in enumerate(dims):
        for j, c in enumerate(cfgs):
            out[i, j] = estimate_gemm_time(int(m), int(k), int(n), c,
                                           spec).total_s
    return out


def _random_dims(count, seed=42, hi=65536):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(8, hi, count) for _ in range(3)],
                    axis=1).astype(np.int64)


def test_batch_matches_scalar_bitwise():
    """Noise-free vectorised grid == scalar loop, bit for bit."""
    dims = _random_dims(60)
    cfgs = candidate_configs(512)
    vec = estimate_batch(dims, cfgs, seed=None)
    np.testing.assert_array_equal(vec, _scalar_grid(dims, cfgs))


def test_batch_terms_match_scalar_bitwise():
    """Every per-term column matches, not just the totals."""
    dims = _random_dims(20, seed=7)
    cfgs = candidate_configs(512, tiles=(0, 3, 5))
    bb = estimate_batch_terms(dims, cfgs)
    for i, (m, k, n) in enumerate(dims):
        for j, c in enumerate(cfgs):
            tb = estimate_gemm_time(int(m), int(k), int(n), c)
            assert bb.compute_s[i, j] == tb.compute_s
            assert bb.memory_s[i, j] == tb.memory_s
            assert bb.collective_s[i, j] == tb.collective_s
            assert bb.launch_s[i, j] == tb.launch_s


def test_batch_matches_scalar_on_edge_shapes():
    """Tiny dims, ragged dims, non-power-of-two chip counts."""
    dims = np.array([[8, 8, 8], [9, 17, 33], [65536, 8, 65536],
                     [100, 130, 70]], dtype=np.int64)
    cfgs = [GemmConfig(c, p, t) for c in (1, 2, 3, 5, 7, 12, 100, 512)
            for p in ("M", "N", "K", "2D") for t in (0, 5, 7)]
    np.testing.assert_array_equal(estimate_batch(dims, cfgs, seed=None),
                                  _scalar_grid(dims, cfgs))


def test_batch_matches_scalar_under_custom_spec():
    spec = TPUSpec(vmem_bytes=2**16, peak_flops=90e12, mxu_dim=256)
    dims = _random_dims(10, seed=3)
    cfgs = candidate_configs(64)
    np.testing.assert_array_equal(
        estimate_batch(dims, cfgs, spec, seed=None),
        _scalar_grid(dims, cfgs, spec))


def test_batch_noise_reproducible_and_bounded():
    dims = _random_dims(20, seed=5)
    cfgs = candidate_configs(64, tiles=(0, 3))
    a = estimate_batch(dims, cfgs, seed=11)
    b = estimate_batch(dims, cfgs, seed=11)
    clean = estimate_batch(dims, cfgs, seed=None)
    np.testing.assert_array_equal(a, b)
    assert np.all(a > 0.2 * clean) and np.all(a < 10 * clean)
    assert not np.array_equal(a, clean)


def test_batch_is_20x_faster_than_scalar_loop():
    """Acceptance: >=20x on a 400-dims x 128-configs grid.  The
    vectorised pass replaces ~51k scalar model calls per repeat.
    (Unloaded, the ratio is ~50x; the bar leaves headroom for noisy
    shared-CPU runners, and both paths are timed back to back under the
    same load with gc paused.)"""
    import gc
    import time
    dims = _random_dims(400)
    cfgs = candidate_configs(512)[:128]
    assert len(cfgs) == 128

    estimate_batch_terms(dims, cfgs)          # warm numpy ufunc caches
    best = 0.0
    for _attempt in range(3):                 # absorb shared-CPU spikes
        gc.disable()
        try:
            t0 = time.perf_counter()
            _scalar_grid(dims, cfgs)
            t_scalar = time.perf_counter() - t0

            reps = []
            for _ in range(5):
                t0 = time.perf_counter()
                estimate_batch_terms(dims, cfgs).total_s
                reps.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        best = max(best, t_scalar / min(reps))
        if best >= 20:
            break
    assert best >= 20, best
