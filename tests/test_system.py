"""End-to-end behaviour tests: the full ADSALA pipeline (paper Figs 2+3)
against the TPU simulator — install over a mixed BLAS-3 grid, select,
persist, reload, speed up.  Uses the shared session-scoped
``tiny_artifact`` install run (tests/conftest.py)."""

import numpy as np
import pytest

from repro.core import AdsalaTuner, GemmConfig, ROUTINES

# generous per-test wall budget: the session-scoped install fixture can
# take minutes on a cold 2-core container, but a wedge should fail the
# test, not hang the slow lane
pytestmark = [pytest.mark.slow, pytest.mark.timeout(900)]


def test_install_produces_two_files(tiny_artifact):
    import os
    d = tiny_artifact.dir
    # paper Fig 2: configurations + production model
    assert os.path.exists(os.path.join(d, "config.json"))
    assert os.path.exists(os.path.join(d, "model.json"))


def test_selection_table_has_all_models(tiny_artifact):
    report = tiny_artifact.report
    assert {r.name for r in report.reports} == {
        "linear_regression", "decision_tree", "xgboost"}
    assert report.selected in {r.name for r in report.reports}


def test_per_routine_speedup_report(tiny_artifact):
    """A mixed-routine install reports held-out speedups per routine
    (the arXiv 2406.19621 Tables III/IV analogue)."""
    report = tiny_artifact.report
    for r in report.reports:
        assert set(r.per_routine) == set(ROUTINES)
        for stats in r.per_routine.values():
            assert stats["n_test"] >= 1
            for v in stats.values():
                assert np.isfinite(v) and v > 0
    table = report.routine_table()
    for routine in ROUTINES:
        assert routine in table
    assert report.routine_table() in report.table()


def test_tuner_reload_and_select(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    for routine in ROUTINES:
        cfg = tuner.select(512, 512, 512, routine)
        assert isinstance(cfg, GemmConfig)
        assert cfg in tuner.candidates


def test_tuner_memoisation(tiny_artifact):
    """Paper §III-C: repeated dims skip re-evaluation (per routine)."""
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    for _ in range(5):
        tuner.select(64, 2048, 64, "syrk")
    assert tuner.stats["calls"] == 5
    assert tuner.stats["evaluations"] == 1
    assert tuner.stats["cache_hits"] == 4


def test_adsala_beats_default_on_aggregate(tiny_artifact):
    """The reproduction claim: tuned worker configs beat 'use every
    chip' in aggregate over a held-out low-discrepancy set, per-routine
    dispatched.

    Model *selection* weighs a wall-clock t_eval measurement, which
    jitters on a loaded 2-core runner and can pick the (tie-with-
    default) linear model over the strictly-better tree model — so the
    strict >1 claim is asserted on the deterministic ideal report, and
    the end-to-end selected-model path must never be *worse* than the
    default."""
    run = tiny_artifact
    assert max(r.ideal_aggregate_speedup
               for r in run.report.reports) > 1.0
    tuner = AdsalaTuner.from_artifact(run.dir)
    rng = np.random.default_rng(123)
    idx = rng.choice(len(run.data.dims), size=30, replace=False)
    names = run.data.routine_names()
    t_default, t_tuned = 0.0, 0.0
    for i in idx:
        m, k, n = (int(v) for v in run.data.dims[i])
        routine = names[i]
        chosen = tuner.select(m, k, n, routine)
        t_tuned += run.backend.time_routine_clean(m, k, n, chosen,
                                                  routine=routine)
        t_default += run.backend.time_routine_clean(
            m, k, n, run.cfg.default_config, routine=routine)
    assert t_default / t_tuned >= 1.0


def test_predicted_times_positive_and_finite(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    for routine in ROUTINES:
        times = tuner.predicted_times(1000, 1000, 1000, routine)
        assert np.all(np.isfinite(times)) and np.all(times > 0)
        assert len(times) == len(tuner.candidates)
