"""End-to-end behaviour tests: the full ADSALA pipeline (paper Figs 2+3)
against the TPU simulator — install, select, persist, reload, speed up."""

import numpy as np
import pytest

from repro.core import (
    AdsalaTuner,
    GemmConfig,
    InstallConfig,
    SimulatedBackend,
    gather_data,
    install,
)

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    """A small but real install run (shared across tests)."""
    d = tmp_path_factory.mktemp("artifact")
    cfg = InstallConfig(
        n_samples=80, repeats=2, tile_ids=(0, 3),
        models=("linear_regression", "decision_tree", "xgboost"),
        grid_budget="small", cv_splits=3, seed=0)
    backend = SimulatedBackend(seed=0)
    data = gather_data(backend, cfg)
    report = install(backend, cfg, data=data, artifact_dir=str(d))
    return d, cfg, backend, data, report


def test_install_produces_two_files(artifact):
    d, *_ = artifact
    assert (d / "config.json").exists()   # paper Fig 2: configurations
    assert (d / "model.json").exists()    # paper Fig 2: production model


def test_selection_table_has_all_models(artifact):
    *_, report = artifact
    assert {r.name for r in report.reports} == {
        "linear_regression", "decision_tree", "xgboost"}
    assert report.selected in {r.name for r in report.reports}


def test_tuner_reload_and_select(artifact):
    d, *_ = artifact
    tuner = AdsalaTuner.from_artifact(str(d))
    cfg = tuner.select(512, 512, 512)
    assert isinstance(cfg, GemmConfig)
    assert cfg in tuner.candidates


def test_tuner_memoisation(artifact):
    """Paper §III-C: repeated dims skip re-evaluation."""
    d, *_ = artifact
    tuner = AdsalaTuner.from_artifact(str(d))
    for _ in range(5):
        tuner.select(64, 2048, 64)
    assert tuner.stats["calls"] == 5
    assert tuner.stats["evaluations"] == 1
    assert tuner.stats["cache_hits"] == 4


def test_adsala_beats_default_on_aggregate(artifact):
    """The reproduction claim: tuned worker configs beat 'use every
    chip' in aggregate over a held-out low-discrepancy set."""
    d, icfg, backend, data, _ = artifact
    tuner = AdsalaTuner.from_artifact(str(d))
    rng = np.random.default_rng(123)
    idx = rng.choice(len(data.dims), size=30, replace=False)
    t_default, t_tuned = 0.0, 0.0
    for i in idx:
        m, k, n = (int(v) for v in data.dims[i])
        chosen = tuner.select(m, k, n)
        t_tuned += backend.time_gemm_clean(m, k, n, chosen)
        t_default += backend.time_gemm_clean(m, k, n, icfg.default_config)
    assert t_default / t_tuned > 1.0


def test_predicted_times_positive_and_finite(artifact):
    d, *_ = artifact
    tuner = AdsalaTuner.from_artifact(str(d))
    times = tuner.predicted_times(1000, 1000, 1000)
    assert np.all(np.isfinite(times)) and np.all(times > 0)
    assert len(times) == len(tuner.candidates)
