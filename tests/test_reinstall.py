"""Closed-loop serving: drift-triggered re-install + atomic hot-swap.

Covers the ISSUE-8 acceptance path end to end (serve a recorded mix,
shift it past the drift threshold, background re-install fires exactly
once, the artifact swap is atomic with zero dropped dispatches, and
rollback restores the previous artifact byte-for-byte), plus fault
injection: the background install is killed at each phase and the live
tuner must keep serving the old artifact with on-disk state intact.
"""

import hashlib
import os
import shutil
import threading

import numpy as np
import pytest

from repro.core.costmodel import GemmConfig
from repro.core.installer import (
    ARTIFACT_COMMIT,
    InstallConfig,
    artifact_prev_dir,
    artifact_tmp_dir,
    commit_artifact,
    install,
    is_artifact,
    resolve_artifact,
)
from repro.core.timing import SimulatedBackend
from repro.core.tuner import AdsalaTuner
from repro.core.workload import WorkloadProfile
from repro.kernels.recorder import DispatchEvent, DispatchRecorder
from repro.serve import ReinstallConfig, ReinstallManager

pytestmark = pytest.mark.timeout(180)

_INSTALL = dict(n_samples=48, repeats=1, routines=("gemm", "syrk"),
                models=("decision_tree",), tile_ids=(0, 1, 3))
#: budget-capped template the manager re-installs with
_REINSTALL_CFG = InstallConfig(timing_budget=200, **_INSTALL)


def _synthetic_recorder(routine: str, lo: int, hi: int, n: int, *,
                        seed: int) -> DispatchRecorder:
    rec = DispatchRecorder()
    rng = np.random.default_rng(seed)
    for _ in range(n):
        m, k, nn = (int(x) for x in rng.integers(lo, hi, 3))
        rec.events.append(DispatchEvent(routine=routine, m=m, k=k, n=nn,
                                        site="synthetic"))
    return rec


def _dir_digest(d: str) -> str:
    h = hashlib.sha256()
    for name in sorted(os.listdir(d)):
        h.update(name.encode())
        with open(os.path.join(d, name), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


@pytest.fixture(scope="session")
def _artifact_src(tmp_path_factory):
    """One real install (gemm-heavy small-shape profile), copied per
    test so swaps/rollbacks never leak between tests."""
    src = tmp_path_factory.mktemp("reinstall") / "artifact"
    prof = WorkloadProfile.from_recorder(
        _synthetic_recorder("gemm", 64, 512, 64, seed=11))
    install(SimulatedBackend(seed=0),
            InstallConfig(workload=prof, **_INSTALL),
            artifact_dir=str(src))
    return src


@pytest.fixture
def artifact(tmp_path, _artifact_src) -> str:
    dst = tmp_path / "artifact"
    shutil.copytree(_artifact_src, dst)
    return str(dst)


def _shifted_recorder(seed: int = 7) -> DispatchRecorder:
    """Serving mix disjoint from the installed profile: syrk-only and
    an order of magnitude larger shapes -> drift ~1."""
    return _synthetic_recorder("syrk", 2048, 8192, 128, seed=seed)


def _manager(artifact: str, rec, clock, **cfg_kw) -> ReinstallManager:
    kw = dict(threshold=0.25, hysteresis=0.05, cooldown_s=60.0,
              min_events=16, install=_REINSTALL_CFG)
    kw.update(cfg_kw)
    return ReinstallManager(artifact, rec,
                            backend=SimulatedBackend(seed=0),
                            cfg=ReinstallConfig(**kw),
                            clock=lambda: clock[0])


# ---------------------------------------------------------------------------
# E2E acceptance: shift -> fire once -> swap under traffic -> recover
# ---------------------------------------------------------------------------

def test_e2e_drift_triggers_swap_under_traffic(artifact, tmp_path):
    clock = [0.0]
    hb = str(tmp_path / "reinstall.hb")
    mgr = _manager(artifact, {"decode": _shifted_recorder()}, clock,
                   heartbeat_path=hb)

    shapes = [(int(m), int(k), int(n)) for m, k, n in
              np.random.default_rng(5).integers(128, 4096, (8, 3))]
    errors: list = []
    served = [0] * 4
    stop = threading.Event()

    def hammer(tid: int) -> None:
        while not stop.is_set():
            try:
                for i, (m, k, n) in enumerate(shapes):
                    r = ("gemm", "syrk")[i % 2]
                    assert isinstance(mgr.select(m, k, n, r), GemmConfig)
                    served[tid] += 1
                for c in mgr.select_many(shapes, routines="syrk"):
                    assert isinstance(c, GemmConfig)
                    served[tid] += 1
            except Exception as e:          # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    try:
        assert mgr.drift() > 0.9            # disjoint mix, before fire
        assert mgr.check()                  # fires
        assert not mgr.check()              # exactly once: in flight
        assert mgr.wait(timeout=120)
        assert mgr.last_error is None
        assert mgr.swaps == 1 and mgr.fires == 1
    finally:
        stop.set()
        for t in threads:
            t.join()

    assert not errors                       # zero dropped dispatches
    assert all(n > 0 for n in served)       # every thread kept serving
    # the re-install was mix-weighted by the live profile: drift closes
    assert mgr.drift() < 0.25
    # on disk: new artifact live, old retained for rollback
    assert is_artifact(artifact)
    assert os.path.exists(os.path.join(artifact, ARTIFACT_COMMIT))
    assert is_artifact(artifact_prev_dir(artifact))
    assert not os.path.isdir(artifact_tmp_dir(artifact))
    # below threshold now -> no re-fire, regardless of cooldown
    clock[0] += 1e6
    assert not mgr.check() and mgr.fires == 1
    # the install stamped its phases into the liveness beacon (the ft
    # heartbeat idiom) and parked on "idle" after the swap
    from repro.ft import read_heartbeat
    assert read_heartbeat(hb)[0] == "idle"


def test_swap_keys_reselected_through_new_model(artifact):
    """Warm-start carry-over is per-artifact: hot *keys* survive a swap
    but their configs must equal what the new artifact would choose
    fresh — never the old tuner's cached choices."""
    clock = [0.0]
    mgr = _manager(artifact, {"all": _shifted_recorder()}, clock)
    keys = [(256, 256, 256), (1024, 512, 2048), (64, 4096, 64)]
    for m, k, n in keys:
        mgr.select(m, k, n, "syrk")
    assert mgr.check() and mgr.wait(timeout=120) and mgr.swaps == 1
    fresh = AdsalaTuner.from_artifact(artifact)
    for m, k, n in keys:
        assert mgr.peek(m, k, n, "syrk")    # key carried over (warm)
        assert mgr.select(m, k, n, "syrk") == fresh.select(m, k, n, "syrk")


# ---------------------------------------------------------------------------
# fault injection: kill the background install at every phase
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", ["gather", "fit", "write", "commit"])
def test_install_killed_mid_phase_keeps_serving(artifact, phase):
    clock = [0.0]
    before = _dir_digest(artifact)
    rec = _shifted_recorder()
    mgr = _manager(artifact, rec, clock)

    def bomb(p: str) -> None:
        if p == phase:
            raise RuntimeError(f"killed@{p}")

    mgr._phase_hook = bomb
    pre = mgr.select(512, 512, 512, "gemm")
    assert mgr.check() and mgr.wait(timeout=120)
    assert f"killed@{phase}" in repr(mgr.last_error)
    assert mgr.swaps == 0

    # the live tuner never noticed: same artifact, same choices
    assert mgr.select(512, 512, 512, "gemm") == pre
    assert _dir_digest(artifact) == before
    assert not os.path.isdir(artifact_prev_dir(artifact))

    tmp = artifact_tmp_dir(artifact)
    if phase == "write":
        # killed after the artifact files, before the sentinel: the tmp
        # is on disk but uncommitted — promotion must refuse it
        assert os.path.isdir(tmp)
        assert not os.path.exists(os.path.join(tmp, ARTIFACT_COMMIT))
        with pytest.raises(ValueError):
            commit_artifact(tmp, artifact)

    # restart: boot resolution keeps the live artifact, sweeps debris
    assert resolve_artifact(artifact) == artifact
    assert not os.path.isdir(tmp)
    assert _dir_digest(artifact) == before
    mgr2 = _manager(artifact, rec, clock)
    assert mgr2.select(512, 512, 512, "gemm") == pre


def test_mid_commit_crash_window_recovers(artifact):
    """Crash between commit's two renames: live dir gone, .prev holds
    the old artifact.  resolve_artifact restores it and the manager
    boots as if nothing happened."""
    pre = AdsalaTuner.from_artifact(artifact).select(512, 512, 512)
    before = _dir_digest(artifact)
    os.replace(artifact, artifact_prev_dir(artifact))
    assert resolve_artifact(artifact) == artifact
    assert _dir_digest(artifact) == before
    mgr = _manager(artifact, _shifted_recorder(), [0.0])
    assert mgr.select(512, 512, 512, "gemm") == pre


def test_boot_refuses_missing_artifact(tmp_path):
    with pytest.raises(FileNotFoundError):
        ReinstallManager(str(tmp_path / "nope"), DispatchRecorder())


# ---------------------------------------------------------------------------
# rollback
# ---------------------------------------------------------------------------

def test_rollback_restores_prev_byte_for_byte(artifact):
    clock = [0.0]
    mgr = _manager(artifact, {"all": _shifted_recorder()}, clock)
    before = _dir_digest(artifact)
    pre = mgr.select(512, 512, 512, "gemm")

    assert mgr.check() and mgr.wait(timeout=120) and mgr.swaps == 1
    assert _dir_digest(artifact) != before  # new artifact is live

    mgr.rollback()
    assert _dir_digest(artifact) == before  # byte-for-byte restore
    assert mgr.select(512, 512, 512, "gemm") == pre
    # the displaced (new) artifact sits in .prev: rollback is symmetric
    assert is_artifact(artifact_prev_dir(artifact))


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_min_events_guard_blocks_noise(artifact):
    clock = [0.0]
    rec = _synthetic_recorder("syrk", 2048, 8192, 4, seed=1)  # 4 events
    mgr = _manager(artifact, rec, clock, min_events=16)
    assert mgr.drift() > 0.9                # drifted, but too few events
    assert not mgr.check() and mgr.fires == 0


def test_uniform_artifact_never_fires(tmp_path):
    """No installed workload profile -> drift undefined -> no fire."""
    art = str(tmp_path / "uniform")
    install(SimulatedBackend(seed=0), InstallConfig(**_INSTALL),
            artifact_dir=art)
    mgr = _manager(art, _shifted_recorder(), [0.0])
    assert mgr.drift() is None
    assert not mgr.check() and mgr.fires == 0


def test_stale_tmp_swept_and_commit_refused(artifact):
    tmp = artifact_tmp_dir(artifact)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "config.json"), "w") as f:
        f.write("{}")                       # partial write, no model
    with pytest.raises(ValueError):
        commit_artifact(tmp, artifact)
    assert resolve_artifact(artifact) == artifact
    assert not os.path.isdir(tmp)           # debris swept at boot
