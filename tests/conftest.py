"""Suite bootstrap.

* Fast lane: ``pytest -m "not slow"`` skips the end-to-end install and
  subprocess-spawning distributed suites (the ``slow`` marker is
  registered in pyproject.toml).
* ``hypothesis`` is a declared test dependency (pyproject ``[test]``
  extra), but the hermetic CI container cannot pip-install it; when the
  real package is missing, a deterministic fixed-seed fallback
  (repro._compat.hypothesis_fallback) fills the import so the four
  property-test modules still collect and run.
"""

import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback

    hypothesis_fallback.install()
