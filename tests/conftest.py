"""Suite bootstrap.

* Fast lane: ``pytest -m "not slow"`` skips the end-to-end install and
  subprocess-spawning distributed suites (the ``slow`` marker is
  registered in pyproject.toml).
* ``hypothesis`` is a declared test dependency (pyproject ``[test]``
  extra), but the hermetic CI container cannot pip-install it; when the
  real package is missing, a deterministic fixed-seed fallback
  (repro._compat.hypothesis_fallback) fills the import so the four
  property-test modules still collect and run.
* ``pytest-timeout`` is likewise declared but not installable here;
  when missing, a SIGALRM fallback plugin
  (repro._compat.pytest_timeout_fallback) enforces the suite's
  ``--timeout`` / ``@pytest.mark.timeout`` budgets so a wedged
  subprocess test fails instead of hanging the lane.
"""

import dataclasses
import os
import sys

import pytest

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback

    hypothesis_fallback.install()

try:
    import pytest_timeout  # noqa: F401

    _timeout_fallback = None
except ModuleNotFoundError:
    from repro._compat import pytest_timeout_fallback as _timeout_fallback


def pytest_addoption(parser):
    if _timeout_fallback is not None:
        _timeout_fallback.addoption(parser)


def pytest_configure(config):
    if _timeout_fallback is not None:
        config.pluginmanager.register(_timeout_fallback,
                                      "timeout-fallback")


@dataclasses.dataclass
class InstallRun:
    """Everything a test needs from one shared install run."""

    dir: str
    cfg: object          # InstallConfig
    backend: object      # SimulatedBackend
    data: object         # GatheredData
    report: object       # InstallReport


@pytest.fixture(scope="session")
def tiny_artifact(tmp_path_factory) -> InstallRun:
    """One real, minimal-budget, mixed-routine install shared by
    test_tuner, test_system and the routine property tests — replacing
    the per-module ``install()`` runs that duplicated ~identical
    artifacts."""
    from repro.core import (InstallConfig, SimulatedBackend, gather_data,
                            install)

    d = tmp_path_factory.mktemp("tiny_artifact")
    cfg = InstallConfig(
        n_samples=48, repeats=2, tile_ids=(0, 3),
        models=("linear_regression", "decision_tree", "xgboost"),
        routines=("gemm", "syrk", "trsm", "attn"),
        grid_budget="small", cv_splits=3, seed=0)
    backend = SimulatedBackend(seed=0)
    data = gather_data(backend, cfg)
    report = install(backend, cfg, data=data, artifact_dir=str(d))
    return InstallRun(dir=str(d), cfg=cfg, backend=backend, data=data,
                      report=report)
