"""Concurrent arrivals + live artifact swaps against the scheduler.

Three pressure sources at once: submitter threads feeding the request
queue, the consumer thread draining it through the paged decode loop,
and a swapper thread firing :meth:`ReinstallManager.swap_now` between
two artifacts mid-stream.  Contracts under fire:

* zero dropped sequences — every submitted rid finishes exactly once,
  with exactly ``max_new`` tokens;
* zero cross-contamination — identical (prompt, max_new) pairs
  submitted from different threads decode to identical tokens (greedy
  argmax is deterministic; a stale page or torn cache would break it);
* every recorded dispatch was served entirely by ONE artifact: each
  event's config is artifact A's choice for that key or artifact B's —
  never a third value (the PR-8 atomicity contract, now observed
  through real serving traffic instead of a synthetic hammer).

The two artifacts are installed with disjoint tile sets so "which
artifact served this dispatch" is decidable from the chosen config.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import build_model, get_smoke_config
from repro.core.installer import InstallConfig, install
from repro.core.timing import SimulatedBackend
from repro.core.tuner import AdsalaTuner
from repro.kernels.recorder import DispatchRecorder
from repro.serve import ReinstallManager
from repro.serve.scheduler import ContinuousBatchingScheduler

pytestmark = pytest.mark.timeout(300)

_TILES_A = (0, 1, 2)
_TILES_B = (5, 6, 7)


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    root = tmp_path_factory.mktemp("sched_race")
    dirs = {}
    for name, tiles in (("a", _TILES_A), ("b", _TILES_B)):
        d = str(root / name)
        install(SimulatedBackend(seed=0),
                InstallConfig(n_samples=48, repeats=1,
                              routines=("gemm", "syrk", "trsm"),
                              models=("decision_tree",),
                              tile_ids=tiles, seed=3),
                artifact_dir=d)
        dirs[name] = d
    return dirs


def test_concurrent_arrivals_with_live_swaps(arts):
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    recs = {"prefill": DispatchRecorder(), "decode": DispatchRecorder()}
    mgr = ReinstallManager(arts["a"], recs,
                           backend=SimulatedBackend(seed=0))
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=3, n_pages=24, page_size=4,
        max_seq_len=16, tuner=mgr, recorders=recs)

    rng = np.random.default_rng(5)
    probe = rng.integers(0, cfg.vocab, 5).tolist()
    expected: dict[int, tuple] = {}     # rid -> (prompt, max_new)
    errors: list = []
    done_submitting = threading.Event()

    def submitter(tid: int) -> None:
        try:
            trng = np.random.default_rng(100 + tid)
            for i in range(5):
                if i == 2:              # every thread replays the probe
                    prompt, new = probe, 4
                else:
                    prompt = trng.integers(
                        0, cfg.vocab, int(trng.integers(3, 10))).tolist()
                    new = int(trng.integers(2, 6))
                rid = sched.submit(prompt, new)
                with lock:
                    expected[rid] = (tuple(prompt), new)
                time.sleep(0.002 * tid)
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    def swapper() -> None:
        try:
            i = 0
            while not done_submitting.is_set() or sched.active \
                    or sched.pending:
                mgr.swap_now(arts["b"] if i % 2 == 0 else arts["a"])
                i += 1
                time.sleep(0.003)
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    lock = threading.Lock()
    subs = [threading.Thread(target=submitter, args=(t,))
            for t in range(3)]
    swap = threading.Thread(target=swapper)
    for t in subs:
        t.start()
    swap.start()
    try:
        # drain while submitters are still feeding: loop until all
        # submitter threads finished AND the scheduler went idle
        while any(t.is_alive() for t in subs) or sched.pending \
                or sched.active:
            sched.step()
    finally:
        done_submitting.set()
        for t in subs:
            t.join()
        swap.join()

    assert not errors, errors
    finished = sched.finished

    # -- zero drops: every rid exactly once, full length ----------------
    assert sorted(finished) == sorted(expected)
    for rid, (prompt, new) in expected.items():
        f = finished[rid]
        assert f.prompt == prompt
        assert len(f.tokens) == new, f"rid {rid} truncated"

    # -- zero cross-contamination: probe replays identical --------------
    probe_tokens = {finished[r].tokens for r, (p, n) in expected.items()
                    if p == tuple(probe) and n == 4}
    assert len(probe_tokens) == 1, \
        f"identical requests decoded differently: {probe_tokens}"

    # -- pool conservation after the storm ------------------------------
    sched.alloc.check()
    assert sched.alloc.live_pages == 0
    assert mgr.swaps > 0, "no swap ever fired mid-stream"

    # -- exactly one artifact per dispatch ------------------------------
    tuners = {name: AdsalaTuner.from_artifact(d)
              for name, d in arts.items()}
    events = [e for rec in recs.values() for e in rec.events
              if e.config is not None]
    assert events, "no tuned dispatches recorded"
    torn = []
    for e in events:
        legal = {t.select(e.m, e.k, e.n, e.routine)
                 for t in tuners.values()}
        if e.config not in legal:
            torn.append((e.site, e.routine, e.m, e.k, e.n, e.config))
    assert not torn, f"dispatches served by no single artifact: {torn[:3]}"
