"""Distributed behaviour on simulated host devices.

XLA locks the device count at first jax init, so these tests run their
bodies in subprocesses with XLA_FLAGS set — the same pattern the
dry-run uses.

On small hosts (<= 2 CPU cores, e.g. the CI container) the 8-device
shard_map compiles blow the 420 s subprocess budget, so the spawned
world shrinks to a 2-device (1, 2) mesh and the per-case work scales
down with it.  Set ``ADSALA_DIST_FULL=1`` (or run on a bigger host) for
the full-size 8-device meshes.
"""

import os
import subprocess
import sys
import textwrap

import pytest

# per-test wall budget: the subprocess itself is capped at 420 s below,
# so 480 s only triggers when the parent wedges outside subprocess.run
# (enforced by pytest-timeout, or its signal fallback in conftest)
pytestmark = [pytest.mark.slow, pytest.mark.timeout(480)]

_FULL = ((os.cpu_count() or 1) > 2
         or os.environ.get("ADSALA_DIST_FULL") == "1")
_DEVICES = 8 if _FULL else 2
_MESH_A = (2, 4) if _FULL else (1, 2)    # save / main mesh
_MESH_B = (4, 2) if _FULL else (2, 1)    # elastic-restore mesh


def _run(body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={_DEVICES}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        MESH_A = {_MESH_A!r}
        MESH_B = {_MESH_B!r}
    """) + textwrap.dedent(body)
    # Inherit the parent environment: a stripped env (the original
    # hermetic {PYTHONPATH, PATH, HOME}) drops JAX_PLATFORMS=cpu, and
    # jax's platform probing then stalls for minutes per subprocess —
    # that, not compile time, was what blew the 420 s budget on the CI
    # container.  Force the cpu platform either way.
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)    # the script pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420, env=env)
    assert proc.returncode == 0, f"STDOUT:{proc.stdout}\nERR:{proc.stderr}"
    return proc.stdout


def test_moe_ep_matches_dense():
    """shard_map expert-parallel MoE == dense one-hot MoE (no drops)."""
    out = _run("""
        import dataclasses
        from repro.models.moe import (MoESpec, moe_defs, apply_moe,
                                      apply_moe_ep)
        from repro.models.params import init_params
        from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh(MESH_A, ("data", "model"))
        s = MoESpec(d_model=32, n_experts=8, top_k=2, d_ff=64,
                    capacity_factor=8.0, ep_axis="model")
        p = init_params(moe_defs(s), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

        dense_out, dense_aux = apply_moe(p, x, s)

        def f(pl, xl):
            out, aux = apply_moe_ep(pl, xl, s)
            return out, jax.lax.pmean(aux, ("data", "model"))
        w_specs = {k: (P() if k.startswith(("router", "shared"))
                       else P("model", None, None)) for k in p}
        ep_out, ep_aux = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(w_specs, P("data", "model", None)),
            out_specs=(P("data", "model", None), P()),
            check_rep=False))(p, x)
        err = float(jnp.abs(dense_out - ep_out).max())
        # EP routes per-shard (local top-k == global top-k for the same
        # tokens); with no capacity drops outputs must match exactly
        print("err", err)
        assert err < 1e-4, err
    """)
    assert "err" in out


def test_moe_tp_matches_dense():
    """Expert-TP path (ff-sharded experts) == dense path."""
    _run("""
        from repro.models.moe import (MoESpec, moe_defs, apply_moe,
                                      apply_moe_tp)
        from repro.models.params import init_params
        from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh(MESH_A, ("data", "model"))
        s = MoESpec(d_model=32, n_experts=6, top_k=2, d_ff=64,
                    capacity_factor=8.0, ep_axis="model")
        p = init_params(moe_defs(s), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
        dense_out, _ = apply_moe(p, x, s)

        def f(pl, xl):
            out, aux = apply_moe_tp(pl, xl, s)
            return out, jax.lax.pmean(aux, ("data", "model"))
        w_specs = {}
        for k in p:
            if k.startswith(("router", "shared")):
                w_specs[k] = P()
            elif k == "wo":
                w_specs[k] = P(None, "model", None)
            else:
                w_specs[k] = P(None, None, "model")
        tp_out, _ = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(w_specs, P("data", None, None)),
            out_specs=(P("data", None, None), P()),
            check_rep=False))(p, x)
        err = float(jnp.abs(dense_out - tp_out).max())
        assert err < 1e-4, err
    """)


def test_sharded_train_step_runs():
    """A real (executed, not just lowered) sharded train step on the
    scaled mesh with a reduced config: loss decreases over a few steps."""
    _run("""
        from repro.configs import get_smoke_config, build_model
        from repro.train.optim import AdamWConfig
        from repro.train.step import build_train_step, init_train_state
        from repro.models.config import ShapeSpec

        mesh = jax.make_mesh(MESH_A, ("data", "model"))
        cfg = get_smoke_config("granite-8b")
        model = build_model(cfg)
        shape = ShapeSpec("t", 32, 4, "train")
        step_fn, s_specs, b_specs = build_train_step(
            model, cfg, shape, mesh, AdamWConfig(lr=1e-2, warmup_steps=1,
                                                 total_steps=20))
        state = init_train_state(model, cfg, AdamWConfig(),
                                 jax.random.PRNGKey(0))
        state = jax.device_put(
            state, jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                                s_specs))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 cfg.vocab)
        batch = jax.device_put(
            {"tokens": tok, "labels": tok},
            jax.tree.map(lambda sp: NamedSharding(mesh, sp), b_specs))
        losses = []
        for _ in range(8):
            state, metrics = jit_step(state, batch)
            losses.append(float(metrics["loss"]))
        print("losses", losses[0], losses[-1])
        assert losses[-1] < losses[0], losses
    """)


def test_elastic_checkpoint_reshard():
    """Save on one mesh, restore onto its transpose — elastic restart."""
    _run("""
        import tempfile
        from repro.ckpt.checkpoint import (save_checkpoint,
                                           restore_checkpoint)
        mesh_a = jax.make_mesh(MESH_A, ("data", "model"))
        mesh_b = jax.make_mesh(MESH_B, ("data", "model"))
        w = jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", "model")))
        state = {"params": {"w": w}}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, state)
        restored = restore_checkpoint(
            d, 1, state, mesh=mesh_b,
            specs={"params": {"w": P("data", "model")}})
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.asarray(w))
        shard_shape = restored["params"]["w"].sharding.shard_shape((8, 8))
        expect = (8 // MESH_B[0], 8 // MESH_B[1])
        assert shard_shape == expect, (shard_shape, expect)
    """)
