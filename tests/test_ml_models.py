"""Model-zoo behaviour: learnability, persistence, packed inference."""

import numpy as np
import pytest

from repro.core.ml import (
    AdaBoostR2Regressor,
    BayesianRidgeRegression,
    DecisionTreeRegressor,
    ElasticNetRegression,
    HistGradientBoostingRegressor,
    KFold,
    KNNRegressor,
    LinearRegression,
    RandomForestRegressor,
    RidgeRegression,
    XGBRegressor,
    grid_search,
    rmse,
    stratified_train_test_split,
)
from repro.core.ml.registry import MODEL_REGISTRY, model_from_dict
from repro.core.ml.tree import PackedEnsemble, tree_predict, tree_predict_row


def _dataset(n=400, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, 5))
    y = (2.0 * X[:, 0] - X[:, 1] ** 2 + np.sin(3 * X[:, 2])
         + 0.05 * rng.standard_normal(n))
    return X, y


LEARNERS = [
    (LinearRegression, {}, 0.9),
    (RidgeRegression, {"alpha": 0.1}, 0.9),
    (ElasticNetRegression, {"alpha": 0.001}, 0.9),
    (BayesianRidgeRegression, {}, 0.9),
    (DecisionTreeRegressor, {"max_depth": 8}, 0.5),
    (RandomForestRegressor, {"n_estimators": 30, "max_depth": 10}, 0.4),
    (AdaBoostR2Regressor, {"n_estimators": 15, "max_depth": 5}, 0.6),
    (XGBRegressor, {"n_estimators": 80, "max_depth": 4}, 0.3),
    (HistGradientBoostingRegressor, {"n_estimators": 80}, 0.3),
    (KNNRegressor, {"k": 5}, 0.5),
]


@pytest.mark.parametrize("cls,params,max_nrmse",
                         LEARNERS, ids=[c.__name__ for c, _, _ in LEARNERS])
def test_model_learns(cls, params, max_nrmse):
    X, y = _dataset()
    Xtr, Xte, ytr, yte = stratified_train_test_split(X, y, seed=0)
    model = cls(**params).fit(Xtr, ytr)
    base = rmse(yte, np.full_like(yte, ytr.mean()))
    assert rmse(yte, model.predict(Xte)) < max_nrmse * base


@pytest.mark.parametrize("cls,params,_",
                         LEARNERS, ids=[c.__name__ for c, _, _ in LEARNERS])
def test_model_persistence_roundtrip(cls, params, _):
    X, y = _dataset(150, seed=1)
    model = cls(**params).fit(X, y)
    clone = model_from_dict(model.to_dict())
    np.testing.assert_allclose(model.predict(X[:20]), clone.predict(X[:20]),
                               rtol=1e-10, atol=1e-10)


def test_packed_ensemble_matches_per_tree():
    X, y = _dataset(200, seed=2)
    forest = RandomForestRegressor(n_estimators=12, max_depth=6,
                                   seed=3).fit(X, y)
    packed = PackedEnsemble(forest.trees_)
    naive = np.stack([tree_predict(t, X[:31]) for t in forest.trees_],
                     axis=1)
    np.testing.assert_allclose(packed.predict_all(X[:31]), naive,
                               atol=1e-12)


def test_packed_ensemble_matches_scalar_row_walk():
    """The multi-row lane walk == scalar per-row descent, per tree."""
    X, y = _dataset(200, seed=5)
    forest = RandomForestRegressor(n_estimators=10, max_depth=7,
                                   seed=6).fit(X, y)
    packed = PackedEnsemble(forest.trees_)
    got = packed.predict_all(X[:17])
    want = np.array([[tree_predict_row(t, x) for t in forest.trees_]
                     for x in X[:17]])
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_packed_ensemble_retires_shallow_trees():
    """Mixed-depth ensembles (stumps next to deep CARTs) stay exact: the
    lane walk retires finished (row, tree) pairs, it must not move them."""
    X, y = _dataset(300, seed=7)
    trees = (
        [DecisionTreeRegressor(max_depth=1).fit(X, y).tree_] * 3
        + [DecisionTreeRegressor(max_depth=12).fit(X, y).tree_]
    )
    packed = PackedEnsemble(trees)
    assert packed.max_depth > 1
    naive = np.stack([tree_predict(t, X) for t in trees], axis=1)
    np.testing.assert_allclose(packed.predict_all(X), naive, atol=1e-12)


def test_packed_ensemble_single_node_trees():
    """All-leaf ensembles (0 splits) short-circuit the walk entirely."""
    X = np.zeros((5, 2))
    tree = DecisionTreeRegressor(max_depth=0).fit(X, np.full(5, 3.25)).tree_
    packed = PackedEnsemble([tree, tree])
    np.testing.assert_allclose(packed.predict_all(X), 3.25)


def test_ensemble_predict_matches_per_row_dispatch():
    """Batch predict == concatenated single-row predicts for every
    packed-ensemble regressor (the select_many vs scalar-dispatch parity
    the tuner relies on)."""
    X, y = _dataset(250, seed=8)
    for cls, params in [
        (RandomForestRegressor, {"n_estimators": 8, "max_depth": 6}),
        (XGBRegressor, {"n_estimators": 20, "max_depth": 4}),
        (AdaBoostR2Regressor, {"n_estimators": 8, "max_depth": 4}),
        (HistGradientBoostingRegressor, {"n_estimators": 20}),
    ]:
        model = cls(**params).fit(X, y)
        batched = model.predict(X[:13])
        scalar = np.concatenate([model.predict(X[i:i + 1])
                                 for i in range(13)])
        np.testing.assert_allclose(batched, scalar, atol=1e-12,
                                   err_msg=cls.__name__)


def test_kfold_partitions_everything():
    y = np.random.default_rng(4).standard_normal(103)
    kf = KFold(n_splits=5, seed=0)
    seen = np.zeros(103, dtype=int)
    for train, val in kf.split(y):
        assert len(np.intersect1d(train, val)) == 0
        seen[val] += 1
    np.testing.assert_array_equal(seen, 1)


def test_stratified_split_balances_label_quantiles():
    rng = np.random.default_rng(5)
    y = rng.lognormal(0, 2, 600)
    X = rng.standard_normal((600, 2))
    _, _, ytr, yte = stratified_train_test_split(X, y, test_fraction=0.3,
                                                 seed=1)
    assert abs(len(yte) / 600 - 0.3) < 0.05
    assert abs(np.median(np.log(ytr)) - np.median(np.log(yte))) < 0.4


def test_grid_search_picks_sane_depth():
    X, y = _dataset(300, seed=6)
    best, score = grid_search(
        lambda **p: DecisionTreeRegressor(**p),
        {"max_depth": [1, 8], "min_samples_leaf": [2]}, X, y, n_splits=3)
    assert best["max_depth"] == 8
    assert np.isfinite(score)


def test_registry_complete():
    assert set(MODEL_REGISTRY) >= {
        "linear_regression", "elasticnet", "bayesian_regression",
        "decision_tree", "random_forest", "adaboost", "xgboost",
        "lightgbm", "knn"}
