"""End-to-end dispatch observability: serve/train steps under a
DispatchRecorder, routine-tagged call-site parity, legacy-artifact gemm
fallback, and the recorder's own semantics (nesting, thread isolation,
zero-overhead-when-inactive)."""

import json
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import build_model, get_smoke_config
from repro.core import AdsalaTuner
from repro.kernels import ops, recorder
from repro.kernels.recorder import DispatchRecorder
from repro.models.config import ShapeSpec
from repro.models.layers import AttnSpec, attention_decode, attention_train
from repro.serve.step import build_decode, build_prefill
from repro.train.step import build_train_step, train_batch_sds

B, S = 2, 16


def _shape(kind: str) -> ShapeSpec:
    return ShapeSpec(f"tiny_{kind}", S, B, kind)


def _serve_once(arch: str, tuner, rec: DispatchRecorder) -> None:
    """One eager prefill + one decode step inside ``rec``."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    prefill, _, _ = build_prefill(model, cfg, _shape("prefill"), None,
                                  tuner=tuner)
    decode, _, _ = build_decode(model, cfg, _shape("decode"), None,
                                tuner=tuner)
    with rec:
        logits, cache = prefill(params, {"tokens": tokens})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        decode(params, tok, cache, jnp.int32(S - 1))


# ---------------------------------------------------------------------------
# End-to-end serve / train steps (acceptance criteria)
# ---------------------------------------------------------------------------

def test_serve_step_records_nontrivial_routine_mix(tiny_artifact):
    """A serve prefill+decode step records >= 2 distinct routines:
    prefill self-attention dispatches ATTN (or the tuner's SYRK score
    materialisation when predicted faster), the decode cache update
    dispatches TRSM, everything else GEMM."""
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    rec = DispatchRecorder()
    _serve_once("stablelm-1.6b", tuner, rec)

    mix = rec.routine_mix()
    assert len(mix) >= 2, f"trivial routine mix {mix}"
    assert set(mix) <= {"gemm", "syrk", "trsm", "attn"}
    assert abs(sum(mix.values()) - 1.0) < 1e-9
    # prefill self-attention dispatched through ops.flash_attention:
    # either one attn event or the per-head syrk score path, both on
    # the per-head (S, Dh, S) triple with B*H batch multiplicity so the
    # flops-weighted mix doesn't under-count score volume
    core_events = [e for e in rec.sites("attn.core")
                   if e.routine in ("attn", "syrk")]
    assert core_events, "prefill attention recorded no attn/syrk event"
    assert all(e.m == e.n == S for e in core_events)
    cfg = get_smoke_config("stablelm-1.6b")
    assert all(e.count == B * cfg.n_heads for e in core_events)
    # decode cache update is TRSM-tagged
    trsm_events = [e for e in rec.sites("attn.cache_update")]
    assert trsm_events and all(e.routine == "trsm" for e in trsm_events)
    # the tuner was actually consulted: events carry chosen configs
    assert all(e.config is not None for e in rec.events)
    # attn events surface the resolved flash config knobs
    for e in core_events:
        if e.routine == "attn":
            assert e.config.flash_grid in ("dense", "tri")
            assert e.config.flash_block[0] >= 128


def test_events_carry_tuner_cache_hits(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    tuner._cache.clear()                       # drop the warm-start set
    a = jnp.ones((37, 19), jnp.float32)
    b = jnp.ones((19, 11), jnp.float32)
    with DispatchRecorder() as rec:
        ops.matmul(a, b, tuner=tuner)
        ops.matmul(a, b, tuner=tuner)
    assert [e.cache_hit for e in rec.events] == [False, True]
    assert rec.events[0].config == rec.events[1].config


def test_moe_records_grouped_gemm_per_expert_shapes(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    cfg = get_smoke_config("mixtral-8x22b")
    rec = DispatchRecorder()
    _serve_once("mixtral-8x22b", tuner, rec)

    for site in ("moe.wi", "moe.wg", "moe.wo"):
        events = rec.sites(site)
        assert events, f"no events at {site}"
        assert all(e.routine == "gemm" for e in events)
        # one event per expert per traced grouped call
        assert len(events) % cfg.n_experts == 0
        # per-expert shapes: every expert runs its capacity bucket
        m0, k0, n0 = events[0].m, events[0].k, events[0].n
        assert all((e.m, e.k, e.n) == (m0, k0, n0)
                   for e in events[:cfg.n_experts])
    # grouped lookups flow through ONE select_many per call: far fewer
    # evaluations than calls
    assert tuner.stats["evaluations"] < tuner.stats["calls"]


def test_mla_latent_projections_and_cache_update(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    rec = DispatchRecorder()
    _serve_once("deepseek-v2-236b", tuner, rec)

    assert rec.sites("mla.down_proj") and rec.sites("mla.up_proj_kv")
    assert all(e.routine == "gemm" for e in rec.sites("mla.down_proj"))
    cache_events = rec.sites("mla.cache_update")
    assert cache_events and all(e.routine == "trsm" for e in cache_events)
    assert {"gemm", "syrk", "trsm"} <= {e.routine for e in rec.events}


def test_train_step_tags_backward_contractions(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    step, _, _ = build_train_step(model, cfg, _shape("train"), None,
                                  tuner=tuner)
    from repro.train.optim import AdamWConfig, init_state
    state = init_state(model.init(jax.random.PRNGKey(0)), AdamWConfig())
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    with DispatchRecorder() as rec:
        _, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])

    fwd = [e for e in rec.events if not e.site.startswith("bwd")]
    bwd = [e for e in rec.events if e.site.startswith("bwd")]
    # two AD-transposed contractions per forward event, all gemm
    assert len(bwd) == 2 * len(fwd) > 0
    assert all(e.routine == "gemm" for e in bwd)
    # bwd events are appended in forward order: dX then dW per event,
    # with the AD-transposed (m, k, n) triples
    f0 = fwd[0]
    assert bwd[0].site == f"bwd.dx[{f0.site}]"
    assert (bwd[0].m, bwd[0].k, bwd[0].n) == (f0.m, f0.n, f0.k)
    assert bwd[1].site == f"bwd.dw[{f0.site}]"
    assert (bwd[1].m, bwd[1].k, bwd[1].n) == (f0.k, f0.m, f0.n)


# ---------------------------------------------------------------------------
# Parity: routine-tagged call sites == pre-existing gemm-path outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_syrk_qk_matches_gemm_path(backend):
    """ops.syrk(Q, K) == tril(Q @ K^T) — the gemm path the attention
    scores used before routine tagging — on both backends."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    got = ops.syrk(q, k, backend=backend, interpret=True)
    want = jnp.tril(q @ k.T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_attention_train_parity_vs_pre_syrk_path(backend, monkeypatch):
    """attention_train with the untuned SYRK score lowering matches the
    chunked XLA / flash path to fp32 tolerance."""
    monkeypatch.setenv("ADSALA_BACKEND", backend)
    spec = AttnSpec(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (B, 24, 32), jnp.float32)
    p = {
        "wq": jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.1,
        "wk": jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * 0.1,
        "wv": jax.random.normal(jax.random.PRNGKey(3), (32, 32)) * 0.1,
        "wo": jax.random.normal(jax.random.PRNGKey(4), (32, 32)) * 0.1,
    }
    out_tagged, _ = attention_train(p, x, spec)
    # force the non-materialised path by disabling the untuned SYRK
    # fallback threshold
    monkeypatch.setattr(ops, "SYRK_FALLBACK_MAX_SEQ", 0)
    out_legacy, _ = attention_train(p, x, spec)
    np.testing.assert_allclose(np.asarray(out_tagged),
                               np.asarray(out_legacy),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_decode_cache_update_parity(backend, tiny_artifact, monkeypatch):
    """The TRSM-tagged decode cache update is a hint: tuned and untuned
    decode produce identical outputs on both backends."""
    monkeypatch.setenv("ADSALA_BACKEND", backend)
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    spec = AttnSpec(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    cache_shape = (B, 8, 2, 16)
    cache = L.KVCache(
        jax.random.normal(jax.random.PRNGKey(5), cache_shape),
        jax.random.normal(jax.random.PRNGKey(6), cache_shape), False)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, 1, 32), jnp.float32)
    p = {
        "wq": jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.1,
        "wk": jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * 0.1,
        "wv": jax.random.normal(jax.random.PRNGKey(3), (32, 32)) * 0.1,
        "wo": jax.random.normal(jax.random.PRNGKey(4), (32, 32)) * 0.1,
    }
    out_plain, _ = attention_decode(p, x, spec, cache, jnp.int32(4))
    with DispatchRecorder() as rec:
        out_tuned, _ = attention_decode(p, x, spec, cache, jnp.int32(4),
                                        tuner=tuner)
    assert any(e.routine == "trsm" for e in rec.events)
    np.testing.assert_allclose(np.asarray(out_tuned),
                               np.asarray(out_plain), atol=0, rtol=0)


# ---------------------------------------------------------------------------
# Legacy (gemm-only) artifact: call sites fall back instead of raising
# ---------------------------------------------------------------------------

def test_legacy_gemm_only_artifact_falls_back_to_gemm(tiny_artifact,
                                                      tmp_path):
    """A v1/gemm-only artifact serving routine-tagged call sites must
    degrade every syrk/trsm dispatch to gemm (recorder shows gemm),
    not raise — the call-site side of the tuner's 'refuses uninstalled
    routines' guard."""
    legacy = tmp_path / "gemm_only"
    shutil.copytree(tiny_artifact.dir, legacy)
    cfg_path = legacy / "config.json"
    config = json.load(open(cfg_path))
    config.setdefault("install", {})["routines"] = ["gemm"]
    config["warm_start"] = None
    json.dump(config, open(cfg_path, "w"))
    tuner = AdsalaTuner.from_artifact(str(legacy))
    assert tuner.routines == ("gemm",)
    # the tuner itself still refuses direct syrk asks...
    with pytest.raises(ValueError, match="no training signal"):
        tuner.select(64, 64, 64, "syrk")

    # ...but the serve step degrades instead of raising
    rec = DispatchRecorder()
    _serve_once("stablelm-1.6b", tuner, rec)
    assert rec.events
    rec.assert_only(["gemm"])          # every event fell back
    assert all(e.config is not None for e in rec.events)


def test_supported_routine_validates_and_falls_back(tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    assert ops.supported_routine("syrk", None) == "syrk"
    assert ops.supported_routine("syrk", tuner) == "syrk"
    with pytest.raises(ValueError, match="unknown routine"):
        ops.supported_routine("cholesky", tuner)
    with pytest.raises(ValueError, match="unknown routine"):
        ops.dispatch_hint(8, 8, 8, None, routine="herk")
    with pytest.raises(ValueError, match="unknown routine"):
        ops.grouped_dispatch_hint([(8, 8, 8)], None, routine="trmm")


# ---------------------------------------------------------------------------
# Recorder semantics
# ---------------------------------------------------------------------------

def test_recorder_nesting_outer_aggregates_inner():
    a = jnp.ones((8, 4), jnp.float32)
    b = jnp.ones((4, 8), jnp.float32)
    with DispatchRecorder() as outer:
        ops.matmul(a, b, site="first")
        with DispatchRecorder() as inner:
            ops.matmul(a, b, site="second")
        ops.matmul(a, b, site="third")
    assert [e.site for e in inner.events] == ["second"]
    assert [e.site for e in outer.events] == ["first", "second", "third"]


def test_recorder_thread_local_isolation():
    a = jnp.ones((8, 4), jnp.float32)
    b = jnp.ones((4, 8), jnp.float32)
    worker_events = []
    barrier_err = []

    def worker():
        try:
            # the main thread's recorder must not see this...
            ops.matmul(a, b, site="worker.untracked")
            # ...and a worker-local recorder sees only its own
            with DispatchRecorder() as wrec:
                ops.matmul(a, b, site="worker.tracked")
            worker_events.extend(wrec.events)
        except Exception as e:  # pragma: no cover - surfaced below
            barrier_err.append(e)

    with DispatchRecorder() as rec:
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        ops.matmul(a, b, site="main")
    assert not barrier_err
    assert [e.site for e in rec.events] == ["main"]
    assert [e.site for e in worker_events] == ["worker.tracked"]


def test_record_is_noop_when_inactive():
    assert not recorder.active()
    recorder.record("gemm", 8, 8, 8)           # must not raise
    assert recorder.active_event_count() == 0
    with DispatchRecorder() as rec:
        assert recorder.active()
    # exited recorder no longer accumulates
    recorder.record("gemm", 8, 8, 8)
    assert rec.events == []
    # and ops run identically with nobody watching
    a = jnp.ones((8, 4), jnp.float32)
    b = jnp.ones((4, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.matmul(a, b)),
                                  np.asarray(a @ b))


def test_summary_routine_mix_and_assert_only():
    with DispatchRecorder() as rec:
        recorder.record("gemm", 64, 64, 64, site="a")
        recorder.record("gemm", 64, 64, 64, cache_hit=True, site="a")
        recorder.record("syrk", 64, 64, 64, site="b")
    s = rec.summary()
    assert s["gemm"]["events"] == 2 and s["gemm"]["cache_hits"] == 1
    # syrk charges the triangular fraction: half a gemm's flops here
    assert s["syrk"]["flops"] == pytest.approx(s["gemm"]["flops"] / 4)
    mix_e = rec.routine_mix(by="events")
    assert mix_e == {"gemm": pytest.approx(2 / 3),
                     "syrk": pytest.approx(1 / 3)}
    mix_f = rec.routine_mix()
    assert mix_f["gemm"] == pytest.approx(0.8)
    assert mix_f["syrk"] == pytest.approx(0.2)
    rec.assert_only(["gemm", "syrk"])
    with pytest.raises(AssertionError, match="outside allowed"):
        rec.assert_only(["gemm"])
    with pytest.raises(ValueError, match="expected 'flops'"):
        rec.routine_mix(by="bytes")
    rec.clear()
    assert rec.routine_mix() == {}


def test_event_count_weights_flops_and_event_mix():
    """A vmapped site traced once with count=N weighs like N dispatches."""
    with DispatchRecorder() as rec:
        recorder.record("gemm", 64, 64, 64)
        recorder.record("syrk", 64, 64, 64, count=8)
    e_gemm, e_syrk = rec.events
    assert e_syrk.flops == pytest.approx(8 * 0.5 * e_gemm.flops)
    s = rec.summary()
    assert s["syrk"]["events"] == 1 and s["syrk"]["dispatches"] == 8
    mix_e = rec.routine_mix(by="events")
    assert mix_e["syrk"] == pytest.approx(8 / 9)
    mix_f = rec.routine_mix()
    assert mix_f["syrk"] == pytest.approx(4 / 5)


def test_explicit_tile_bypasses_tuner_and_config_label(tiny_artifact):
    """An explicit tile overrides the tuner: no consult, and the event
    must not claim a config that was never dispatched."""
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    calls_before = tuner.stats["calls"]
    a = jnp.ones((16, 8), jnp.float32)
    b = jnp.ones((8, 16), jnp.float32)
    with DispatchRecorder() as rec:
        ops.matmul(a, b, tuner=tuner, tile=(8, 8, 8))
    assert tuner.stats["calls"] == calls_before
    assert rec.events[0].config is None


def test_grouped_dispatch_hint_records_per_expert():
    shapes = [(32, 16, 24)] * 3
    with DispatchRecorder() as rec:
        hints = ops.grouped_dispatch_hint(shapes, None, site="moe.test")
    assert hints is None                        # untuned: no configs...
    assert len(rec.events) == 3                 # ...but still observable
    assert all(e.site == "moe.test" and e.routine == "gemm"
               for e in rec.events)


def test_syrk_rejects_mismatched_second_operand():
    a = jnp.ones((8, 4), jnp.float32)
    with pytest.raises(ValueError, match="SYRK-shaped"):
        ops.syrk(a, jnp.ones((6, 4), jnp.float32))


def test_observe_skips_tuner_when_no_recorder(tiny_artifact):
    """Observability-only sites must not pay tuner lookups (or pollute
    its LRU with fused hint shapes) when nobody is watching."""
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    calls_before = dict(tuner.stats)
    ops.observe(23, 29, 31, tuner, routine="syrk", site="idle")
    assert tuner.stats == calls_before
    assert not tuner.peek(23, 29, 31, "syrk")
    with pytest.raises(ValueError, match="unknown routine"):
        ops.observe(8, 8, 8, tuner, routine="herk")   # validated anyway
    with DispatchRecorder() as rec:
        ops.observe(23, 29, 31, tuner, routine="syrk", site="watched")
    assert rec.events[0].config is not None           # consulted now


def test_windowed_attention_tagged_gemm_not_syrk():
    """A sliding-window layer consumes a band, not the triangle — it
    must not record (or price) as SYRK."""
    spec = AttnSpec(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                    window=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (B, 24, 32), jnp.float32)
    p = {
        "wq": jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.1,
        "wk": jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * 0.1,
        "wv": jax.random.normal(jax.random.PRNGKey(3), (32, 32)) * 0.1,
        "wo": jax.random.normal(jax.random.PRNGKey(4), (32, 32)) * 0.1,
    }
    with DispatchRecorder() as rec:
        attention_train(p, x, spec)
    qk = rec.sites("attn.qk")
    assert qk and all(e.routine == "gemm" for e in qk)
