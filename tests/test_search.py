"""The compositional search harness: ConfigSpace + SearchGraph + beam.

Three contracts anchor the refactor:

* the default space's exhaustive enumeration is bit-for-bit the
  historical ``candidate_configs`` grid (every artifact pin survives);
* a full-width, full-depth beam returns exactly the exhaustive argmin
  for every routine (ties included — first-occurrence order);
* a narrow beam over the ~11x enlarged space finds the optimum while
  pricing a small fraction of it (the smoke benchmark's claim).

Runs under real `hypothesis` or the deterministic
``repro._compat.hypothesis_fallback`` shim — only ``integers`` /
``sampled_from`` strategies and ``given``/``settings`` are used.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdsalaTuner,
    Axis,
    ConfigSpace,
    Gate,
    GemmConfig,
    SearchGraph,
    beam_search,
    candidate_configs,
    exhaustive_best,
    gather_data,
    install,
)
from repro.core.costmodel import (
    DEFAULT_TILES,
    EXTENDED_TILES,
    TRSM_SEQ_CHIPS,
    chip_doublings,
)
from repro.core.installer import InstallConfig
from repro.core.timing import SimulatedBackend

# ---------------------------------------------------------------------------
# ConfigSpace: enumeration parity, gates, serialisation, sampling
# ---------------------------------------------------------------------------


def _legacy_candidate_loop(max_chips, tiles, partitions):
    """The pre-refactor candidate_configs triple loop, re-rolled."""
    out = []
    for c in chip_doublings(max_chips):
        for p in partitions:
            if p == "2D" and c < 4:
                continue
            for t in tiles:
                out.append(GemmConfig(c, p, t))
    return out


@pytest.mark.parametrize("max_chips,tiles,parts", [
    (512, tuple(range(len(DEFAULT_TILES))), ("M", "N", "K", "2D")),
    (64, (0, 3), ("M", "N", "K", "2D")),
    (8, (0, 1, 3, 5), ("M", "2D")),
    (6, (3,), ("M", "N", "K", "2D")),
    (1, (0,), ("M", "N", "K")),
])
def test_default_space_enumeration_is_legacy_grid(max_chips, tiles, parts):
    space = ConfigSpace.default(max_chips, tiles=tiles, partitions=parts)
    assert space.enumerate() == _legacy_candidate_loop(
        max_chips, tiles, parts)
    assert space.size() == len(space.enumerate())


def test_candidate_configs_routes_through_the_space():
    """The public enumeration API is now a thin view of ConfigSpace."""
    assert candidate_configs(512) == ConfigSpace.default(512).enumerate()
    assert candidate_configs(64, tiles=(0, 3)) == \
        ConfigSpace.default(64, tiles=(0, 3)).enumerate()


def test_min_chips_gate_defers_then_fires():
    space = ConfigSpace.default(512)
    # partition assigned before chips: gate defers (admits)
    assert space.check({"partition": "2D"})
    # chips joins below the submesh minimum: gate fires
    assert not space.check({"partition": "2D", "n_chips": 2})
    assert space.check({"partition": "2D", "n_chips": 4})


def test_min_local_gate_is_dims_aware():
    space = ConfigSpace.enlarged(512, min_local=8)
    tiny = (9, 17, 33)
    # sharding M over 512 chips leaves <8 rows per chip
    assert not space.check({"partition": "M", "n_chips": 512}, dims=tiny)
    assert space.check({"partition": "M", "n_chips": 1}, dims=tiny)
    # without dims the gate is a no-op
    assert space.check({"partition": "M", "n_chips": 512})
    # enumeration honours it: no huge-chip shardings for tiny dims
    for cfg in space.enumerate(dims=tiny):
        assert space.contains(cfg, dims=tiny)
    assert space.size(dims=tiny) < space.size()


def test_space_serialisation_round_trip():
    for space in (ConfigSpace.default(64, tiles=(0, 3)),
                  ConfigSpace.enlarged(512)):
        d = json.loads(json.dumps(space.to_dict()))   # through JSON
        back = ConfigSpace.from_dict(d)
        assert back == space
        assert back.enumerate() == space.enumerate()
    with pytest.raises(ValueError, match="version"):
        ConfigSpace.from_dict({"version": 99, "axes": []})


def test_space_requires_core_axes():
    with pytest.raises(ValueError, match="n_chips"):
        ConfigSpace((Axis("partition", ("M",)), Axis("tile_id", (0,))))
    with pytest.raises(ValueError, match="unknown axis"):
        ConfigSpace((Axis("n_chips", (1,)), Axis("partition", ("M",)),
                     Axis("tile_id", (0,)), Axis("warp_size", (32,))))


def test_enlarged_space_is_10x_and_contains_default():
    default = ConfigSpace.default(512)
    enlarged = ConfigSpace.enlarged(512)
    assert enlarged.size() >= 10 * default.size()
    for cfg in default.enumerate():
        assert enlarged.contains(cfg)
    # knob values beyond the fixed default become members
    assert enlarged.contains(GemmConfig(8, "M", 3, trsm_seq_chips=8))
    assert not default.contains(GemmConfig(8, "M", 3, trsm_seq_chips=8))


def test_sample_is_deterministic_and_in_space():
    space = ConfigSpace.enlarged(512)
    a = space.sample(25, seed=7)
    b = space.sample(25, seed=7)
    assert a == b
    assert len(set(a)) == len(a) == 25
    assert all(space.contains(c) for c in a)
    assert space.sample(25, seed=8) != a


def test_complete_uses_canonical_defaults():
    space = ConfigSpace.enlarged(512)
    cfg = space.complete({})
    assert (cfg.n_chips, cfg.partition, cfg.tile_id,
            cfg.trsm_seq_chips) == (512, "2D", 3, TRSM_SEQ_CHIPS)
    # default inadmissible under the partial -> first admissible value
    cfg = space.complete({"n_chips": 2})
    assert cfg.partition == "M"   # 2D needs >= 4 chips
    with pytest.raises(ValueError, match="no admissible"):
        ConfigSpace.default(512).complete({"n_chips": 2,
                                           "partition": "2D"})


def test_search_graph_refines_in_order():
    space = ConfigSpace.default(64, tiles=(0, 3))
    g = SearchGraph(space, order=("partition", "n_chips", "tile_id"))
    s = g.initial()
    assert not g.is_complete(s)
    assert list(g.actions(s)) == ["M", "N", "K", "2D"]
    s = g.apply(s, "2D")
    # chips below the 2D submesh minimum are not offered
    assert all(c >= 4 for c in g.actions(s))
    s = g.apply(s, 4)
    s = g.apply(s, 3)
    assert g.is_complete(s)
    assert g.config(s) == GemmConfig(4, "2D", 3)


# ---------------------------------------------------------------------------
# beam search: exactness at full width, quality at narrow width
# ---------------------------------------------------------------------------

_ROUTINE_CASES = [None, "gemm", "syrk", "trsm",
                  ["gemm", "syrk", "trsm", "gemm"]]


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 65536), k=st.integers(8, 65536),
       n=st.integers(8, 65536),
       routine=st.sampled_from(("gemm", "syrk", "trsm")))
def test_full_width_beam_is_exhaustive_argmin(m, k, n, routine):
    """Satellite property: at full width/depth the beam equals the
    exhaustive enumeration's argmin bit for bit, per routine."""
    space = ConfigSpace.default(512)
    dims = np.array([[m, k, n]])
    beam = beam_search(dims, space, width=space.size(), top_k=3,
                       routines=routine)
    exact = exhaustive_best(dims, space, top_k=3, routines=routine)
    assert beam.configs == exact.configs
    assert beam.costs == exact.costs


@pytest.mark.parametrize("routines", _ROUTINE_CASES)
def test_full_width_beam_matches_exhaustive_mixed(routines):
    rng = np.random.default_rng(11)
    dims = rng.integers(8, 32768, size=(4, 3)).astype(np.int64)
    space = ConfigSpace.default(512)
    beam = beam_search(dims, space, width=space.size(),
                       routines=routines)
    exact = exhaustive_best(dims, space, routines=routines)
    assert beam.configs == exact.configs


def test_full_width_beam_exact_on_enlarged_space():
    rng = np.random.default_rng(5)
    dims = rng.integers(8, 32768, size=(3, 3)).astype(np.int64)
    space = ConfigSpace.enlarged(512)
    routines = ["gemm", "syrk", "trsm"]
    beam = beam_search(dims, space, width=space.size(),
                       routines=routines)
    exact = exhaustive_best(dims, space, routines=routines)
    assert beam.configs == exact.configs


def test_narrow_beam_quality_and_cost_on_enlarged_space():
    """The smoke claim in miniature: width 8 finds the exhaustive
    optimum on the ~11x space while pricing <= 25% of it."""
    rng = np.random.default_rng(2)
    dims = rng.integers(8, 65536, size=(8, 3)).astype(np.int64)
    routines = [("gemm", "syrk", "trsm")[i % 3] for i in range(len(dims))]
    space = ConfigSpace.enlarged(512)
    beam = beam_search(dims, space, width=8, routines=routines)
    exact = exhaustive_best(dims, space, routines=routines)
    regret = [b[0] / e[0] for b, e in zip(beam.costs, exact.costs)]
    assert max(regret) <= 1.01
    assert beam.priced_fraction <= 0.25
    assert beam.n_priced < exact.n_priced


def test_beam_handles_gated_out_branches():
    """Tiny dims make whole partition branches uncompletable under
    min_local gates; the beam must drop them, not crash."""
    space = ConfigSpace.enlarged(512, min_local=8)
    res = beam_search(np.array([[9, 17, 33]]), space, width=4)
    assert len(res.configs[0]) == 1
    assert space.contains(res.configs[0][0], dims=(9, 17, 33))


def test_beam_validates_width():
    space = ConfigSpace.default(8, tiles=(0,))
    with pytest.raises(ValueError, match="width"):
        beam_search(np.array([[64, 64, 64]]), space, width=0)


# ---------------------------------------------------------------------------
# installer integration: budgeted gathering + artifact space round-trip
# ---------------------------------------------------------------------------

def _budget_cfg(**kw):
    base = dict(n_samples=16, repeats=2, tile_ids=(0, 3),
                models=("linear_regression",),
                routines=("gemm", "syrk", "trsm"),
                timing_budget=16 * 10, seed=0)
    base.update(kw)
    return InstallConfig(**base)


def test_budgeted_gather_times_only_selected_cells():
    cfg = _budget_cfg()
    data = gather_data(SimulatedBackend(seed=0), cfg)
    assert data.mask is not None and data.mask.dtype == bool
    D, C = data.times.shape
    assert data.mask.shape == (D, C)
    quota = max(2, cfg.timing_budget // cfg.n_samples)
    per_dim = data.mask.sum(axis=1)
    assert np.all(per_dim >= 2) and np.all(per_dim <= quota)
    assert int(data.mask.sum()) <= cfg.timing_budget
    # untimed cells are +inf, timed cells finite
    assert np.all(np.isinf(data.times[~data.mask]))
    assert np.all(np.isfinite(data.times[data.mask]))
    # the baseline default config is timed for every dim (speedup denom)
    j_def = data.cfgs.index(cfg.default_config)
    assert np.all(data.mask[:, j_def])
    # training rows only come from timed cells
    X, y = data.to_rows()
    assert np.all(np.isfinite(y)) and len(y) == int(data.mask.sum())


def test_budgeted_gather_round_trips_through_npz(tmp_path):
    data = gather_data(SimulatedBackend(seed=0), _budget_cfg())
    p = str(tmp_path / "grid.npz")
    data.save(p)
    from repro.core.installer import GatheredData
    back = GatheredData.load(p)
    np.testing.assert_array_equal(back.mask, data.mask)
    np.testing.assert_array_equal(back.times, data.times)
    assert back.cfgs == data.cfgs
    assert back.space == data.space


def test_budgeted_install_artifact_serves(tmp_path):
    """A sparse-grid install trains, persists its space, and serves."""
    cfg = _budget_cfg()
    backend = SimulatedBackend(seed=0)
    data = gather_data(backend, cfg)
    report = install(backend, cfg, data=data,
                     artifact_dir=str(tmp_path))
    assert report.artifact_dir == str(tmp_path)
    conf = json.load(open(tmp_path / "config.json"))
    assert conf["install"]["timing_budget"] == cfg.timing_budget
    assert ConfigSpace.from_dict(conf["space"]) == cfg.resolved_space()
    tuner = AdsalaTuner.from_artifact(str(tmp_path))
    assert isinstance(tuner.select(1024, 512, 256, "trsm"), GemmConfig)


def test_artifact_space_block_round_trip(tiny_artifact):
    """The persisted "space" block reconstructs the exact install space
    and the tuner adopts it."""
    conf = json.load(open(tiny_artifact.dir + "/config.json"))
    space = ConfigSpace.from_dict(conf["space"])
    assert space == tiny_artifact.cfg.resolved_space()
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    assert tuner.space == space
    # every candidate is a member; enumeration matches the artifact list
    assert space.enumerate() == tuner.candidates


def test_legacy_artifact_without_space_block(tiny_artifact, tmp_path):
    """Pre-search artifacts carry no "space" block; the tuner
    reconstructs the default space the candidate list implies."""
    import shutil
    legacy = tmp_path / "legacy"
    shutil.copytree(tiny_artifact.dir, legacy)
    conf = json.load(open(legacy / "config.json"))
    del conf["space"]
    json.dump(conf, open(legacy / "config.json", "w"))
    tuner = AdsalaTuner.from_artifact(str(legacy))
    assert tuner.space.enumerate() == tuner.candidates


def test_warm_start_accepts_beam_found_configs(tiny_artifact, tmp_path):
    """v3 warm blocks carry explicit configs; anything inside the
    persisted space loads even if it is not the dense argmin — that is
    what lets budgeted/beam installs warm-start the tuner."""
    import shutil
    edited = tmp_path / "beamish"
    shutil.copytree(tiny_artifact.dir, edited)
    conf = json.load(open(edited / "config.json"))
    space = ConfigSpace.from_dict(conf["space"])
    # replace the first entry with a different in-space config
    current = conf["warm_start"]["configs"][0]
    other = next(c for c in space.enumerate()
                 if {"n_chips": c.n_chips, "partition": c.partition,
                     "tile_id": c.tile_id} != current)
    conf["warm_start"]["configs"][0] = {
        "n_chips": other.n_chips, "partition": other.partition,
        "tile_id": other.tile_id}
    json.dump(conf, open(edited / "config.json", "w"))
    tuner = AdsalaTuner.from_artifact(str(edited))   # no warning
    ws = conf["warm_start"]
    assert len(tuner._cache) == len(ws["dims"])
    key = (ws["routines"][0], *ws["dims"][0])
    assert tuner._cache[key][0] == other


# ---------------------------------------------------------------------------
# tuner dispatch-time search
# ---------------------------------------------------------------------------

class _StubModel:
    """log-time grows with chips and m: argmin is fewest-chips."""

    def predict(self, X):
        return np.log(1e-6 * (X[:, 3] + 1e-3 * X[:, 0]))


class _IdentityPipe:
    def transform(self, X):
        return X


def _stub_tuner(**kw):
    return AdsalaTuner(_StubModel(), _IdentityPipe(),
                       candidate_configs(64, tiles=(0, 3)), **kw)


def test_select_search_matches_fixed_argmin_for_default_space():
    """Over the same space the beam (full width) picks exactly what the
    fixed-candidate argmin picks — the search path is a refactor, not a
    behaviour change, until the space grows."""
    t_fixed = _stub_tuner()
    t_beam = _stub_tuner()
    shapes = [(64, 64, 64), (512, 512, 512), (64, 2048, 64)]
    fixed = t_fixed.select_many(shapes)
    beamed = t_beam.select_many(shapes,
                                search=t_beam.space.size())
    assert beamed == fixed
    assert set(t_beam.stats) == {"calls", "cache_hits", "evaluations"}
    assert t_beam.stats["evaluations"] == len(shapes)


def test_select_search_memoises_and_search_width_default():
    t = _stub_tuner(search_width=4)
    cfg = t.select(256, 128, 256, "syrk")          # beam path (width 4)
    assert t.space.contains(cfg)
    again = t.select(256, 128, 256, "syrk")        # cache hit, no beam
    assert again == cfg
    assert t.stats == {"calls": 2, "cache_hits": 1, "evaluations": 1}
    # search=False forces the fixed path even with a default width
    t2 = _stub_tuner(search_width=4)
    assert t2.select(256, 128, 256, search=False) in t2.candidates


def test_select_search_over_wider_space_reaches_new_configs():
    """Give the tuner a space wider than its candidate list: the beam
    can select configs the fixed argmin cannot express."""
    space = ConfigSpace.default(64)                # all 6 tiles
    t = _stub_tuner(space=space)                   # candidates: tiles 0,3
    cfg = t.select(64, 64, 64, search=space.size())
    fixed = _stub_tuner().select(64, 64, 64)
    # stub model is tile-blind, so ties resolve to tile 0 either way;
    # the searched config must at minimum be a space member and as good
    t_chk = _stub_tuner(space=space)
    times = t_chk.predicted_times_many([(64, 64, 64)],
                                       candidates=[cfg, fixed])
    assert space.contains(cfg)
    assert times[0, 0] <= times[0, 1]


def test_select_with_times_after_search():
    t = _stub_tuner(search_width=8)
    cfg, times = t.select_with_times(128, 64, 128)
    assert len(times) == len(t.candidates)
    assert t.candidates[int(np.argmin(times))].n_chips == cfg.n_chips
