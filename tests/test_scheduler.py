"""Golden parity: continuous batching may reorder work, never results.

Every sequence decoded through the continuous-batching scheduler (paged
KV cache, ragged admission, slot reuse) must produce token-for-token
identical output to the fixed-batch ``prefill`` + ``decode_step`` path
on the same params — for both attention families (MHA KV cache and
MLA latent cache), untuned on both sides so the comparison is pure
cache plumbing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_smoke_config
from repro.serve.scheduler import ContinuousBatchingScheduler
from repro.train.step import make_ctx

pytestmark = pytest.mark.timeout(300)

#: the two attention families with a paged cache representation
ARCHS = ["stablelm-1.6b", "deepseek-v2-236b"]

_BUILT: dict = {}


def _built(arch):
    if arch not in _BUILT:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUILT[arch] = (cfg, model, params)
    return _BUILT[arch]


def _trace(cfg, n=6, seed=7):
    """A fixed ragged request trace: (prompt, max_new) pairs."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        length = int(rng.integers(3, 10))
        out.append((rng.integers(0, cfg.vocab, length).tolist(),
                    int(rng.integers(1, 7))))
    return out


def _reference(model, cfg, params, prompt, max_new, cache_len):
    """The existing fixed-batch serving path, batch of one."""
    pctx = make_ctx(None, "prefill", cache_len=cache_len, remat=False)
    dctx = make_ctx(None, "decode", cache_len=cache_len)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = model.prefill(params, toks, pctx)
    out = [int(jnp.argmax(logits[0]))]
    for i in range(max_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache,
            jnp.int32(len(prompt) + i), dctx)
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_golden_parity_vs_fixed_batch(arch):
    cfg, model, params = _built(arch)
    page = 4
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=3, n_pages=32, page_size=page,
        max_seq_len=16)
    trace = _trace(cfg)
    rids = [sched.submit(p, n) for p, n in trace]
    finished = sched.run_until_drained()

    assert len(finished) == len(trace)
    # the reference decodes against the same gathered span (cap) so the
    # attention mask geometry matches slot-for-slot
    for rid, (prompt, max_new) in zip(rids, trace):
        want = _reference(model, cfg, params, prompt, max_new, sched.cap)
        assert list(finished[rid].tokens) == want, \
            f"{arch} rid={rid} prompt_len={len(prompt)}"
    # all pages returned to the pool
    sched.alloc.check()
    assert sched.alloc.live_pages == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_slot_reuse_does_not_cross_contaminate(arch):
    """The same prompt admitted early and late (through recycled pages
    and slots) must decode identically — stale page contents from a
    retired sequence can never leak into a new one."""
    cfg, model, params = _built(arch)
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=2, n_pages=12, page_size=4,
        max_seq_len=12)
    rng = np.random.default_rng(3)
    probe = rng.integers(0, cfg.vocab, 5).tolist()
    first = sched.submit(probe, 4)
    fillers = [sched.submit(rng.integers(0, cfg.vocab,
                                         int(rng.integers(3, 9))).tolist(),
                            int(rng.integers(2, 6))) for _ in range(3)]
    again = sched.submit(probe, 4)      # admitted after retires/recycling
    finished = sched.run_until_drained()
    assert finished[first].tokens == finished[again].tokens
    assert len(finished) == len(fillers) + 2


def test_trsm_site_tags_survive_paging():
    """The paged cache update keeps the fixed-batch path's TRSM-site
    recorder tag — the signal the workload profile / re-installer keys
    on must not change shape because serving went paged."""
    cfg, model, params = _built("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=2, n_pages=16, page_size=4,
        max_seq_len=12)
    sched.submit([1, 2, 3, 4, 5], 3)
    sched.run_until_drained()
    decode_sites = {e.site for e in sched.recorders["decode"].events}
    assert "attn.cache_update" in decode_sites
    trsm = [e for e in sched.recorders["decode"].events
            if e.site == "attn.cache_update"]
    assert all(e.routine == "trsm" for e in trsm)
    # cache-update events price the gathered span, not the pool size
    assert all(e.m == sched.cap for e in trsm)
    assert sched.recorders["prefill"].events, "prefill traffic unrecorded"


def test_mla_cache_update_tag():
    cfg, model, params = _built("deepseek-v2-236b")
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=1, n_pages=8, page_size=4,
        max_seq_len=12)
    sched.submit([1, 2, 3], 2)
    sched.run_until_drained()
    sites = {e.site for e in sched.recorders["decode"].events}
    assert "mla.cache_update" in sites


def test_admission_defers_then_completes_under_tiny_pool():
    """A pool that fits one sequence at a time forces FIFO deferral;
    everything still finishes with zero drops."""
    cfg, model, params = _built("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=4, n_pages=3, page_size=4,
        max_seq_len=12)
    rng = np.random.default_rng(11)
    rids = [sched.submit(rng.integers(0, cfg.vocab, 6).tolist(), 4)
            for _ in range(4)]
    finished = sched.run_until_drained()
    assert sorted(finished) == sorted(rids)
    # one 6+3-token sequence needs 3 pages = the whole pool: strictly
    # sequential service, so later sequences were admitted later
    admits = [finished[r].admitted_step for r in rids]
    assert admits == sorted(admits) and len(set(admits)) == len(admits)


def test_max_new_one_finishes_at_prefill():
    cfg, model, params = _built("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=1, n_pages=8, page_size=4,
        max_seq_len=12)
    rid = sched.submit([5, 6, 7], 1)
    finished = sched.run_until_drained()
    assert len(finished[rid].tokens) == 1
    assert sched.steps == 0             # never needed a decode step
    assert finished[rid].tokens[0] == _reference(
        model, cfg, params, [5, 6, 7], 1, sched.cap)[0]


def test_submit_validation():
    cfg, model, params = _built("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(
        model, cfg, params, slots=1, n_pages=4, page_size=4,
        max_seq_len=8)
    with pytest.raises(ValueError, match="cap"):
        sched.submit(list(range(7)), 4)     # 10 slots > cap 8
    with pytest.raises(ValueError, match="empty"):
        sched.submit([], 2)
    with pytest.raises(ValueError, match="max_new"):
        sched.submit([1, 2], 0)
    rid = sched.submit([1, 2], 2)
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit([3, 4], 2, rid=rid)


def test_unpageable_families_refuse_loudly():
    """Ring/recurrent caches have no paged form: the scheduler must
    raise at construction, not corrupt at decode."""
    cfg = get_smoke_config("recurrentgemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ContinuousBatchingScheduler(model, cfg, params, slots=1,
                                    n_pages=4, page_size=4,
                                    max_seq_len=8)

    wcfg = get_smoke_config("whisper-tiny")
    wmodel = build_model(wcfg)
    wparams = wmodel.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ContinuousBatchingScheduler(wmodel, wcfg, wparams, slots=1,
                                    n_pages=4, page_size=4,
                                    max_seq_len=8)
