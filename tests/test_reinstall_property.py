"""Property tests for the closed serving loop's math and debouncing.

* drift is a metric-shaped score: in [0, 1], symmetric, 0 on self;
* WorkloadProfile.merge volume-weighting is associative up to floating
  tolerance (merging per-traffic-class profiles in any grouping gives
  the same install weighting);
* the DriftTrigger hysteresis invariant: no two fires within the
  cooldown, regardless of the drift trajectory, and a second fire
  requires re-arming below threshold - hysteresis.

Runs under real `hypothesis` or the deterministic
``repro._compat.hypothesis_fallback`` shim (fixed-seed example sweeps)
— only ``integers`` / ``floats`` / ``lists`` strategies and
``given``/``settings`` are used.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import ROUTINES
from repro.core.workload import WorkloadProfile
from repro.kernels.recorder import DispatchEvent, DispatchRecorder
from repro.serve import DriftTrigger

pytestmark = pytest.mark.timeout(120)


def _rand_profile(seed: int, by: str = "flops") -> WorkloadProfile:
    rng = np.random.default_rng(seed)
    rec = DispatchRecorder()
    for _ in range(int(rng.integers(1, 50))):
        m, k, n = (int(x) for x in 2 ** rng.integers(3, 14, 3))
        rec.events.append(DispatchEvent(
            routine=ROUTINES[int(rng.integers(len(ROUTINES)))],
            m=m, k=k, n=n, count=int(rng.integers(1, 5)),
            site="prop"))
    return WorkloadProfile.from_recorder(rec, by=by)


# ---------------------------------------------------------------------------
# drift: bounded, symmetric, zero on self
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(sa=st.integers(0, 10**6), sb=st.integers(0, 10**6))
def test_drift_in_unit_interval_and_symmetric(sa, sb):
    a, b = _rand_profile(sa), _rand_profile(sb)
    d = a.drift(b)
    assert 0.0 <= d <= 1.0
    assert d == pytest.approx(b.drift(a), abs=1e-12)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(0, 10**6))
def test_drift_zero_on_self(s):
    a = _rand_profile(s)
    assert a.drift(a) == pytest.approx(0.0, abs=1e-12)
    # the routine-mix (mapping) entry point agrees on the self case
    assert a.drift(a.routine_weights) == pytest.approx(0.0, abs=1e-12)


@settings(max_examples=15, deadline=None)
@given(sa=st.integers(0, 10**6), sb=st.integers(0, 10**6))
def test_profile_drift_dominates_routine_only_drift(sa, sb):
    """The profile-vs-profile drift (max of routine and shape-cell TV)
    can only sharpen, never soften, the routine-mix warning the serve
    loop printed before the closed loop existed."""
    a, b = _rand_profile(sa), _rand_profile(sb)
    assert a.drift(b) >= a.drift(b.routine_weights) - 1e-12


# ---------------------------------------------------------------------------
# merge: volume-weighting associative up to tolerance
# ---------------------------------------------------------------------------

def _assert_profiles_close(p: WorkloadProfile, q: WorkloadProfile):
    assert p.total == pytest.approx(q.total, rel=1e-9)
    assert set(p.routine_weights) == set(q.routine_weights)
    for r, w in p.routine_weights.items():
        assert w == pytest.approx(q.routine_weights[r], abs=1e-9)
    assert set(p.cells) == set(q.cells)
    for c, w in p.cells.items():
        assert w == pytest.approx(q.cells[c], abs=1e-9)


@settings(max_examples=15, deadline=None)
@given(sa=st.integers(0, 10**6), sb=st.integers(0, 10**6),
       sc=st.integers(0, 10**6))
def test_merge_volume_weighting_associative(sa, sb, sc):
    a, b, c = (_rand_profile(s) for s in (sa, sb, sc))
    flat = WorkloadProfile.merge([a, b, c])
    left = WorkloadProfile.merge([WorkloadProfile.merge([a, b]), c])
    right = WorkloadProfile.merge([a, WorkloadProfile.merge([b, c])])
    _assert_profiles_close(flat, left)
    _assert_profiles_close(flat, right)


@settings(max_examples=15, deadline=None)
@given(sa=st.integers(0, 10**6), sb=st.integers(0, 10**6))
def test_merge_weights_follow_recorded_volume(sa, sb):
    """Default merge weights are each profile's recorded total — the
    per-traffic-class semantics the ReinstallManager relies on."""
    a, b = _rand_profile(sa), _rand_profile(sb)
    merged = WorkloadProfile.merge([a, b])
    explicit = WorkloadProfile.merge([a, b],
                                     weights=[a.total, b.total])
    _assert_profiles_close(merged, explicit)
    assert merged.total == pytest.approx(a.total + b.total, rel=1e-9)


# ---------------------------------------------------------------------------
# trigger: hysteresis + cooldown invariants over arbitrary trajectories
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(threshold=st.floats(0.05, 0.9),
       hyst_frac=st.floats(0.0, 1.0),
       cooldown=st.floats(0.0, 50.0),
       drifts=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=60),
       dt=st.floats(0.1, 5.0))
def test_trigger_cooldown_and_hysteresis_invariants(
        threshold, hyst_frac, cooldown, drifts, dt):
    trig = DriftTrigger(threshold=threshold,
                        hysteresis=hyst_frac * threshold,
                        cooldown_s=cooldown)
    fires = []
    for i, d in enumerate(drifts):
        now = i * dt
        if trig.observe(d, now):
            fires.append((now, i))
            # a fire only ever happens above threshold
            assert d > threshold
    # no two fires within the cooldown, regardless of trajectory
    for (t0, _), (t1, _) in zip(fires, fires[1:]):
        assert t1 - t0 >= cooldown
    # between consecutive fires the drift must have re-armed the
    # trigger by dipping to threshold - hysteresis or below
    rearm = max(threshold - trig.hysteresis, 0.0)
    for (_, i0), (_, i1) in zip(fires, fires[1:]):
        assert any(d <= rearm for d in drifts[i0 + 1:i1])


def test_trigger_rejects_bad_params():
    with pytest.raises(ValueError):
        DriftTrigger(threshold=0.0)
    with pytest.raises(ValueError):
        DriftTrigger(threshold=0.2, hysteresis=0.3)
    with pytest.raises(ValueError):
        DriftTrigger(cooldown_s=-1.0)


def test_trigger_oscillation_fires_once():
    """Hovering just around the threshold (the thrash scenario
    hysteresis exists for) fires exactly once."""
    trig = DriftTrigger(threshold=0.25, hysteresis=0.05, cooldown_s=0.0)
    seq = [0.26, 0.24, 0.26, 0.24, 0.26]    # never dips to 0.20
    fired = sum(trig.observe(d, float(i)) for i, d in enumerate(seq))
    assert fired == 1
