"""Checkpointing + fault-tolerance driver behaviour."""

import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft.driver import DriverConfig, TrainDriver


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 4))),
                   "b": jnp.asarray(rng.standard_normal(4))},
        "m": {"w": jnp.zeros((8, 4)), "b": jnp.zeros(4)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 5, state)
    # a crashed write: directory without COMMIT
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 5
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), 9, state)


def test_checkpoint_dtype_cast_on_restore(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 1, state)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float16)
        if x.dtype == jnp.float32 else x, state)
    restored = restore_checkpoint(str(tmp_path), 1, like)
    assert restored["params"]["w"].dtype == jnp.float16


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, _state())
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _toy_step(state, batch):
    new = dict(state)
    new["step"] = state["step"] + 1
    loss = jnp.sum(batch["x"]) * 0.0 + 1.0 / (1 + state["step"])
    return new, {"loss": loss}


def _data():
    while True:
        yield {"x": jnp.ones(3)}


def test_driver_runs_and_checkpoints(tmp_path):
    driver = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=4, max_steps=10),
        _toy_step, _state(), _data())
    summary = driver.run()
    assert summary["step"] == 10
    assert latest_step(str(tmp_path)) == 10   # final sync checkpoint


def test_driver_resume(tmp_path):
    d1 = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=6),
        _toy_step, _state(), _data())
    d1.run()
    d2 = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=9),
        _toy_step, _state(), _data(), state_template=_state())
    resumed = d2.maybe_resume()
    assert resumed == 6
    summary = d2.run()
    assert summary["step"] == 9


def test_driver_straggler_detection(tmp_path):
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 12:
            time.sleep(0.25)          # injected straggler
        else:
            time.sleep(0.002)
        return _toy_step(state, batch)

    flagged = []
    driver = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=15,
                     straggler_factor=3.0,
                     on_straggler=lambda s, dt: flagged.append(s)),
        slow_step, _state(), _data())
    summary = driver.run()
    assert 12 in summary["stragglers"]
    assert flagged


def test_driver_preemption_checkpoint(tmp_path):
    """SIGTERM mid-run -> driver stops and leaves a final checkpoint."""
    def slowish(state, batch):
        time.sleep(0.01)
        return _toy_step(state, batch)

    driver = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                     max_steps=500),
        slowish, _state(), _data())
    killer = threading.Timer(0.15, lambda: os.kill(os.getpid(),
                                                   signal.SIGTERM))
    killer.start()
    summary = driver.run()
    assert summary["preempted"]
    assert 0 < summary["step"] < 500
    assert latest_step(str(tmp_path)) == summary["step"]
