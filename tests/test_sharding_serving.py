"""Sharding rules + serving options (int8 KV cache) across the zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, build_model
from repro.models import Ctx
from repro.models.params import ParamDef


class _FakeMesh:
    """Just enough mesh surface for the rule tables (no jax devices)."""

    def __init__(self, shape: dict[str, int]):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape", [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
])
def test_param_specs_divisible(arch, mesh_shape):
    """Every sharded param dim must divide its mesh axes — the invariant
    GSPMD requires for every (arch x mesh) cell."""
    from repro.dist.sharding import param_rules
    from repro.models.params import param_specs

    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = _FakeMesh(mesh_shape)
    rules = param_rules(cfg, mesh)
    specs = model.param_partition_specs(rules)

    defs_leaves = jax.tree.leaves(
        model.defs, is_leaf=lambda x: isinstance(x, ParamDef))
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: s.__class__.__name__ == "PartitionSpec")
    assert len(defs_leaves) == len(spec_leaves)
    for d, spec in zip(defs_leaves, spec_leaves):
        for dim, axis in zip(d.shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= mesh_shape[a]
            assert dim % size == 0, \
                f"{arch}: dim {dim} not divisible by {axes} ({size})"


def test_int8_kv_cache_decode_accuracy():
    """int8 KV decode within 3% relative logit error of fp (the §Perf C1
    quality gate)."""
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, jnp.float32)
    S = 24
    tokens = jax.random.randint(rng, (2, S + 1), 0, cfg.vocab)
    ctx = Ctx(mode="prefill", cache_len=S + 8, remat=False)
    full_logits, _ = model.prefill(params, tokens, ctx)
    qctx = Ctx(mode="prefill", cache_len=S + 8, remat=False,
               kv_quantized=True)
    _, qcache = model.prefill(params, tokens[:, :S], qctx)
    dctx = Ctx(mode="decode", cache_len=S + 8, kv_quantized=True)
    ql, _ = model.decode_step(params, tokens[:, S:S + 1], qcache,
                              jnp.int32(S), dctx)
    rel = float(jnp.abs(full_logits - ql).max()) \
        / float(jnp.abs(full_logits).max())
    assert rel < 0.03


def test_int8_kv_cache_halves_bytes():
    from repro.models.layers import init_kv_cache

    fp = init_kv_cache(2, 64, 4, 32, jnp.bfloat16)
    q = init_kv_cache(2, 64, 4, 32, jnp.bfloat16, quantized=True)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c))

    assert nbytes(q) < 0.6 * nbytes(fp)


def test_windowed_ring_cache_decode():
    """Ring-buffer cache: tokens beyond the window are forgotten."""
    from repro.models import layers as L
    from repro.models.params import init_params

    spec = L.AttnSpec(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                      window=8)
    p = init_params(L.attention_defs(spec), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, 32))
    out_ref, (k, v) = L.attention_train(p, x, spec)
    cache = L.seed_kv_cache(k[:, :19], v[:, :19], 8, windowed=True)
    out_dec, _ = L.attention_decode(p, x[:, 19:20], spec, cache,
                                    jnp.int32(19))
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_ref[:, 19]),
                               atol=2e-5, rtol=2e-4)


def test_auto_spec_heuristics():
    from repro.dist.sharding import auto_spec

    mesh = _FakeMesh({"data": 16, "model": 16})
    # KV cache (B=128, cap=32768, Hkv=8, 128): batch->data, cap->model
    s = auto_spec((128, 32768, 8, 128), mesh, batch_dim=0)
    assert tuple(s) == ("data", "model", None, None)
    # scan-stacked (L=59, B=128, cap, R): batch at dim 1
    s = auto_spec((59, 128, 32768, 576), mesh, batch_dim=1)
    assert tuple(s)[1] == "data" and "model" in tuple(s)
    # B=1 long-context: nothing shardable on batch
    s = auto_spec((1, 4096, 8, 128), mesh, batch_dim=0)
    assert tuple(s)[0] is None
