"""Sharding rules + serving options (int8 KV cache) across the zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, build_model
from repro.models import Ctx
from repro.models.params import ParamDef


class _FakeMesh:
    """Just enough mesh surface for the rule tables (no jax devices)."""

    def __init__(self, shape: dict[str, int]):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape", [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
])
def test_param_specs_divisible(arch, mesh_shape):
    """Every sharded param dim must divide its mesh axes — the invariant
    GSPMD requires for every (arch x mesh) cell."""
    from repro.dist.sharding import param_rules
    from repro.models.params import param_specs

    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = _FakeMesh(mesh_shape)
    rules = param_rules(cfg, mesh)
    specs = model.param_partition_specs(rules)

    defs_leaves = jax.tree.leaves(
        model.defs, is_leaf=lambda x: isinstance(x, ParamDef))
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: s.__class__.__name__ == "PartitionSpec")
    assert len(defs_leaves) == len(spec_leaves)
    for d, spec in zip(defs_leaves, spec_leaves):
        for dim, axis in zip(d.shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= mesh_shape[a]
            assert dim % size == 0, \
                f"{arch}: dim {dim} not divisible by {axes} ({size})"


def test_int8_kv_cache_decode_accuracy():
    """int8 KV decode within 3% relative logit error of fp (the §Perf C1
    quality gate)."""
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, jnp.float32)
    S = 24
    tokens = jax.random.randint(rng, (2, S + 1), 0, cfg.vocab)
    ctx = Ctx(mode="prefill", cache_len=S + 8, remat=False)
    full_logits, _ = model.prefill(params, tokens, ctx)
    qctx = Ctx(mode="prefill", cache_len=S + 8, remat=False,
               kv_quantized=True)
    _, qcache = model.prefill(params, tokens[:, :S], qctx)
    dctx = Ctx(mode="decode", cache_len=S + 8, kv_quantized=True)
    ql, _ = model.decode_step(params, tokens[:, S:S + 1], qcache,
                              jnp.int32(S), dctx)
    rel = float(jnp.abs(full_logits - ql).max()) \
        / float(jnp.abs(full_logits).max())
    assert rel < 0.03


def test_int8_kv_cache_halves_bytes():
    from repro.models.layers import init_kv_cache

    fp = init_kv_cache(2, 64, 4, 32, jnp.bfloat16)
    q = init_kv_cache(2, 64, 4, 32, jnp.bfloat16, quantized=True)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c))

    assert nbytes(q) < 0.6 * nbytes(fp)


def test_windowed_ring_cache_decode():
    """Ring-buffer cache: tokens beyond the window are forgotten."""
    from repro.models import layers as L
    from repro.models.params import init_params

    spec = L.AttnSpec(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                      window=8)
    p = init_params(L.attention_defs(spec), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, 32))
    out_ref, (k, v) = L.attention_train(p, x, spec)
    cache = L.seed_kv_cache(k[:, :19], v[:, :19], 8, windowed=True)
    out_dec, _ = L.attention_decode(p, x[:, 19:20], spec, cache,
                                    jnp.int32(19))
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_ref[:, 19]),
                               atol=2e-5, rtol=2e-4)


def test_auto_spec_heuristics():
    from repro.dist.sharding import auto_spec

    mesh = _FakeMesh({"data": 16, "model": 16})
    # KV cache (B=128, cap=32768, Hkv=8, 128): batch->data, cap->model
    s = auto_spec((128, 32768, 8, 128), mesh, batch_dim=0)
    assert tuple(s) == ("data", "model", None, None)
    # scan-stacked (L=59, B=128, cap, R): batch at dim 1
    s = auto_spec((59, 128, 32768, 576), mesh, batch_dim=1)
    assert tuple(s)[1] == "data" and "model" in tuple(s)
    # B=1 long-context: nothing shardable on batch
    s = auto_spec((1, 4096, 8, 128), mesh, batch_dim=0)
    assert tuple(s)[0] is None


# ---------------------------------------------------------------------------
# cache_specs layout coverage: scan-dict (with suffix), whisper's plain
# list, and paged pools — across a (1,2) and a (2,4) mesh
# ---------------------------------------------------------------------------

_MESHES = [{"data": 1, "model": 2}, {"data": 2, "model": 4}]


def _mesh_size(mesh_shape, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh_shape[a]
    return size


def _assert_specs_divisible(cache_abs, specs, mesh_shape, label):
    """Structure parity + the GSPMD invariant on every leaf."""
    from repro.dist.sharding import is_partition_spec

    leaves = jax.tree.leaves(cache_abs)
    spec_leaves = jax.tree.leaves(specs, is_leaf=is_partition_spec)
    assert len(leaves) == len(spec_leaves), label
    for l, s in zip(leaves, spec_leaves):
        assert len(tuple(s)) == len(l.shape), (label, l.shape, s)
        for dim, entry in zip(l.shape, tuple(s)):
            assert dim % _mesh_size(mesh_shape, entry) == 0, \
                f"{label}: dim {dim} not divisible by {entry}"


@pytest.mark.parametrize("mesh_shape", _MESHES)
@pytest.mark.parametrize("arch,batch", [
    ("stablelm-1.6b", 4),        # pure scan, empty prefix/suffix
    ("recurrentgemma-2b", 4),    # scan-dict WITH a non-empty suffix
])
def test_cache_specs_scan_dict_layout(arch, batch, mesh_shape):
    from repro.dist.sharding import data_axes, divisible_axes
    from repro.models.config import ShapeSpec
    from repro.serve.step import cache_sds, cache_specs

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    mesh = _FakeMesh(mesh_shape)
    shape = ShapeSpec("t", seq_len=32, global_batch=batch, kind="decode")
    cache_abs = cache_sds(model, cfg, shape)
    specs = cache_specs(cache_abs, mesh)

    assert set(specs) == {"prefix", "scan", "suffix"}
    if arch == "recurrentgemma-2b":
        assert specs["suffix"], "suffix branch not exercised"
    _assert_specs_divisible(cache_abs, specs, mesh_shape, arch)

    # batch placement: dim 0 on prefix/suffix leaves, dim 1 on scan
    want = divisible_axes(batch, data_axes(mesh), mesh)
    for seg in ("prefix", "suffix", "scan"):
        for s in jax.tree.leaves(
                specs[seg],
                is_leaf=lambda x: x.__class__.__name__ == "PartitionSpec"):
            entries = tuple(s)
            if seg == "scan":
                assert entries[0] is None       # repeat dim replicated
                assert entries[1] in (want, None)
            else:
                assert entries[0] in (want, None)


@pytest.mark.parametrize("mesh_shape", _MESHES)
def test_cache_specs_whisper_plain_list(mesh_shape):
    """The non-dict fallback branch: whisper's per-layer list of
    {self, cross_k, cross_v} caches, batch at dim 0 everywhere."""
    from repro.dist.sharding import data_axes, divisible_axes
    from repro.models.config import ShapeSpec
    from repro.serve.step import cache_sds, cache_specs

    cfg = get_smoke_config("whisper-tiny")
    model = build_model(cfg)
    mesh = _FakeMesh(mesh_shape)
    shape = ShapeSpec("t", seq_len=32, global_batch=4, kind="decode")
    cache_abs = cache_sds(model, cfg, shape)
    specs = cache_specs(cache_abs, mesh)

    assert isinstance(specs, list) and len(specs) == cfg.n_layers
    assert set(specs[0]) == {"self", "cross_k", "cross_v"}
    _assert_specs_divisible(cache_abs, specs, mesh_shape, "whisper")
    want = divisible_axes(4, data_axes(mesh), mesh)
    for s in jax.tree.leaves(
            specs, is_leaf=lambda x: x.__class__.__name__
            == "PartitionSpec"):
        assert tuple(s)[0] in (want, None)


@pytest.mark.parametrize("mesh_shape", _MESHES)
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "deepseek-v2-236b"])
def test_cache_specs_paged_pools(arch, mesh_shape):
    """Paged pools route through paged_spec: page dim -> data axes,
    'model' on a head/width dim, never on the page-offset dim."""
    from repro.serve.step import cache_specs, paged_cache_sds

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    mesh = _FakeMesh(mesh_shape)
    n_pages, page_size = 16, 4
    pool_abs = paged_cache_sds(model, n_pages, page_size)
    specs = cache_specs(pool_abs, mesh)

    _assert_specs_divisible(pool_abs, specs, mesh_shape, f"paged-{arch}")
    for l, s in zip(jax.tree.leaves(pool_abs),
                    jax.tree.leaves(
                        specs, is_leaf=lambda x: x.__class__.__name__
                        == "PartitionSpec")):
        entries = tuple(s)
        stacked = l.shape[0] != n_pages     # scan pools: (R, P, page, ...)
        page_dim = 1 if stacked else 0
        assert l.shape[page_dim] == n_pages
        assert l.shape[page_dim + 1] == page_size
        # page dim carries the data axes on the (2,4) mesh (16 % 2 == 0)
        if mesh_shape["data"] > 1:
            assert entries[page_dim] is not None
        # the page-offset dim is NEVER sharded
        assert entries[page_dim + 1] is None
        if stacked:
            assert entries[0] is None       # repeat dim replicated


def test_paged_spec_rules():
    from repro.dist.sharding import paged_spec

    mesh = _FakeMesh({"data": 16, "model": 16})
    # (P=256, page=16, Hkv=8, D=128): pages->data, D->model (largest
    # divisible dim outside the page pair)
    s = paged_spec((256, 16, 8, 128), mesh, page_dim=0)
    assert tuple(s) == ("data", None, None, "model")
    # scan-stacked pool: repeat dim replicated, page dim 1
    s = paged_spec((12, 256, 16, 8, 128), mesh, page_dim=1)
    assert tuple(s) == (None, "data", None, None, "model")
    # page count not divisible -> data demoted to None, model intact
    s = paged_spec((30, 16, 8, 128), mesh, page_dim=0)
    assert tuple(s) == (None, None, None, "model")
    # the page-offset dim never takes 'model' even when divisible and
    # largest: (P, page=4096, small heads)
    s = paged_spec((256, 4096, 8, 24), mesh, page_dim=0)
    assert tuple(s)[1] is None
