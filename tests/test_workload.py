"""Workload-aware installation: WorkloadProfile round-trip/merge/quotas,
the mixture sampler's coverage floor, the routine-assignment
stratification fix, and the headline property — a mix-weighted install
beats a uniform one on the workload it was weighted by, at equal budget.
"""

import json

import numpy as np
import pytest

from repro.core import (
    AdsalaTuner,
    GatheredData,
    InstallConfig,
    SimulatedBackend,
    WorkloadProfile,
    costmodel,
    gather_data,
    install,
)
from repro.core.installer import _assign_routines
from repro.core.workload import apportion, shape_cell
from repro.kernels.recorder import DispatchEvent, DispatchRecorder, record


# ---------------------------------------------------------------------
# fixtures / helpers
# ---------------------------------------------------------------------

def _serve_events() -> list[DispatchEvent]:
    """A decode-serve-like dispatch mix: skinny projection gemms, small
    per-head syrk scores, a trsm-tagged cache update."""
    return [
        DispatchEvent("gemm", 64, 2048, 2048, count=96, site="proj"),
        DispatchEvent("gemm", 64, 2048, 8192, count=32, site="mlp.up"),
        DispatchEvent("gemm", 64, 8192, 2048, count=32, site="mlp.down"),
        DispatchEvent("gemm", 64, 2048, 50257, count=1, site="logits"),
        DispatchEvent("syrk", 512, 64, 512, count=64, site="attn.qk"),
        DispatchEvent("trsm", 64, 64, 2048, count=16, site="cache"),
    ]


def _serve_profile(by: str = "flops") -> WorkloadProfile:
    return WorkloadProfile.from_events(_serve_events(), by=by)


def _ks(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample Kolmogorov–Smirnov statistic (no scipy on this box)."""
    a, b = np.sort(a), np.sort(b)
    both = np.concatenate([a, b])
    ca = np.searchsorted(a, both, side="right") / len(a)
    cb = np.searchsorted(b, both, side="right") / len(b)
    return float(np.max(np.abs(ca - cb)))


ROUTINES3 = ("gemm", "syrk", "trsm")


# ---------------------------------------------------------------------
# profile construction + serialisation
# ---------------------------------------------------------------------

def test_recorder_to_profile_to_json_round_trip(tmp_path):
    with DispatchRecorder() as rec:
        for e in _serve_events():
            record(e.routine, e.m, e.k, e.n, site=e.site, count=e.count)
    prof = WorkloadProfile.from_recorder(rec, source={"arch": "test"})
    assert prof.source["kind"] == "recorder"
    assert set(prof.routine_weights) == {"gemm", "syrk", "trsm"}
    assert prof.routine_weights["gemm"] > 0.9      # flop-dominant
    np.testing.assert_allclose(sum(prof.routine_weights.values()), 1.0)
    np.testing.assert_allclose(sum(prof.cells.values()), 1.0)
    assert shape_cell(64, 2048, 2048) in prof.cells

    path = tmp_path / "profile.json"
    prof.save(str(path))
    back = WorkloadProfile.load(str(path))
    assert back.to_dict() == prof.to_dict()
    assert back.cells == prof.cells            # tuple keys survive JSON
    assert back.by == "flops" and back.total == prof.total


def test_profile_from_empty_recorder():
    prof = WorkloadProfile.from_recorder(DispatchRecorder())
    assert prof.routine_weights == {} and prof.cells == {}
    assert prof.total == 0.0
    # an empty profile degrades to an even split + uniform sampling
    assert prof.routine_quotas(ROUTINES3, 9) == \
        {"gemm": 3, "syrk": 3, "trsm": 3}
    dims = prof.sample_dims(16, mem_limit_bytes=2**28, seed=0)
    assert dims.shape == (16, 3)


def test_profile_by_events_weighting():
    prof = _serve_profile(by="events")
    # count-weighted: the 64-count syrk site outweighs the 1-count logits
    assert prof.by == "events"
    assert prof.routine_weights["syrk"] > 0.2
    with pytest.raises(ValueError, match="flops.*events|events.*flops"):
        WorkloadProfile(by="wallclock")


def test_profile_rejects_unknown_routine():
    with pytest.raises(ValueError, match="unknown routine"):
        WorkloadProfile(routine_weights={"cholesky": 1.0})


def test_profile_from_dispatch_block_with_shapes():
    with DispatchRecorder() as rec:
        for e in _serve_events():
            record(e.routine, e.m, e.k, e.n, site=e.site, count=e.count)
    block = {"routine_mix": rec.routine_mix(),
             "summary": rec.summary(), "shapes": rec.shape_table()}
    prof = WorkloadProfile.from_dispatch_block(block)
    direct = WorkloadProfile.from_recorder(rec)
    assert prof.cells.keys() == direct.cells.keys()
    for c in prof.cells:
        np.testing.assert_allclose(prof.cells[c], direct.cells[c])


def test_profile_from_legacy_dispatch_block_mix_only():
    """Pre-shape-table dry-run blocks still yield routine weights (no
    cells — the installer falls back to uniform shape sampling)."""
    block = {"routine_mix": {"gemm": 0.8, "syrk": 0.2},
             "routine_mix_events": {"gemm": 0.75, "syrk": 0.25},
             "summary": {"gemm": {"events": 2, "flops": 8e9,
                                  "dispatches": 96},
                         "syrk": {"events": 1, "flops": 2e9,
                                  "dispatches": 32}}}
    prof = WorkloadProfile.from_dispatch_block(block)
    assert prof.cells == {}
    np.testing.assert_allclose(prof.routine_weights["gemm"], 0.8)
    assert prof.total == pytest.approx(10e9)
    dims = prof.sample_dims(8, mem_limit_bytes=2**28, seed=0)
    assert dims.shape == (8, 3)
    # events weighting = count-weighted dispatches, NOT raw traced
    # sites — a vmapped site's batch multiplicity must survive into
    # the merge weight
    ev = WorkloadProfile.from_dispatch_block(block, by="events")
    assert ev.total == pytest.approx(128)
    np.testing.assert_allclose(ev.routine_weights["gemm"], 0.75)


def test_merge_across_cells_volume_weighted():
    a = WorkloadProfile(routine_weights={"gemm": 1.0},
                        cells={(4, 11, 11): 1.0}, total=9e9)
    b = WorkloadProfile(routine_weights={"syrk": 1.0},
                        cells={(9, 6, 9): 1.0}, total=1e9)
    m = WorkloadProfile.merge([a, b])
    np.testing.assert_allclose(m.routine_weights["gemm"], 0.9)
    np.testing.assert_allclose(m.routine_weights["syrk"], 0.1)
    np.testing.assert_allclose(m.cells[(4, 11, 11)], 0.9)
    assert m.total == pytest.approx(10e9)
    assert m.source["n_profiles"] == 2
    # explicit weights override the recorded volumes
    m2 = WorkloadProfile.merge([a, b], weights=[1.0, 1.0])
    np.testing.assert_allclose(m2.routine_weights["gemm"], 0.5)
    # degenerate cases
    assert WorkloadProfile.merge([]).routine_weights == {}
    with pytest.raises(ValueError, match="mixed"):
        WorkloadProfile.merge(
            [a, WorkloadProfile(by="events", total=1.0)])
    with pytest.raises(ValueError, match="3 weights"):
        WorkloadProfile.merge([a, b], weights=[1.0, 2.0, 3.0])


# ---------------------------------------------------------------------
# quotas
# ---------------------------------------------------------------------

def test_apportion_exact_and_deterministic():
    assert sum(apportion([3, 1, 1], 100)) == 100
    assert apportion([0, 0], 5) == [3, 2]          # all-zero -> even
    assert apportion([], 5) == []
    assert apportion([1, 1, 1], 10) == apportion([1, 1, 1], 10)


def test_quota_allocation_proportional_with_floor():
    prof = _serve_profile()
    q = prof.routine_quotas(ROUTINES3, 100, floor=0.25)
    assert sum(q.values()) == 100
    # gemm dominates the flop mix -> the lion's share of the budget
    assert q["gemm"] > 70
    # the floor guarantees every requested routine keeps coverage even
    # at ~zero observed weight (trsm is ~0.1% of this profile's flops)
    assert q["trsm"] >= 8
    assert q["syrk"] >= 8


def test_quota_zero_weight_routine_gets_floor_only():
    prof = WorkloadProfile(routine_weights={"gemm": 1.0}, total=1.0)
    q = prof.routine_quotas(ROUTINES3, 90, floor=0.3)
    assert sum(q.values()) == 90
    assert q["syrk"] == q["trsm"] == 9             # 0.3 * 90 / 3
    assert q["gemm"] == 72
    # floor=0: unobserved routines get nothing
    q0 = prof.routine_quotas(ROUTINES3, 90, floor=0.0)
    assert q0 == {"gemm": 90, "syrk": 0, "trsm": 0}


def test_quota_single_routine_profile():
    prof = WorkloadProfile(routine_weights={"gemm": 1.0}, total=1.0)
    assert prof.routine_quotas(("gemm",), 37) == {"gemm": 37}
    with pytest.raises(ValueError, match="empty routine"):
        prof.routine_quotas((), 10)
    with pytest.raises(ValueError, match="outside"):
        prof.routine_quotas(("gemm",), 10, floor=1.5)


# ---------------------------------------------------------------------
# biased sampler
# ---------------------------------------------------------------------

def test_biased_sampler_coverage_floor_and_bias():
    prof = _serve_profile()
    mem = InstallConfig().mem_limit_bytes
    dims = prof.sample_dims(200, bias=0.75, mem_limit_bytes=mem,
                            dtype_bytes=2, seed=0)
    assert dims.shape == (200, 3)
    from repro.core.halton import gemm_bytes
    assert np.all(gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2], 2)
                  <= mem)
    in_region = np.asarray(
        [shape_cell(*d) in prof.cells for d in dims])
    # the biased fraction actually lands in observed regions...
    assert in_region.mean() > 0.5
    # ...and the uniform floor keeps coverage off-profile (the whole
    # point: the model must not collapse onto the recorded workload)
    assert (~in_region).sum() >= 0.15 * len(dims)
    # deterministic given seed
    np.testing.assert_array_equal(
        dims, prof.sample_dims(200, bias=0.75, mem_limit_bytes=mem,
                               dtype_bytes=2, seed=0))


def test_biased_sampler_bias_zero_is_uniform():
    prof = _serve_profile()
    from repro.core.halton import sample_gemm_dims
    mem = 2**28
    got = prof.sample_dims(32, bias=0.0, mem_limit_bytes=mem, seed=3)
    np.testing.assert_array_equal(
        got, sample_gemm_dims(32, mem_limit_bytes=mem, seed=3,
                              log_space=False))
    with pytest.raises(ValueError, match="bias"):
        prof.sample_dims(8, bias=1.5, mem_limit_bytes=mem)


def test_biased_sampler_unfillable_region_falls_back_to_floor():
    """A region whose octave box exceeds the memory budget hands its
    quota back to the uniform floor instead of spinning forever."""
    prof = WorkloadProfile(routine_weights={"gemm": 1.0},
                           cells={(16, 16, 16): 1.0}, total=1.0)
    mem = 64 * 2**20
    dims = prof.sample_dims(32, bias=0.9, mem_limit_bytes=mem,
                            dtype_bytes=2, seed=0)
    assert dims.shape == (32, 3)
    from repro.core.halton import gemm_bytes
    assert np.all(gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2], 2)
                  <= mem)


# ---------------------------------------------------------------------
# routine-assignment stratification bugfix
# ---------------------------------------------------------------------

def test_routine_assignment_not_stratified_across_halton_strata():
    """Routine id must be decoupled from sample index: the old
    ``i % len(routines)`` cycling locked each routine to a residue
    class of the *deterministic* Halton sequence — with 3 routines the
    base-3 (k) column's leading digit cycles with exactly that period,
    so each routine saw a disjoint third of the k range.  On a
    rejection-free domain the old scheme's per-routine marginals are
    fully disjoint (KS = 1.0); the seeded permutation must keep every
    pairwise, per-axis KS below the alpha=0.01 critical value region."""
    n = 300
    cfg = InstallConfig(n_samples=n, routines=ROUTINES3, dim_max=2048,
                        log_space=True, seed=0)
    from repro.core.halton import sample_gemm_dims
    dims = sample_gemm_dims(
        n, mem_limit_bytes=cfg.mem_limit_bytes,
        dtype_bytes=cfg.dtype_bytes, seed=0, dim_max=2048,
        log_space=True)

    def worst_ks(rids: np.ndarray) -> float:
        return max(_ks(dims[rids == r1, col], dims[rids == r2, col])
                   for col in range(3)
                   for r1 in range(3) for r2 in range(r1 + 1, 3))

    # the bug, reconstructed: index-cycled assignment is perfectly
    # stratified (disjoint per-routine k marginals)
    cycled = np.arange(n) % 3
    assert worst_ks(cycled) > 0.9

    # the fix: seeded-permutation marginals are indistinguishable
    # (alpha=0.01 two-sample KS critical value for 100 vs 100 is
    # ~0.23; 0.3 leaves deterministic-seed headroom)
    fixed = _assign_routines(cfg, n)
    assert worst_ks(np.asarray(fixed)) < 0.3

    # reproducible via InstallConfig.seed, different across seeds
    again = _assign_routines(cfg, n)
    np.testing.assert_array_equal(fixed, again)
    other = _assign_routines(
        InstallConfig(n_samples=n, routines=ROUTINES3, seed=1), n)
    assert not np.array_equal(fixed, other)


def test_assignment_budget_split_matches_old_cycling_counts():
    """Even split is preserved (only the *order* changed)."""
    cfg = InstallConfig(n_samples=100, routines=ROUTINES3)
    rids = np.asarray(_assign_routines(cfg, 100))
    assert np.bincount(rids, minlength=3).tolist() == [34, 33, 33]


def test_workload_path_routine_region_independence():
    """The mixture sampler's row shuffle and the routine-assignment
    permutation must come from DISTINCT rng streams: both are seeded
    from cfg.seed over the same n, and if they used the identical
    stream the two permutations would cancel in the (dim, routine)
    pairing — re-aligning routine id with the region block order, the
    exact stratification bug the uniform path just fixed."""
    prof = WorkloadProfile(
        routine_weights={"gemm": 0.5, "syrk": 0.5},
        # two regions far apart along m
        cells={(4, 8, 8): 0.5, (12, 8, 8): 0.5}, total=1.0)
    n = 200
    cfg = InstallConfig(n_samples=n, routines=("gemm", "syrk"),
                        workload=prof, workload_bias=0.8, seed=0)
    dims = prof.sample_dims(
        n, bias=cfg.workload_bias, mem_limit_bytes=cfg.mem_limit_bytes,
        dtype_bytes=cfg.dtype_bytes, seed=cfg.seed)
    rids = np.asarray(_assign_routines(cfg, n))
    # with cancelling permutations gemm takes the low-m region block
    # wholesale and KS on the m marginal is ~0.7; independent streams
    # keep the marginals indistinguishable
    assert _ks(dims[rids == 0, 0], dims[rids == 1, 0]) < 0.3


def test_gather_data_workload_quotas_and_provenance():
    prof = _serve_profile()
    cfg = InstallConfig(n_samples=60, repeats=1, tile_ids=(0,),
                        routines=ROUTINES3, workload=prof,
                        workload_bias=0.75, seed=0)
    data = gather_data(SimulatedBackend(seed=0), cfg)
    counts = np.bincount(data.routine_ids(), minlength=3)
    # gemm is ~98% of the profile's flops: it must dominate the budget,
    # while the floor keeps syrk/trsm covered
    assert counts[0] > 40
    assert counts[1] >= 4 and counts[2] >= 4
    assert data.workload == prof.to_dict()


# ---------------------------------------------------------------------
# GatheredData persistence guards
# ---------------------------------------------------------------------

def test_load_raises_on_missing_routines_with_mixed_config(tmp_path):
    """An npz without a ``routines`` array must not be silently
    mislabeled all-gemm when the sidecar config says the install mixed
    routines."""
    dims = np.array([[64, 64, 64], [128, 64, 64]], dtype=np.int64)
    times = np.ones((2, 1))
    cfgs = [costmodel.GemmConfig(1, "M", 0)]
    path = tmp_path / "gathered.npz"
    # simulate a pre-routine writer: no routines array
    np.savez_compressed(
        path, dims=dims, times=times,
        cfg_chips=np.asarray([1]), cfg_tile=np.asarray([0]),
        cfg_part=np.asarray([0]))
    mixed = {"install": {"routines": ["gemm", "syrk", "trsm"]}}
    with pytest.raises(ValueError, match="mixed routines"):
        GatheredData.load(str(path), config=mixed)
    # sidecar config.json next to the npz is picked up automatically
    with open(tmp_path / "config.json", "w") as f:
        json.dump(mixed, f)
    with pytest.raises(ValueError, match="mixed routines"):
        GatheredData.load(str(path))
    # a gemm-only sidecar (or none) keeps the legacy behaviour
    data = GatheredData.load(str(path),
                             config={"install": {"routines": ["gemm"]}})
    assert data.routines is None
    assert data.routine_names() == ["gemm", "gemm"]


def test_gathered_data_workload_npz_round_trip(tmp_path):
    prof = _serve_profile()
    cfg = InstallConfig(n_samples=12, repeats=1, tile_ids=(0,),
                        routines=ROUTINES3, workload=prof)
    data = gather_data(SimulatedBackend(seed=0), cfg)
    path = tmp_path / "gathered.npz"
    data.save(str(path))
    back = GatheredData.load(str(path))
    assert back.workload == prof.to_dict()
    np.testing.assert_array_equal(back.routine_ids(), data.routine_ids())


# ---------------------------------------------------------------------
# drift + artifact surfacing
# ---------------------------------------------------------------------

def test_drift_total_variation():
    prof = WorkloadProfile(routine_weights={"gemm": 0.8, "syrk": 0.2},
                           total=1.0)
    assert prof.drift({"gemm": 0.8, "syrk": 0.2}) == pytest.approx(0.0)
    assert prof.drift({"trsm": 1.0}) == pytest.approx(1.0)
    assert prof.drift({"gemm": 1.0}) == pytest.approx(0.2)
    # un-normalised observed mixes are normalised first
    assert prof.drift({"gemm": 8.0, "syrk": 2.0}) == pytest.approx(0.0)


def test_artifact_surfaces_workload_profile(tmp_path):
    prof = _serve_profile()
    cfg = InstallConfig(n_samples=40, repeats=1, tile_ids=(0, 3),
                        routines=ROUTINES3,
                        models=("linear_regression",),
                        workload=prof, seed=0)
    art = tmp_path / "artifact"
    install(SimulatedBackend(seed=0), cfg, artifact_dir=str(art))
    config = json.load(open(art / "config.json"))
    assert config["workload"] == prof.to_dict()
    assert config["install"]["workload_bias"] == cfg.workload_bias

    tuner = AdsalaTuner.from_artifact(str(art))
    assert tuner.workload is not None
    assert tuner.workload.to_dict() == prof.to_dict()
    drift = tuner.workload_drift({"gemm": 1.0})
    assert 0.0 < drift < 0.1                       # gemm-dominant profile


def test_uniform_artifact_has_no_workload(tiny_artifact):
    config = json.load(open(tiny_artifact.dir + "/config.json"))
    assert config["workload"] is None
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    assert tuner.workload is None
    assert tuner.workload_drift({"gemm": 1.0}) is None


# ---------------------------------------------------------------------
# the headline property: weighted install beats uniform on its workload
# ---------------------------------------------------------------------

@pytest.mark.slow
def test_mix_weighted_install_beats_uniform_on_profile(tmp_path):
    """Equal budget, same backend/models/candidates: the install driven
    by the recorded serve profile must achieve lower predicted-time
    regret on that profile's shape distribution than the uniform
    install (ISSUE 5 acceptance criterion).  Regret is measured against
    the noise-free oracle: mean(t_chosen / t_best - 1) over an eval set
    drawn from the profile itself."""
    prof = _serve_profile()
    backend = SimulatedBackend(seed=0)
    base = dict(n_samples=120, repeats=2, tile_ids=(0, 3),
                routines=ROUTINES3, models=("lightgbm",), cv_splits=2,
                seed=0)
    cfg_u = InstallConfig(**base)
    cfg_w = InstallConfig(**base, workload=prof, workload_bias=0.75)
    art_u, art_w = tmp_path / "uniform", tmp_path / "weighted"
    install(backend, cfg_u, artifact_dir=str(art_u))
    install(backend, cfg_w, artifact_dir=str(art_w))

    # eval set ~ the profile's own shape + routine distribution
    eval_dims = prof.sample_dims(
        80, bias=1.0, mem_limit_bytes=cfg_u.mem_limit_bytes,
        dtype_bytes=cfg_u.dtype_bytes, seed=1234)
    quotas = prof.routine_quotas(ROUTINES3, len(eval_dims), floor=0.0)
    names = np.repeat(np.asarray(ROUTINES3, dtype=object),
                      [quotas[r] for r in ROUTINES3])
    names = list(names[np.random.default_rng(7).permutation(len(names))])
    cands = costmodel.candidate_configs(cfg_u.max_chips,
                                        tiles=cfg_u.tile_ids)
    clean = backend.time_routine_clean_batch(eval_dims, cands,
                                             routines=names)
    t_best = clean.min(axis=1)

    def regret(artifact: str) -> float:
        tuner = AdsalaTuner.from_artifact(artifact)
        pred = tuner.predicted_times_many(
            [tuple(d) for d in eval_dims], routines=names)
        chosen = clean[np.arange(len(eval_dims)),
                       np.argmin(pred, axis=1)]
        return float(np.mean(chosen / np.maximum(t_best, 1e-12) - 1.0))

    r_uniform, r_weighted = regret(str(art_u)), regret(str(art_w))
    # measured margin is ~9x (0.68 vs 0.075); require a clear win, not
    # just a tie-break
    assert r_weighted < r_uniform * 0.8, \
        f"weighted regret {r_weighted:.4f} !< uniform {r_uniform:.4f}"
