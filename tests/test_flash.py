"""Tuned triangular flash attention: dense-vs-tri kernel parity, the
block-sparse tile map's properties, the padded-KV regression, and the
tuner-driven ops.flash_attention dispatch.

Runs under real `hypothesis` or the deterministic fallback shim —
only ``integers`` / ``sampled_from`` / ``booleans`` strategies are used.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import AdsalaTuner
from repro.kernels import ops
from repro.kernels.flash_attention import (
    FLASH_GRID_KINDS,
    flash_attention_pallas,
    flash_grid_counts,
    flash_tile_map,
)
from repro.kernels.recorder import DispatchRecorder
from repro.kernels.ref import flash_attention_ref


def _rand_qkv(sq, skv, d=16, bh=2, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# dense vs triangular grid parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv,causal,window", [
    (96, 96, True, None),          # square causal
    (100, 64, True, None),         # padded non-square, sq > skv
    (64, 100, True, None),         # padded non-square, sq < skv
    (96, 96, True, 40),            # sliding window (mixtral-style)
    (80, 80, False, None),         # non-causal (tri map == dense map)
    (96, 96, False, 24),           # window without causality
])
def test_tri_grid_matches_dense_grid(sq, skv, causal, window):
    q, k, v = _rand_qkv(sq, skv)
    outs = {}
    for grid in FLASH_GRID_KINDS:
        outs[grid] = np.asarray(flash_attention_pallas(
            q, k, v, bq=32, bkv=32, causal=causal, window=window,
            interpret=True, grid=grid))
    # identical block arithmetic in identical order -> bitwise equal
    np.testing.assert_array_equal(outs["tri"], outs["dense"])
    want = np.asarray(flash_attention_ref(q, k, v, causal=causal,
                                          window=window))
    np.testing.assert_allclose(outs["tri"], want, atol=2e-5, rtol=2e-5)


def test_tri_grid_matches_dense_gqa_broadcast_kv():
    """GQA: 8 query heads sharing 2 KV heads, KV broadcast before the
    flat (B*H, S, D) call — both grids agree with the oracle."""
    rng = np.random.default_rng(3)
    b, h, hk, s, d = 2, 8, 2, 72, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    kv = rng.standard_normal((2, b, hk, s, d)).astype(np.float32)
    k, v = (jnp.asarray(np.repeat(a, h // hk, axis=1)) for a in kv)
    flat = (b * h, s, d)
    outs = [flash_attention_pallas(q.reshape(flat), k.reshape(flat),
                                   v.reshape(flat), bq=32, bkv=32,
                                   interpret=True, grid=g)
            for g in FLASH_GRID_KINDS]
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.asarray(outs[1]))
    want = flash_attention_ref(q.reshape(flat), k.reshape(flat),
                               v.reshape(flat))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_bf16_tri_parity():
    q, k, v = _rand_qkv(64, 64, dtype=jnp.bfloat16)
    a, b_ = (np.asarray(flash_attention_pallas(
        q, k, v, bq=32, bkv=32, interpret=True, grid=g), np.float32)
        for g in FLASH_GRID_KINDS)
    np.testing.assert_array_equal(a, b_)


def test_unknown_grid_rejected():
    q, k, v = _rand_qkv(32, 32)
    with pytest.raises(ValueError, match="unknown flash grid"):
        flash_attention_pallas(q, k, v, interpret=True, grid="banded")


# ---------------------------------------------------------------------------
# padded-KV masking regression (the sq > skv denominator leak)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("grid", FLASH_GRID_KINDS)
@pytest.mark.parametrize("sq,skv", [(100, 64), (130, 70), (96, 33)])
def test_causal_padded_kv_regression(grid, sq, skv):
    """sq > skv with causal masking: padded KV ids in [skv, gkv*bkv)
    satisfy kv <= q for the tail query rows, so without the explicit
    KV-length mask their zero-K scores (exp(0) each) inflate the
    softmax denominator and shrink every tail-row output."""
    q, k, v = _rand_qkv(sq, skv, seed=7)
    out = np.asarray(flash_attention_pallas(
        q, k, v, bq=32, bkv=32, causal=True, interpret=True, grid=grid))
    want = np.asarray(flash_attention_ref(q, k, v, causal=True))
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    # the tail rows specifically (q id >= skv) are the leak site
    np.testing.assert_allclose(out[:, skv:], want[:, skv:],
                               atol=2e-5, rtol=2e-5)


def test_non_causal_padded_kv_supported():
    """Non-causal with a ragged Skv used to raise; the KV-length mask
    makes it exact instead."""
    q, k, v = _rand_qkv(64, 50, seed=9)
    for grid in FLASH_GRID_KINDS:
        out = flash_attention_pallas(q, k, v, bq=32, bkv=32,
                                     causal=False, interpret=True,
                                     grid=grid)
        want = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# tile-map properties
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(sq=st.integers(8, 600), skv=st.integers(8, 600),
       bq=st.sampled_from([16, 32, 64, 128]),
       bkv=st.sampled_from([16, 32, 64, 128]),
       causal=st.sampled_from([True, False]),
       window=st.sampled_from([None, 16, 64, 200]))
def test_tile_map_never_launches_fully_masked_tile(sq, skv, bq, bkv,
                                                   causal, window):
    """Every non-degenerate tile in the triangular map intersects the
    attention mask: some (q, kv) pair with kv < skv is unmasked.  (The
    single placeholder tile a fully-masked row emits so its output is
    still written is flagged first AND last.)"""
    qt, kvt, first, last = flash_tile_map(sq, skv, bq, bkv,
                                          causal=causal, window=window)
    gq, gkv = -(-sq // bq), -(-skv // bkv)
    assert len(qt) <= gq * gkv
    rows_seen = set()
    for i, j, f, l in zip(qt, kvt, first, last):
        rows_seen.add(int(i))
        q_ids = np.arange(i * bq, i * bq + bq)[:, None]
        kv_ids = np.arange(j * bkv, j * bkv + bkv)[None, :]
        mask = kv_ids < skv
        if causal:
            mask = mask & (kv_ids <= q_ids)
        if window is not None:
            mask = mask & (kv_ids > q_ids - window)
        if not (f and l):              # degenerate placeholders exempt
            assert mask.any(), (
                f"fully-masked tile ({i},{j}) launched for sq={sq} "
                f"skv={skv} bq={bq} bkv={bkv} causal={causal} "
                f"window={window}")
    # every output row block is written exactly once
    assert rows_seen == set(range(gq))
    for i in range(gq):
        row = [t for t in range(len(qt)) if qt[t] == i]
        assert sum(int(first[t]) for t in row) == 1
        assert sum(int(last[t]) for t in row) == 1
        # row-major, KV ascending: the sequential pipeline streams each
        # row's K/V blocks contiguously
        assert list(kvt[row]) == sorted(kvt[row])


@settings(max_examples=25, deadline=None)
@given(s=st.integers(256, 4096), b=st.sampled_from([64, 128, 256]))
def test_causal_square_tri_grid_fraction(s, b):
    """At Sq = Skv the triangular grid launches g(g+1)/2 of g² tiles —
    the (g+1)/2g fraction the cost model prices as tri_frac."""
    tri, dense = flash_grid_counts(s, s, b, b, causal=True)
    g = -(-s // min(b, s))
    assert dense == g * g
    assert tri == g * (g + 1) // 2


# ---------------------------------------------------------------------------
# tuner-driven dispatch through ops.flash_attention
# ---------------------------------------------------------------------------

def test_ops_flash_attention_honors_backend_env(monkeypatch):
    q, k, v = _rand_qkv(48, 48, seed=11)
    monkeypatch.setenv("ADSALA_BACKEND", "xla")
    out_xla = ops.flash_attention(q, k, v, causal=True)
    monkeypatch.setenv("ADSALA_BACKEND", "pallas")
    out_pl = ops.flash_attention(q, k, v, causal=True)
    monkeypatch.setenv("ADSALA_BACKEND", "bogus")
    with pytest.raises(ValueError, match="ADSALA_BACKEND"):
        ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pl),
                               atol=2e-5, rtol=2e-5)


def test_ops_flash_attention_records_resolved_config(tiny_artifact,
                                                     monkeypatch):
    """On the pallas backend a tuned masked call records ONE attn event
    whose config carries the resolved flash knobs (not config=None)."""
    monkeypatch.setenv("ADSALA_BACKEND", "pallas")
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    q, k, v = _rand_qkv(40, 40, seed=13)
    with DispatchRecorder() as rec:
        out = ops.flash_attention(q, k, v, causal=True, tuner=tuner)
    assert out.shape == q.shape
    attn = [e for e in rec.events if e.routine == "attn"]
    assert len(attn) == 1
    e = attn[0]
    assert (e.m, e.k, e.n) == (40, 16, 40)
    assert e.count == q.shape[0]
    assert e.config is not None
    assert e.config.flash_grid in FLASH_GRID_KINDS
    assert e.config.flash_block[0] >= 128
    # the same shape again is served from the tuner's LRU
    with DispatchRecorder() as rec2:
        ops.flash_attention(q, k, v, causal=True, tuner=tuner)
    assert [e.cache_hit for e in rec2.events
            if e.routine == "attn"] == [True]


def test_ops_flash_attention_explicit_knobs_skip_tuner(tiny_artifact,
                                                       monkeypatch):
    """Explicit bq/bkv/grid overrides bypass the tuner (like matmul's
    explicit tile) and still compute the right thing."""
    monkeypatch.setenv("ADSALA_BACKEND", "pallas")
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    calls_before = tuner.stats["calls"]
    q, k, v = _rand_qkv(64, 64, seed=17)
    with DispatchRecorder() as rec:
        out = ops.flash_attention(q, k, v, causal=True, tuner=tuner,
                                  bq=32, bkv=32, grid="tri")
    assert tuner.stats["calls"] == calls_before
    assert [e.config for e in rec.events] == [None]
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_ops_flash_attention_untuned_xla_syrk_fallback(monkeypatch):
    """Untuned XLA causal self-attention at Sq <= SYRK_FALLBACK_MAX_SEQ
    keeps the SYRK score materialisation (the retired layers hardcode's
    behavior), recording syrk — not attn — events."""
    monkeypatch.setenv("ADSALA_BACKEND", "xla")
    q, k, v = _rand_qkv(32, 32, seed=19)
    with DispatchRecorder() as rec:
        out = ops.flash_attention(q, k, v, causal=True)
    assert {e.routine for e in rec.events} == {"syrk"}
    # ...and past the threshold the chunked path records attn
    monkeypatch.setattr(ops, "SYRK_FALLBACK_MAX_SEQ", 16)
    with DispatchRecorder() as rec2:
        out2 = ops.flash_attention(q, k, v, causal=True)
    assert {e.routine for e in rec2.events} == {"attn"}
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=2e-5, rtol=2e-5)


def test_ops_flash_attention_tuned_xla_prices_syrk_vs_attn(tiny_artifact,
                                                           monkeypatch):
    """With attn + syrk signal the XLA branch picks the score path by
    predicted time, not by the retired hardcoded threshold: whatever it
    picks is recorded, and both paths agree numerically."""
    monkeypatch.setenv("ADSALA_BACKEND", "xla")
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    q, k, v = _rand_qkv(48, 48, seed=23)
    with DispatchRecorder() as rec:
        out = ops.flash_attention(q, k, v, causal=True, tuner=tuner)
    routines = {e.routine for e in rec.events}
    assert routines <= {"attn", "syrk"} and routines
    t_attn = float(np.min(tuner.select_with_times(48, 16, 48, "attn")[1]))
    t_syrk = float(np.min(tuner.select_with_times(48, 16, 48, "syrk")[1]))
    expected = "syrk" if t_syrk < t_attn else "attn"
    assert routines == {expected}
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_ops_flash_attention_non_causal_stays_gemm(tiny_artifact,
                                                   monkeypatch):
    """Unmasked attention keeps the gemm identity (dense grid, no attn
    routine) — the attn routine means a mask made tiles skippable."""
    monkeypatch.setenv("ADSALA_BACKEND", "pallas")
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    q, k, v = _rand_qkv(40, 40, seed=29)
    with DispatchRecorder() as rec:
        ops.flash_attention(q, k, v, causal=False, tuner=tuner)
    assert {e.routine for e in rec.events} == {"gemm"}
