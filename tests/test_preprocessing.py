"""Yeo-Johnson / scaler / LOF / correlation-prune properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.preprocessing import (
    PreprocessPipeline,
    StandardScaler,
    YeoJohnson,
    correlation_prune,
    local_outlier_factor,
    yeo_johnson_mle_lambda,
    yeo_johnson_transform,
    yeo_johnson_transform_matrix,
)


@settings(max_examples=50, deadline=None)
@given(lam=st.floats(-3, 3),
       x=st.lists(st.floats(-100, 100), min_size=3, max_size=30))
def test_yj_monotone(lam, x):
    """YJ is strictly monotone for every λ (order preserved)."""
    xs = np.unique(np.asarray(x, dtype=np.float64))
    if len(xs) < 2:
        return
    y = yeo_johnson_transform(xs, lam)
    assert np.all(np.diff(y) > -1e-12)


def test_yj_identity_at_lambda_one():
    x = np.linspace(-5, 5, 21)
    np.testing.assert_allclose(yeo_johnson_transform(x, 1.0), x, atol=1e-12)


def test_yj_log_branch():
    x = np.array([0.0, 1.0, np.e - 1.0])
    np.testing.assert_allclose(
        yeo_johnson_transform(x, 0.0), np.log1p(x), atol=1e-12)


def test_yj_matrix_matches_columnwise():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((40, 5)) * 7
    lams = np.array([-2.0, 0.0, 0.5, 2.0, 3.0])
    ref = np.stack([yeo_johnson_transform(X[:, j], lams[j])
                    for j in range(5)], axis=1)
    np.testing.assert_allclose(
        yeo_johnson_transform_matrix(X, lams), ref, atol=1e-10)


def test_yj_mle_gaussianises_lognormal():
    """MLE λ on lognormal data should pull skewness toward 0."""
    rng = np.random.default_rng(1)
    x = rng.lognormal(0.0, 1.0, 800)

    def skew(v):
        v = v - v.mean()
        return abs(np.mean(v**3) / (np.mean(v**2) ** 1.5 + 1e-12))

    lam = yeo_johnson_mle_lambda(x)
    assert skew(yeo_johnson_transform(x, lam)) < 0.3 * skew(x)


def test_scaler_roundtrip_stats():
    rng = np.random.default_rng(2)
    X = rng.standard_normal((200, 4)) * [1, 10, 100, 0.1] + [5, -3, 0, 2]
    Xt = StandardScaler().fit_transform(X)
    np.testing.assert_allclose(Xt.mean(0), 0.0, atol=1e-10)
    np.testing.assert_allclose(Xt.std(0), 1.0, atol=1e-10)


def test_lof_flags_planted_outliers():
    rng = np.random.default_rng(3)
    inliers = rng.standard_normal((200, 3))
    outliers = rng.standard_normal((5, 3)) * 0.1 + 15.0
    X = np.concatenate([inliers, outliers])
    lof = local_outlier_factor(X, k=10)
    # every planted outlier scores above the inlier 95th percentile
    assert lof[200:].min() > np.quantile(lof[:200], 0.95)


def test_correlation_prune_drops_duplicate():
    rng = np.random.default_rng(4)
    a = rng.standard_normal(300)
    b = rng.standard_normal(300)
    X = np.stack([a, a * 2.0 + 1e-9, b], axis=1)   # col1 = col0 duplicate
    alive, kept = correlation_prune(X, threshold=0.8)
    assert len(kept) == 2
    assert 2 in kept                                # independent col stays
    assert (0 in kept) != (1 in kept)               # one duplicate dropped


def test_pipeline_roundtrip_persistence():
    rng = np.random.default_rng(5)
    X = np.abs(rng.lognormal(0, 1, (150, 6)))
    y = rng.standard_normal(150)
    pipe = PreprocessPipeline()
    Xt, yt = pipe.fit_transform(X, y)
    assert Xt.shape[0] == yt.shape[0] <= 150
    pipe2 = PreprocessPipeline.from_dict(pipe.to_dict())
    Xq = np.abs(rng.lognormal(0, 1, (10, 6)))
    np.testing.assert_allclose(pipe.transform(Xq), pipe2.transform(Xq),
                               atol=1e-12)


def test_pipeline_never_drops_more_than_ten_percent():
    rng = np.random.default_rng(6)
    X = rng.standard_normal((100, 4))
    X[::7] += 40.0   # 15% extreme rows
    y = rng.standard_normal(100)
    pipe = PreprocessPipeline(lof_threshold=1.01)
    Xt, yt = pipe.fit_transform(X, y)
    assert len(yt) >= 90
