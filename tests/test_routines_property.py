"""Property tests for the BLAS-3 routine cost model and tuner plumbing.

Runs under real `hypothesis` or the deterministic
``repro._compat.hypothesis_fallback`` shim (fixed-seed example sweeps) —
only ``integers`` / ``sampled_from`` strategies and ``given``/``settings``
are used.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ROUTINES, AdsalaTuner, candidate_configs
from repro.core.costmodel import (
    GemmConfig,
    TPUSpec,
    TRSM_SEQ_CHIPS,
    estimate_batch_terms,
    estimate_routine_time,
    routine_ids,
)

_CFGS = [GemmConfig(c, p, t) for c in (1, 2, 4, 8, 64, 512)
         for p in ("M", "N", "K", "2D") for t in (0, 3, 5)
         if not (p == "2D" and c < 4)]


def _terms(tb):
    return (tb.compute_s, tb.memory_s, tb.collective_s, tb.launch_s)


# ---------------------------------------------------------------------------
# batched == scalar, bit for bit (noise-free), for every routine
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(m=st.integers(8, 65536), k=st.integers(8, 65536),
       n=st.integers(8, 65536),
       routine=st.sampled_from(ROUTINES))
def test_batch_matches_scalar_bitwise_per_routine(m, k, n, routine):
    bb = estimate_batch_terms(np.array([[m, k, n]]), _CFGS,
                              routines=routine)
    for j, cfg in enumerate(_CFGS):
        tb = estimate_routine_time(m, k, n, cfg, routine=routine)
        assert bb.compute_s[0, j] == tb.compute_s
        assert bb.memory_s[0, j] == tb.memory_s
        assert bb.collective_s[0, j] == tb.collective_s
        assert bb.launch_s[0, j] == tb.launch_s


def test_batch_matches_scalar_bitwise_mixed_rows():
    """Rows mixing all three routines in one grid call."""
    rng = np.random.default_rng(9)
    dims = np.stack([rng.integers(8, 65536, 30) for _ in range(3)],
                    axis=1).astype(np.int64)
    routines = [ROUTINES[i % 3] for i in range(len(dims))]
    bb = estimate_batch_terms(dims, _CFGS, routines=routines)
    for i, (m, k, n) in enumerate(dims):
        for j, cfg in enumerate(_CFGS):
            tb = estimate_routine_time(int(m), int(k), int(n), cfg,
                                       routine=routines[i])
            assert bb.compute_s[i, j] == tb.compute_s
            assert bb.memory_s[i, j] == tb.memory_s
            assert bb.collective_s[i, j] == tb.collective_s
            assert bb.launch_s[i, j] == tb.launch_s


def test_batch_matches_scalar_under_custom_spec_all_routines():
    spec = TPUSpec(vmem_bytes=2**16, peak_flops=90e12, mxu_dim=256)
    rng = np.random.default_rng(3)
    dims = np.stack([rng.integers(8, 4096, 12) for _ in range(3)],
                    axis=1).astype(np.int64)
    routines = [ROUTINES[i % 3] for i in range(len(dims))]
    bb = estimate_batch_terms(dims, _CFGS, spec, routines=routines)
    for i, (m, k, n) in enumerate(dims):
        for j, cfg in enumerate(_CFGS):
            tb = estimate_routine_time(int(m), int(k), int(n), cfg, spec,
                                       routine=routines[i])
            assert bb.total_s[i, j] == tb.total_s


# ---------------------------------------------------------------------------
# physics sanity per routine
# ---------------------------------------------------------------------------

@settings(max_examples=18, deadline=None)
@given(m=st.integers(8, 16384), k=st.integers(8, 16384),
       n=st.integers(8, 16384),
       routine=st.sampled_from(ROUTINES),
       cfg=st.sampled_from(_CFGS))
def test_terms_positive_and_finite_all_routines(m, k, n, routine, cfg):
    tb = estimate_routine_time(m, k, n, cfg, routine=routine)
    for v in _terms(tb):
        assert np.isfinite(v) and v >= 0
    assert tb.total_s > 0


@settings(max_examples=18, deadline=None)
@given(m=st.integers(8, 16384), k=st.integers(8, 16384),
       n=st.integers(8, 16384), cfg=st.sampled_from(_CFGS))
def test_syrk_flops_at_most_gemm(m, k, n, cfg):
    """Triangular output: SYRK never computes more than the same-shape
    GEMM (issue acceptance: SYRK flops <= GEMM flops)."""
    syrk = estimate_routine_time(m, k, n, cfg, routine="syrk")
    gemm = estimate_routine_time(m, k, n, cfg, routine="gemm")
    assert syrk.compute_s <= gemm.compute_s


@settings(max_examples=12, deadline=None)
@given(m=st.integers(64, 16384), k=st.integers(8, 4096),
       n=st.integers(8, 4096),
       p=st.sampled_from([8, 16, 64, 512]))
def test_trsm_m_parallelism_capped(m, k, n, p):
    """Chips beyond TRSM_SEQ_CHIPS on the M axis buy no compute time:
    the substitution chain serialises them."""
    at_cap = estimate_routine_time(
        m, k, n, GemmConfig(TRSM_SEQ_CHIPS, "M", 3), routine="trsm")
    beyond = estimate_routine_time(m, k, n, GemmConfig(p, "M", 3),
                                   routine="trsm")
    assert beyond.compute_s == at_cap.compute_s


def test_batch_noise_positive_finite_all_routines():
    rng = np.random.default_rng(4)
    dims = np.stack([rng.integers(8, 65536, 24) for _ in range(3)],
                    axis=1).astype(np.int64)
    routines = [ROUTINES[i % 3] for i in range(len(dims))]
    noisy = estimate_batch_terms(dims, _CFGS,
                                 rng=np.random.default_rng(7),
                                 routines=routines).total_s
    assert np.all(np.isfinite(noisy)) and np.all(noisy > 0)
    clean = estimate_batch_terms(dims, _CFGS, routines=routines).total_s
    assert np.all(noisy > 0.2 * clean) and np.all(noisy < 10 * clean)


def test_routine_ids_validation():
    assert routine_ids(None, 3).tolist() == [0, 0, 0]
    assert routine_ids("trsm", 2).tolist() == [2, 2]
    assert routine_ids(["gemm", "syrk"], 2).tolist() == [0, 1]
    with pytest.raises(ValueError, match="unknown routine"):
        routine_ids("cholesky", 1)
    with pytest.raises(ValueError, match="one per dim"):
        routine_ids(["gemm"], 2)


# ---------------------------------------------------------------------------
# tuner over the shared mixed-routine artifact
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_artifact_tuner_selects_consistently_per_routine(tiny_artifact):
    """select_many over a mixed-routine shape list returns exactly the
    per-routine scalar selections (routine-consistent configs)."""
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    tuner._cache.clear()
    shapes = [(512, 512, 512), (64, 2048, 64), (4096, 128, 4096)]
    routines = ["gemm", "syrk", "trsm"]
    pairs = [(s, r) for s in shapes for r in routines]
    batched = tuner.select_many([s for s, _ in pairs],
                                routines=[r for _, r in pairs])
    fresh = AdsalaTuner.from_artifact(tiny_artifact.dir)
    fresh._cache.clear()
    scalar = [fresh.select(*s, routine=r) for s, r in pairs]
    assert batched == scalar
    for cfg in batched:
        assert cfg in tuner.candidates


def test_stub_tuner_batched_times_positive():
    """Cheap no-artifact check that routine columns flow through the
    feature -> predict path for every routine."""

    class _Model:
        def predict(self, X):
            return np.log(1e-6 * (X[:, 3] + 1e-3 * X[:, 0] + X[:, 20]))

    class _Pipe:
        def transform(self, X):
            return X

    t = AdsalaTuner(_Model(), _Pipe(), candidate_configs(8, tiles=(0,)))
    times = t.predicted_times_many(
        [(64, 64, 64)] * 3, routines=["gemm", "syrk", "trsm"])
    assert times.shape == (3, len(t.candidates))
    assert np.all(np.isfinite(times)) and np.all(times > 0)
