"""Scrambled Halton sampler: domain, discrepancy, memory bound."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.halton import (
    gemm_bytes,
    halton_sequence,
    sample_gemm_dims,
    scrambled_halton,
)


def test_plain_halton_low_discrepancy_vs_random():
    """Star-discrepancy proxy: max deviation of empirical CDF on a grid
    must beat i.i.d. uniform sampling."""
    n = 512
    h = halton_sequence(n, 2)
    r = np.random.default_rng(0).random((n, 2))

    def disc(pts):
        worst = 0.0
        for gx in np.linspace(0.1, 1.0, 10):
            for gy in np.linspace(0.1, 1.0, 10):
                frac = np.mean((pts[:, 0] < gx) & (pts[:, 1] < gy))
                worst = max(worst, abs(frac - gx * gy))
        return worst

    assert disc(h) < disc(r)


def test_scrambled_halton_in_unit_cube():
    pts = scrambled_halton(1000, 3, seed=3)
    assert pts.shape == (1000, 3)
    assert np.all(pts >= 0.0) and np.all(pts < 1.0)


def test_scrambling_changes_points_but_keeps_uniformity():
    a = scrambled_halton(500, 3, seed=0)
    b = scrambled_halton(500, 3, seed=1)
    assert not np.allclose(a, b)
    for pts in (a, b):
        assert abs(pts.mean() - 0.5) < 0.05


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), mb=st.sampled_from([50, 100, 500]))
def test_samples_respect_memory_budget(seed, mb):
    dims = sample_gemm_dims(64, mem_limit_bytes=mb * 2**20, seed=seed)
    assert dims.shape == (64, 3)
    assert np.all(dims >= 8)
    assert np.all(gemm_bytes(dims[:, 0], dims[:, 1], dims[:, 2])
                  <= mb * 2**20)


def test_gemm_bytes_formula():
    # paper §IV-B: 4(mk + kn + mn) bytes single precision
    assert gemm_bytes(10, 20, 30, 4) == 4 * (200 + 600 + 300)
    assert gemm_bytes(10, 20, 30, 8) == 8 * (200 + 600 + 300)


def test_deterministic_given_seed():
    a = sample_gemm_dims(32, mem_limit_bytes=2**27, seed=7)
    b = sample_gemm_dims(32, mem_limit_bytes=2**27, seed=7)
    np.testing.assert_array_equal(a, b)
