"""Property tests for the paged-KV page allocator.

Runs under real `hypothesis` or the deterministic
``repro._compat.hypothesis_fallback`` shim (fixed-seed example sweeps) —
only ``integers`` / ``sampled_from`` / ``lists`` strategies and
``given``/``settings`` are used.

The allocator contract the continuous-batching scheduler leans on:

* a live page is never handed out twice;
* ``free + live == n_pages`` after *every* operation;
* retiring a sequence frees exactly the page count it held;
* exhaustion defers cleanly — ``None`` returned, state untouched.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.kv_cache import PageAllocator, pages_for


def test_pages_for_ceil():
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    assert pages_for(0, 4) == 0
    with pytest.raises(ValueError):
        pages_for(-1, 4)


# ---------------------------------------------------------------------------
# arbitrary admit/grow/retire trajectories keep every invariant
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n_pages=st.integers(1, 24), page_size=st.integers(1, 8),
       seed=st.integers(0, 10_000), n_ops=st.integers(1, 120))
def test_trajectory_invariants(n_pages, page_size, seed, n_ops):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(n_pages, page_size)
    next_seq = 0
    held: dict[int, int] = {}          # seq -> pages it must hold
    for _ in range(n_ops):
        op = rng.choice(["admit", "grow", "retire"])
        if op == "admit":
            want = int(rng.integers(1, 3 * page_size + 1))
            got = alloc.admit(next_seq, want)
            need = pages_for(want, page_size)
            if need > n_pages - sum(held.values()):
                assert got is None      # exhaustion defers, no change
            else:
                assert got is not None and len(got) == need
                assert len(set(got)) == need
                held[next_seq] = need
                next_seq += 1
        elif op == "grow" and held:
            seq = int(rng.choice(list(held)))
            total = int(rng.integers(1, 5 * page_size + 1))
            before = alloc.pages_of(seq)
            got = alloc.grow(seq, total)
            need = pages_for(total, page_size) - len(before)
            if need <= 0:
                assert got == []        # already covered
            elif need > n_pages - sum(held.values()):
                assert got is None
                assert alloc.pages_of(seq) == before   # untouched
            else:
                assert len(got) == need
                assert alloc.pages_of(seq) == before + got
                held[seq] += need
        elif op == "retire" and held:
            seq = int(rng.choice(list(held)))
            assert alloc.retire(seq) == held.pop(seq)
        # the conservation / no-double-allocation audit after every op
        alloc.check()
        assert alloc.free_pages + alloc.live_pages == n_pages
        assert alloc.live_pages == sum(held.values())
    # live pages across sequences are pairwise disjoint
    all_pages = [p for s in alloc.live_seqs for p in alloc.pages_of(s)]
    assert len(set(all_pages)) == len(all_pages)


@settings(max_examples=15, deadline=None)
@given(page_size=st.integers(1, 8), n_seqs=st.integers(1, 6))
def test_retire_frees_exactly_and_pages_recycle(page_size, n_seqs):
    alloc = PageAllocator(n_seqs * 3, page_size)
    admitted = {}
    for s in range(n_seqs):
        admitted[s] = alloc.admit(s, (s % 3 + 1) * page_size)
        assert admitted[s] is not None
    for s in range(n_seqs):
        assert alloc.retire(s) == len(admitted[s])
        alloc.check()
    assert alloc.free_pages == n_seqs * 3
    # every freed page is allocatable again
    again = alloc.admit(99, n_seqs * 3 * page_size)
    assert again is not None and sorted(again) == list(range(n_seqs * 3))


def test_exhaustion_defers_without_corruption():
    alloc = PageAllocator(4, 2)
    a = alloc.admit(0, 6)               # 3 pages
    assert len(a) == 3
    assert alloc.admit(1, 4) is None    # needs 2, only 1 free
    alloc.check()
    assert alloc.free_pages == 1
    assert alloc.pages_of(0) == a       # survivor untouched
    b = alloc.admit(1, 2)               # 1 page fits
    assert len(b) == 1 and not set(b) & set(a)
    assert alloc.grow(0, 8) is None     # 4th page: pool dry
    assert alloc.pages_of(0) == a
    alloc.retire(1)
    assert alloc.grow(0, 8) == b        # freed page recycles (LIFO)


def test_allocator_rejects_bad_usage():
    alloc = PageAllocator(4, 2)
    with pytest.raises(ValueError):
        alloc.admit(0, 0)               # empty sequence
    alloc.admit(0, 2)
    with pytest.raises(ValueError):
        alloc.admit(0, 2)               # duplicate seq id
    with pytest.raises(KeyError):
        alloc.retire(7)                 # never admitted
    with pytest.raises(ValueError):
        PageAllocator(0, 2)
    with pytest.raises(ValueError):
        PageAllocator(4, 0)
