"""AdsalaTuner LRU memoisation, batched selection and warm-start cache."""

import numpy as np
import pytest

from repro.core import (
    AdsalaTuner,
    GemmConfig,
    InstallConfig,
    SimulatedBackend,
    candidate_configs,
    install,
)


class _StubModel:
    """Deterministic 'runtime' model: log-time grows with chip count and
    with m, so the argmin is always the fewest-chips candidate."""

    def predict(self, X: np.ndarray) -> np.ndarray:
        # X columns follow FEATURE_NAMES: 0=m, 3=n_workers
        return np.log(1e-6 * (X[:, 3] + 1e-3 * X[:, 0]))


class _IdentityPipe:
    def transform(self, X: np.ndarray) -> np.ndarray:
        return X


def _tuner(**kw) -> AdsalaTuner:
    return AdsalaTuner(_StubModel(), _IdentityPipe(),
                       candidate_configs(64, tiles=(0, 3)), **kw)


def test_select_returns_min_chip_candidate():
    t = _tuner()
    cfg = t.select(512, 512, 512)
    assert cfg.n_chips == min(c.n_chips for c in t.candidates)


def test_lru_eviction_at_cache_size():
    t = _tuner(cache_size=4)
    shapes = [(64 * i, 64, 64) for i in range(1, 6)]
    for s in shapes:
        t.select(*s)
    assert len(t._cache) == 4
    assert (64, 64, 64) not in t._cache          # oldest evicted
    # re-selecting the evicted shape is a miss -> new evaluation
    before = t.stats["evaluations"]
    t.select(64, 64, 64)
    assert t.stats["evaluations"] == before + 1


def test_lru_move_to_end_recency():
    t = _tuner(cache_size=3)
    a, b, c, d = (64, 64, 64), (128, 64, 64), (192, 64, 64), (256, 64, 64)
    for s in (a, b, c):
        t.select(*s)
    t.select(*a)                                  # refresh a's recency
    t.select(*d)                                  # evicts b, not a
    assert a in t._cache and b not in t._cache
    assert list(t._cache) == [c, a, d]


def test_stats_counters():
    t = _tuner()
    t.select(64, 64, 64)
    t.select(64, 64, 64)
    t.select(128, 64, 64)
    assert t.stats == {"calls": 3, "cache_hits": 1, "evaluations": 2}


def test_select_with_times_consistency():
    t = _tuner()
    cfg, times = t.select_with_times(512, 256, 128)
    assert len(times) == len(t.candidates)
    assert t.candidates[int(np.argmin(times))] == cfg
    cfg2, times2 = t.select_with_times(512, 256, 128)
    assert cfg2 == cfg
    np.testing.assert_array_equal(times, times2)
    np.testing.assert_allclose(times, t.predicted_times(512, 256, 128))


def test_select_many_matches_scalar_selects():
    shapes = [(64, 64, 64), (512, 512, 512), (64, 2048, 64),
              (64, 64, 64)]
    batched = _tuner().select_many(shapes)
    scalar = [_tuner().select(*s) for s in shapes]
    assert batched == scalar


def test_select_many_stats_one_evaluation_per_unique_shape():
    t = _tuner()
    shapes = [(64, 64, 64)] * 3 + [(128, 64, 64)]
    t.select_many(shapes)
    assert t.stats == {"calls": 4, "cache_hits": 2, "evaluations": 2}
    t.select_many(shapes)                         # all cached now
    assert t.stats == {"calls": 8, "cache_hits": 6, "evaluations": 2}


def test_predicted_times_many_empty():
    t = _tuner()
    out = t.predicted_times_many([])
    assert out.shape == (0, len(t.candidates))
    assert t.select_many([]) == []


def test_select_many_respects_cache_size():
    t = _tuner(cache_size=2)
    t.select_many([(64 * i, 64, 64) for i in range(1, 6)])
    assert len(t._cache) == 2


def test_manual_warm_start_hits_without_evaluation():
    t = _tuner()
    cfg = t.candidates[0]
    t.warm_start([((64, 64, 64), cfg)])
    assert t.select(64, 64, 64) == cfg
    assert t.stats == {"calls": 1, "cache_hits": 1, "evaluations": 0}


def test_warm_start_times_recomputed_lazily():
    t = _tuner()
    # the stub model's true choice for this shape, from a scratch tuner
    expect = _tuner().select(64, 64, 64)
    t.warm_start([((64, 64, 64), expect)])
    cfg, times = t.select_with_times(64, 64, 64)
    assert cfg == expect
    assert t.candidates[int(np.argmin(times))] == cfg


@pytest.fixture(scope="module")
def small_artifact(tmp_path_factory):
    d = tmp_path_factory.mktemp("tuner_artifact")
    cfg = InstallConfig(n_samples=30, repeats=2, tile_ids=(0, 3),
                        models=("linear_regression",),
                        grid_budget="small", cv_splits=3, seed=0)
    backend = SimulatedBackend(seed=0)
    install(backend, cfg, artifact_dir=str(d))
    return d


def test_artifact_warm_start_round_trip(small_artifact):
    import json
    ws = json.load(open(small_artifact / "config.json"))["warm_start"]
    assert len(ws["dims"]) == 30 and len(ws["best"]) == 30

    tuner = AdsalaTuner.from_artifact(str(small_artifact))
    assert len(tuner._cache) == 30
    m, k, n = ws["dims"][0]
    cfg = tuner.select(m, k, n)
    assert tuner.stats == {"calls": 1, "cache_hits": 1, "evaluations": 0}
    assert isinstance(cfg, GemmConfig)
    # the persisted choice must equal what a cold tuner would compute
    cold = AdsalaTuner.from_artifact(str(small_artifact))
    cold._cache.clear()
    assert cold.select(m, k, n) == cfg


def test_artifact_warm_start_skipped_when_candidates_filtered(
        small_artifact):
    tuner = AdsalaTuner.from_artifact(str(small_artifact), max_chips=8)
    assert len(tuner._cache) == 0
    assert all(c.n_chips <= 8 for c in tuner.candidates)


def test_artifact_warm_start_grows_default_cache(small_artifact):
    """A warm set larger than the default cache must survive intact
    (the default install budget, 400 dims, exceeds cache_size=256);
    an explicitly requested cache_size still wins."""
    auto = AdsalaTuner.from_artifact(str(small_artifact))
    assert auto.cache_size >= 30 and len(auto._cache) == 30

    capped = AdsalaTuner.from_artifact(str(small_artifact), cache_size=10)
    assert capped.cache_size == 10 and len(capped._cache) == 10
