"""AdsalaTuner LRU memoisation, batched selection and warm-start cache."""

import numpy as np
import pytest

from repro.core import (
    AdsalaTuner,
    GemmConfig,
    candidate_configs,
)
from repro.core.features import LEGACY_FEATURE_NAMES


class _StubModel:
    """Deterministic 'runtime' model: log-time grows with chip count and
    with m, so the argmin is always the fewest-chips candidate."""

    def predict(self, X: np.ndarray) -> np.ndarray:
        # X columns follow FEATURE_NAMES: 0=m, 3=n_workers
        return np.log(1e-6 * (X[:, 3] + 1e-3 * X[:, 0]))


class _IdentityPipe:
    def transform(self, X: np.ndarray) -> np.ndarray:
        return X


def _tuner(**kw) -> AdsalaTuner:
    return AdsalaTuner(_StubModel(), _IdentityPipe(),
                       candidate_configs(64, tiles=(0, 3)), **kw)


def test_select_returns_min_chip_candidate():
    t = _tuner()
    cfg = t.select(512, 512, 512)
    assert cfg.n_chips == min(c.n_chips for c in t.candidates)


def test_lru_eviction_at_cache_size():
    t = _tuner(cache_size=4)
    shapes = [(64 * i, 64, 64) for i in range(1, 6)]
    for s in shapes:
        t.select(*s)
    assert len(t._cache) == 4
    assert ("gemm", 64, 64, 64) not in t._cache   # oldest evicted
    # re-selecting the evicted shape is a miss -> new evaluation
    before = t.stats["evaluations"]
    t.select(64, 64, 64)
    assert t.stats["evaluations"] == before + 1


def test_lru_move_to_end_recency():
    t = _tuner(cache_size=3)
    a, b, c, d = (64, 64, 64), (128, 64, 64), (192, 64, 64), (256, 64, 64)
    for s in (a, b, c):
        t.select(*s)
    t.select(*a)                                  # refresh a's recency
    t.select(*d)                                  # evicts b, not a
    ka, kb = ("gemm", *a), ("gemm", *b)
    assert ka in t._cache and kb not in t._cache
    assert list(t._cache) == [("gemm", *c), ka, ("gemm", *d)]


def test_routines_have_distinct_cache_entries():
    """gemm / syrk / trsm calls with the same dims never alias."""
    t = _tuner()
    for routine in ("gemm", "syrk", "trsm"):
        t.select(256, 128, 256, routine)
    assert t.stats == {"calls": 3, "cache_hits": 0, "evaluations": 3}
    for routine in ("gemm", "syrk", "trsm"):
        assert (routine, 256, 128, 256) in t._cache
    # repeat calls hit per-routine entries
    t.select(256, 128, 256, "trsm")
    assert t.stats["cache_hits"] == 1


def test_select_many_mixed_routines_matches_scalar():
    shapes = [(64, 64, 64)] * 3
    routines = ["gemm", "syrk", "trsm"]
    batched = _tuner().select_many(shapes, routines=routines)
    scalar = [_tuner().select(*s, routine=r)
              for s, r in zip(shapes, routines)]
    assert batched == scalar


def test_select_many_rejects_routine_length_mismatch():
    with pytest.raises(ValueError, match="one per"):
        _tuner().select_many([(64, 64, 64)] * 2, routines=["gemm"])
    with pytest.raises(ValueError, match="unknown routine"):
        _tuner().select(64, 64, 64, "cholesky")


def test_legacy_feature_artifact_refuses_new_routines():
    """A pre-routine artifact (19-col features) keeps serving gemm but
    raises for syrk/trsm instead of feeding the model unseen columns."""
    t = AdsalaTuner(_StubModel(), _IdentityPipe(),
                    candidate_configs(64, tiles=(0, 3)),
                    feature_names=list(LEGACY_FEATURE_NAMES))
    assert t.routines == ("gemm",)
    cfg = t.select(512, 512, 512)          # legacy layout still works
    assert cfg.n_chips == min(c.n_chips for c in t.candidates)
    with pytest.raises(ValueError, match="no training signal"):
        t.select(512, 512, 512, "syrk")


def test_gemm_only_install_refuses_unseen_routines(tiny_artifact,
                                                   tmp_path):
    """A *new* artifact installed with routines=('gemm',) has constant
    routine feature columns — its model never saw syrk/trsm vary, so
    the tuner must refuse them rather than hand out gemm-quality
    picks."""
    import json
    import shutil
    gemm_only = tmp_path / "gemm_only_artifact"
    shutil.copytree(tiny_artifact.dir, gemm_only)
    cfg_path = gemm_only / "config.json"
    config = json.load(open(cfg_path))
    config["install"]["routines"] = ["gemm"]
    json.dump(config, open(cfg_path, "w"))

    # the intact v2 warm_start now carries syrk/trsm entries the edited
    # install no longer claims — from_artifact drops them with a warning
    with pytest.warns(UserWarning, match="dropped"):
        tuner = AdsalaTuner.from_artifact(str(gemm_only))
    assert tuner.routines == ("gemm",)
    assert isinstance(tuner.select(512, 512, 512), GemmConfig)
    with pytest.raises(ValueError, match="no training signal"):
        tuner.select(512, 512, 512, "trsm")


def test_stats_counters():
    t = _tuner()
    t.select(64, 64, 64)
    t.select(64, 64, 64)
    t.select(128, 64, 64)
    assert t.stats == {"calls": 3, "cache_hits": 1, "evaluations": 2}


def test_select_with_times_consistency():
    t = _tuner()
    cfg, times = t.select_with_times(512, 256, 128)
    assert len(times) == len(t.candidates)
    assert t.candidates[int(np.argmin(times))] == cfg
    cfg2, times2 = t.select_with_times(512, 256, 128)
    assert cfg2 == cfg
    np.testing.assert_array_equal(times, times2)
    np.testing.assert_allclose(times, t.predicted_times(512, 256, 128))


def test_select_many_matches_scalar_selects():
    shapes = [(64, 64, 64), (512, 512, 512), (64, 2048, 64),
              (64, 64, 64)]
    batched = _tuner().select_many(shapes)
    scalar = [_tuner().select(*s) for s in shapes]
    assert batched == scalar


def test_select_many_stats_one_evaluation_per_unique_shape():
    t = _tuner()
    shapes = [(64, 64, 64)] * 3 + [(128, 64, 64)]
    t.select_many(shapes)
    assert t.stats == {"calls": 4, "cache_hits": 2, "evaluations": 2}
    t.select_many(shapes)                         # all cached now
    assert t.stats == {"calls": 8, "cache_hits": 6, "evaluations": 2}


def test_predicted_times_many_empty():
    t = _tuner()
    out = t.predicted_times_many([])
    assert out.shape == (0, len(t.candidates))
    assert t.select_many([]) == []


def test_select_many_respects_cache_size():
    t = _tuner(cache_size=2)
    t.select_many([(64 * i, 64, 64) for i in range(1, 6)])
    assert len(t._cache) == 2


def test_manual_warm_start_hits_without_evaluation():
    t = _tuner()
    cfg = t.candidates[0]
    t.warm_start([((64, 64, 64), cfg)])
    assert t.select(64, 64, 64) == cfg
    assert t.stats == {"calls": 1, "cache_hits": 1, "evaluations": 0}


def test_warm_start_times_recomputed_lazily():
    t = _tuner()
    # the stub model's true choice for this shape, from a scratch tuner
    expect = _tuner().select(64, 64, 64)
    t.warm_start([((64, 64, 64), expect)])
    cfg, times = t.select_with_times(64, 64, 64)
    assert cfg == expect
    assert t.candidates[int(np.argmin(times))] == cfg


def test_artifact_warm_start_round_trip(tiny_artifact):
    import json
    n = tiny_artifact.cfg.n_samples
    ws = json.load(
        open(tiny_artifact.dir + "/config.json"))["warm_start"]
    assert ws["version"] == 3
    assert len(ws["dims"]) == n and len(ws["configs"]) == n
    assert set(ws["routines"]) == {"gemm", "syrk", "trsm", "attn"}
    assert all({"n_chips", "partition", "tile_id"} <= set(c)
               for c in ws["configs"])

    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir)
    assert len(tuner._cache) == n
    m, k, n0 = ws["dims"][0]
    routine = ws["routines"][0]
    cfg = tuner.select(m, k, n0, routine)
    assert tuner.stats == {"calls": 1, "cache_hits": 1, "evaluations": 0}
    assert isinstance(cfg, GemmConfig)
    # the persisted choice must equal what a cold tuner would compute
    cold = AdsalaTuner.from_artifact(tiny_artifact.dir)
    cold._cache.clear()
    assert cold.select(m, k, n0, routine) == cfg


def test_artifact_v1_warm_start_loads_as_gemm(tiny_artifact, tmp_path):
    """A pre-routine warm_start block (no version/routines keys) must
    still preload — every entry keyed as a gemm choice."""
    import json
    import shutil
    legacy = tmp_path / "v1_artifact"
    shutil.copytree(tiny_artifact.dir, legacy)
    cfg_path = legacy / "config.json"
    config = json.load(open(cfg_path))
    # v1 blocks persisted argmin indices into the candidate list
    best = [config["candidates"].index(c)
            for c in config["warm_start"]["configs"]]
    config["warm_start"] = {
        "dims": config["warm_start"]["dims"], "best": best}
    json.dump(config, open(cfg_path, "w"))

    tuner = AdsalaTuner.from_artifact(str(legacy))
    assert len(tuner._cache) == tiny_artifact.cfg.n_samples
    assert all(key[0] == "gemm" for key in tuner._cache)
    m, k, n = config["warm_start"]["dims"][0]
    tuner.select(m, k, n)
    assert tuner.stats == {"calls": 1, "cache_hits": 1, "evaluations": 0}


def test_warm_start_entries_outside_installed_routines_dropped(
        tiny_artifact, tmp_path):
    """A hand-edited / mixed-version artifact whose warm_start block
    carries routines the install never covered must not preload them:
    a stale cache hit would serve a prediction the model has no signal
    for, where live dispatch degrades to gemm or raises."""
    import json
    import shutil
    mixed = tmp_path / "hand_edited"
    shutil.copytree(tiny_artifact.dir, mixed)
    cfg_path = mixed / "config.json"
    config = json.load(open(cfg_path))
    # claim a gemm-only install but leave the v2 mixed warm_start intact
    config["install"]["routines"] = ["gemm"]
    json.dump(config, open(cfg_path, "w"))

    n_gemm = config["warm_start"]["routines"].count("gemm")
    with pytest.warns(UserWarning, match="dropped"):
        tuner = AdsalaTuner.from_artifact(str(mixed))
    assert tuner.routines == ("gemm",)
    assert len(tuner._cache) == n_gemm
    assert all(key[0] == "gemm" for key in tuner._cache)
    # the syrk shapes that were in the block now raise like live
    # dispatch instead of serving a stale preloaded choice
    i = config["warm_start"]["routines"].index("syrk")
    m, k, n = config["warm_start"]["dims"][i]
    with pytest.raises(ValueError, match="no training signal"):
        tuner.select(m, k, n, "syrk")


def test_warm_start_out_of_space_config_dropped(tiny_artifact, tmp_path):
    """v3 blocks carry explicit config dicts; entries outside the
    persisted ConfigSpace (hand-edited / different install version) or
    malformed are dropped, not crashed on."""
    import json
    import shutil
    broken = tmp_path / "bad_config"
    shutil.copytree(tiny_artifact.dir, broken)
    cfg_path = broken / "config.json"
    config = json.load(open(cfg_path))
    # 6 chips is not a power-of-two doubling -> outside the space
    config["warm_start"]["configs"][0] = {
        "n_chips": 6, "partition": "2D", "tile_id": 3}
    config["warm_start"]["configs"][1] = {"partition": "M"}  # malformed
    json.dump(config, open(cfg_path, "w"))

    with pytest.warns(UserWarning, match="dropped 2/"):
        tuner = AdsalaTuner.from_artifact(str(broken))
    assert len(tuner._cache) == tiny_artifact.cfg.n_samples - 2
    # the dropped shapes fall back to a cold evaluation, not a crash
    ws = config["warm_start"]
    cfg = tuner.select(*ws["dims"][0], ws["routines"][0])
    assert isinstance(cfg, GemmConfig)
    assert tuner.stats["evaluations"] == 1


def test_warm_start_v2_out_of_range_best_index_dropped(tiny_artifact,
                                                       tmp_path):
    """v2 blocks (argmin indices) still load; indices outside the
    candidate list are dropped, not IndexError'd."""
    import json
    import shutil
    broken = tmp_path / "bad_index"
    shutil.copytree(tiny_artifact.dir, broken)
    cfg_path = broken / "config.json"
    config = json.load(open(cfg_path))
    n_cands = len(config["candidates"])
    best = [config["candidates"].index(c)
            for c in config["warm_start"]["configs"]]
    best[0] = n_cands + 7
    best[1] = -1
    config["warm_start"] = {
        "version": 2, "dims": config["warm_start"]["dims"],
        "routines": config["warm_start"]["routines"], "best": best}
    json.dump(config, open(cfg_path, "w"))

    with pytest.warns(UserWarning, match="dropped 2/"):
        tuner = AdsalaTuner.from_artifact(str(broken))
    assert len(tuner._cache) == tiny_artifact.cfg.n_samples - 2
    ws = config["warm_start"]
    cfg = tuner.select(*ws["dims"][0], ws["routines"][0])
    assert isinstance(cfg, GemmConfig)
    assert tuner.stats["evaluations"] == 1


def test_warm_start_v1_block_with_unknown_routine_key(tiny_artifact,
                                                      tmp_path):
    """v1-gemm-only path: a legacy block hand-edited with a bogus
    routines list on a gemm-only install keeps only valid entries."""
    import json
    import shutil
    legacy = tmp_path / "v1_bogus"
    shutil.copytree(tiny_artifact.dir, legacy)
    cfg_path = legacy / "config.json"
    config = json.load(open(cfg_path))
    config["install"]["routines"] = ["gemm"]
    dims = config["warm_start"]["dims"]
    best = [config["candidates"].index(c)
            for c in config["warm_start"]["configs"]]
    config["warm_start"] = {
        "dims": dims, "best": best,
        "routines": ["gemm"] * (len(dims) - 1) + ["trsm"]}
    json.dump(config, open(cfg_path, "w"))

    with pytest.warns(UserWarning, match="dropped 1/"):
        tuner = AdsalaTuner.from_artifact(str(legacy))
    assert len(tuner._cache) == len(dims) - 1
    assert all(key[0] == "gemm" for key in tuner._cache)


def test_artifact_warm_start_skipped_when_candidates_filtered(
        tiny_artifact):
    tuner = AdsalaTuner.from_artifact(tiny_artifact.dir, max_chips=8)
    assert len(tuner._cache) == 0
    assert all(c.n_chips <= 8 for c in tuner.candidates)


def test_artifact_warm_start_grows_default_cache(tiny_artifact):
    """A warm set larger than the default cache must survive intact
    (the default install budget, 400 dims, exceeds cache_size=256);
    an explicitly requested cache_size still wins."""
    n = tiny_artifact.cfg.n_samples
    auto = AdsalaTuner.from_artifact(tiny_artifact.dir)
    assert auto.cache_size >= n and len(auto._cache) == n

    capped = AdsalaTuner.from_artifact(tiny_artifact.dir, cache_size=10)
    assert capped.cache_size == 10 and len(capped._cache) == 10
