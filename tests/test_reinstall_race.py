"""Hammer select/select_many from N threads while artifact swaps fire.

The atomicity contract of :class:`repro.serve.ReinstallManager`: every
dispatch is served entirely by ONE artifact's tuner.  Two artifacts are
installed with disjoint tile sets, so their per-key choices are
distinguishable; reader threads hammer the manager while the main
thread fires swaps between them, and every observed config must be the
old artifact's choice or the new one's — never a third value, and
never a batch mixing the two (a torn swap).  Caches are per-artifact:
after the final swap the served configs equal a fresh load of the
final artifact, byte-for-byte of its choices.
"""

import threading

import numpy as np
import pytest

from repro.core.installer import InstallConfig, install
from repro.core.timing import SimulatedBackend
from repro.core.tuner import AdsalaTuner
from repro.kernels.recorder import DispatchRecorder
from repro.serve import ReinstallManager

pytestmark = pytest.mark.timeout(300)

#: disjoint tile sets -> the two artifacts choose from disjoint
#: candidate pools, so "which artifact served this?" is decidable
_TILES_A = (0, 1, 2)
_TILES_B = (5, 6, 7)

KEYS = [(int(m), int(k), int(n)) for m, k, n in
        np.random.default_rng(17).integers(128, 8192, (10, 3))]
ROUTINES_CYCLE = ["gemm", "syrk"] * 5


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    root = tmp_path_factory.mktemp("race")
    dirs = {}
    for name, tiles in (("a", _TILES_A), ("b", _TILES_B)):
        d = str(root / name)
        install(SimulatedBackend(seed=0),
                InstallConfig(n_samples=48, repeats=1,
                              routines=("gemm", "syrk"),
                              models=("decision_tree",),
                              tile_ids=tiles, seed=3),
                artifact_dir=d)
        dirs[name] = d
    return dirs


def _choices(artifact: str) -> dict:
    t = AdsalaTuner.from_artifact(artifact)
    return {(r, m, k, n): t.select(m, k, n, r)
            for (m, k, n), r in zip(KEYS, ROUTINES_CYCLE)}


def test_swaps_under_select_hammer_never_tear(arts):
    choice = {name: _choices(d) for name, d in arts.items()}
    keys = list(choice["a"])
    # the contract test needs distinguishable artifacts
    differing = [k for k in keys if choice["a"][k] != choice["b"][k]]
    assert differing, "artifacts with disjoint tiles chose identically"

    mgr = ReinstallManager(arts["a"], DispatchRecorder(),
                           backend=SimulatedBackend(seed=0))
    errors: list = []
    torn: list = []
    stop = threading.Event()
    n_batches = [0] * 6

    def hammer(tid: int) -> None:
        rng = np.random.default_rng(tid)
        while not stop.is_set():
            try:
                if tid % 2 == 0:
                    # single selects: observed value must belong to one
                    # of the two artifacts' choice sets
                    i = int(rng.integers(len(KEYS)))
                    r, m, k, n = keys[i]
                    got = mgr.select(m, k, n, r)
                    if got not in (choice["a"][keys[i]],
                                   choice["b"][keys[i]]):
                        torn.append((keys[i], got))
                else:
                    # batched: the WHOLE batch must be served by a
                    # single artifact — half-and-half is a torn swap
                    got = mgr.select_many(KEYS, routines=ROUTINES_CYCLE)
                    for src in ("a", "b"):
                        if all(g == choice[src][k]
                               for g, k in zip(got, keys)):
                            break
                    else:
                        torn.append(("batch", got))
                n_batches[tid] += 1
            except Exception as e:          # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(6)]
    for t in threads:
        t.start()
    try:
        for i in range(12):                # 12 live swaps under fire,
            mgr.swap_now(arts["a"] if i % 2 == 0 else arts["b"])  # ending on B
    finally:
        stop.set()
        for t in threads:
            t.join()

    assert not errors
    assert not torn, f"torn dispatches observed: {torn[:3]}"
    assert all(n > 0 for n in n_batches)
    assert mgr.swaps == 12

    # final artifact is B: post-swap selects equal a fresh B load —
    # the cache is keyed per artifact, old choices never leak through
    for key in keys:
        r, m, k, n = key
        assert mgr.select(m, k, n, r) == choice["b"][key]


def test_warm_carry_reselects_not_copies(arts):
    """The warm-start transplant re-evaluates hot keys through the NEW
    model; for keys where the artifacts disagree, serving the old
    choice after a swap would be a cache-leak bug."""
    mgr = ReinstallManager(arts["a"], DispatchRecorder(),
                           backend=SimulatedBackend(seed=0))
    choice_a, choice_b = _choices(arts["a"]), _choices(arts["b"])
    for (r, m, k, n), want in choice_a.items():
        assert mgr.select(m, k, n, r) == want
    mgr.swap_now(arts["b"])
    for (r, m, k, n), want in choice_b.items():
        assert mgr.peek(m, k, n, r)         # hot set carried over
        assert mgr.select(m, k, n, r) == want


def test_stats_are_per_artifact_instance(arts):
    mgr = ReinstallManager(arts["a"], DispatchRecorder(),
                           backend=SimulatedBackend(seed=0))
    for (m, k, n), r in zip(KEYS, ROUTINES_CYCLE):
        mgr.select(m, k, n, r)
        mgr.select(m, k, n, r)              # memo hit on the old tuner
    assert mgr.stats["cache_hits"] > 0
    old_stats = mgr.stats
    mgr.swap_now(arts["b"])
    assert mgr.stats is not old_stats       # fresh instance, fresh LRU
    assert mgr.stats["cache_hits"] == 0
